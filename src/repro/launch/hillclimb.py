import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Perf hillclimbing on the three chosen cells (EXPERIMENTS.md §Perf).

Each candidate is a hypothesis about the dominant roofline term; every row
is lowered, compiled, and scored — the log records hypothesis → change →
before → after → confirmed/refuted.

  PYTHONPATH=src python -m repro.launch.hillclimb [--cell glm4] [--out runs/hillclimb]
"""
import argparse
import json
from pathlib import Path

from repro.core.roofline import roofline_terms
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh

# hypothesis → overrides, per target cell
PLANS = {
    "glm4": {
        "arch": "glm4-9b", "shape": "train_4k",
        "candidates": [
            ("baseline (paper-faithful defaults)", {}),
            ("H1 flash-attn kernel: causal block-skip halves attention flops "
             "and removes S×S score traffic (compute+memory ↓)",
             {"use_flash": True}),
            ("H2 remat=dots: save matmul outputs, ~2x less recompute "
             "(compute ↓, HBM footprint ↑)", {"remat": "dots"}),
            ("H3 heads-TP (32 heads % 16 == 0): attention sharded over "
             "'model' removes the QKV all-gather (collective ↓, compute/16 "
             "on attention)", {"heads_tp": True}),
            ("H4 flash + dots + heads-TP combined",
             {"use_flash": True, "remat": "dots", "heads_tp": True}),
            ("H5 larger q-chunk (512): fewer scan steps, bigger score tiles "
             "(memory ↑ slightly, scan overhead ↓)", {"attn_q_chunk": 512}),
            ("H6 dots + drop seq-sharded residuals: the per-block boundary "
             "reshard costs an all-gather each way; dots-remat doesn't need "
             "the memory (collective ↓)",
             {"remat": "dots", "seq_shard_residuals": False}),
            ("H7 H6 + heads-TP: with boundaries unsharded, heads-TP's "
             "reshard overhead is gone too — attention compute /16",
             {"remat": "dots", "seq_shard_residuals": False, "heads_tp": True}),
        ],
    },
    "arctic": {
        "arch": "arctic-480b", "shape": "train_4k",
        "candidates": [
            ("baseline (mb=8, dispatch MoE)", {}),
            ("H1 microbatches 8→2: FSDP param regathers scale with mb count "
             "(collective ↓ ~4x, activation memory ↑ ~4x)",
             {"microbatches": 2}),
            ("H2 microbatches 8→4 (middle point)", {"microbatches": 4}),
            ("H3 ragged (dropless) MoE: no dispatch one-hots "
             "(memory/compute ↓, same collectives)", {"moe_impl": "ragged"}),
            ("H4 remat=dots (less recompute, more HBM)", {"remat": "dots"}),
            ("H5 mb=2 + flash attention", {"microbatches": 2, "use_flash": True}),
            ("H6 mb=2 + dots + no seq-res constraint (combine confirmed "
             "wins)", {"microbatches": 2, "remat": "dots",
                       "seq_shard_residuals": False}),
            ("H7 mb=1: regathers minimized; analytic HBM check decides "
             "feasibility", {"microbatches": 1, "remat": "dots",
                             "seq_shard_residuals": False}),
            ("H8 mb=4 + dots + no seq-res: the largest mb whose analytic "
             "HBM stays under 14.4 GiB", {"microbatches": 4, "remat": "dots",
                                          "seq_shard_residuals": False}),
        ],
    },
    "qwen15": {
        "arch": "qwen1.5-4b", "shape": "train_4k",
        "candidates": [
            ("baseline", {}),
            ("H1 flash-attn (MHA 20 heads, S=4k: attention is the biggest "
             "non-matmul term)", {"use_flash": True}),
            ("H2 remat=dots", {"remat": "dots"}),
            ("H3 flash + dots", {"use_flash": True, "remat": "dots"}),
            ("H4 dots + no seq-res constraint", {"remat": "dots",
                                                 "seq_shard_residuals": False}),
        ],
    },
}


def run_plan(name: str, plan, mesh, out_dir: Path):
    rows = []
    print(f"\n=== {plan['arch']} × {plan['shape']} ===")
    for label, ov in plan["candidates"]:
        try:
            res = lower_cell(plan["arch"], plan["shape"], mesh, overrides=ov)
            from repro.configs import get_config, param_count

            cfg_ov = {k: v for k, v in ov.items()
                      if k not in ("heads_tp", "microbatches", "moe_impl")}
            cfg = get_config(plan["arch"]).replace(**cfg_ov)
            mb = ov.get("microbatches",
                        8 if param_count(cfg) > 50e9 else 1)
            t = roofline_terms(res, cfg=cfg, microbatches=mb)
            row = {"cell": name, "label": label, "overrides": ov,
                   "compute_s": t["compute_s"], "memory_s": t["memory_s"],
                   "collective_s": t["collective_s"], "dominant": t["dominant"],
                   "step_bound_s": t["step_time_lower_bound_s"],
                   "roofline_fraction": t["roofline_fraction"],
                   "useful_flop_ratio": t["useful_flop_ratio"],
                   "peak_bytes": res["peak_bytes"],
                   "analytic_hbm_bytes": res["analytic_hbm_bytes"]}
            rows.append(row)
            print(f"  {label[:60]:62s} comp={t['compute_s']:8.2f}s "
                  f"mem={t['memory_s']:8.2f}s coll={t['collective_s']:8.2f}s "
                  f"dom={t['dominant'][:4]} bound={t['step_time_lower_bound_s']:8.2f}s",
                  flush=True)
        except Exception as e:  # servelint: ignore[broad-except] — hill-climb cell loop: a failed candidate is a data point; the error lands in the row and the climb continues
            rows.append({"cell": name, "label": label, "error": repr(e)[:300]})
            print(f"  {label[:60]:62s} FAILED: {e}", flush=True)
    with open(out_dir / f"{name}.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", choices=list(PLANS) + ["all"], default="all")
    ap.add_argument("--out", default="runs/hillclimb")
    args = ap.parse_args(argv)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    mesh = make_production_mesh()
    names = list(PLANS) if args.cell == "all" else [args.cell]
    for n in names:
        run_plan(n, PLANS[n], mesh, out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
