"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from runs/dryrun.

  PYTHONPATH=src python -m repro.launch.report [--results runs/dryrun/results.jsonl]
"""
import argparse
import json
from pathlib import Path

from repro.configs import all_cells
from repro.core.roofline import roofline_terms


def load(path):
    seen = {}
    for line in open(path):
        r = json.loads(line)
        seen[(r["arch"], r["shape"], r["mesh"])] = r  # last write wins
    return seen


def dryrun_table(rows):
    out = ["| arch | shape | mesh | peak GiB/dev (CPU-BA) | analytic GiB/dev "
           "| fits v5e | compile s |",
           "|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        fits = "yes" if r["analytic_hbm_bytes"] <= 16 * 2**30 * 0.9 else "NO"
        out.append(f"| {a} | {s} | {m} | {r['peak_bytes']/2**30:.2f} "
                   f"| {r['analytic_hbm_bytes']/2**30:.2f} | {fits} "
                   f"| {r['compile_s']} |")
    return "\n".join(out)


def roofline_table(rows, mesh="16x16"):
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL/HLO flops | roofline frac | what would move the dominant term |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(rows.items()):
        if m != mesh:
            continue
        t = roofline_terms(r)
        out.append(
            f"| {a} | {s} | {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {t['dominant'].replace('_s','')} "
            f"| {t['useful_flop_ratio']:.2f} | {t['roofline_fraction']:.1%} "
            f"| {suggestion(t, r)} |")
    return "\n".join(out)


def suggestion(t, r):
    dom = t["dominant"]
    if dom == "compute_s":
        if t["useful_flop_ratio"] < 0.5:
            return "cut redundant compute (remat policy, causal-aware attention)"
        return "near compute roofline; only kernel-level MXU tuning remains"
    if dom == "memory_s":
        return ("raise arithmetic intensity: fuse attention (Pallas flash), "
                "larger microbatch, bf16 residuals")
    return ("cut collective bytes: fewer FSDP regathers (lower microbatch "
            "count), heads-TP where divisible, int8-compressed DP")


def skip_table():
    out = ["| arch | shape | reason |", "|---|---|---|"]
    for cell in all_cells(include_skipped=True):
        if len(cell) == 3:
            out.append(f"| {cell[0]} | {cell[1]} | {cell[2]} |")
    return "\n".join(out)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="runs/dryrun/results.jsonl")
    ap.add_argument("--mesh", default="16x16")
    args = ap.parse_args(argv)
    rows = load(args.results)
    print("## Dry-run\n")
    print(dryrun_table(rows))
    print("\n## Skips\n")
    print(skip_table())
    print("\n## Roofline (single-pod 16x16)\n")
    print(roofline_table(rows, args.mesh))


if __name__ == "__main__":
    main()
