"""Production mesh construction (as functions — importing this module never
touches jax device state).

The paper's `taskset` pinning maps here: ``pinned=True`` orders devices so
that the 'model' axis (which carries the heaviest collectives) lands on
physically contiguous chips of the ICI torus — see core/affinity.py for the
topology model and the hop-cost scoring used by benchmarks/pinning.py.
"""
from __future__ import annotations

import logging

import jax
import numpy as np

log = logging.getLogger(__name__)


def make_production_mesh(*, multi_pod: bool = False, pinned: bool = True):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pinned:
        try:
            from jax.experimental import mesh_utils

            devs = mesh_utils.create_device_mesh(shape)
            return jax.sharding.Mesh(devs, axes)
        except (ImportError, NotImplementedError, ValueError,
                AssertionError, RuntimeError) as e:
            # the topology-aware path needs real accelerators in the right
            # count; on CPU/fake devices it raises one of the above — log
            # and fall back to enumeration order, never silently swallow
            log.warning("topology-pinned mesh unavailable (%s: %s); "
                        "falling back to enumeration-order mesh",
                        type(e).__name__, e)
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_mesh(shape, axes, *, pinned: bool = True):
    """Arbitrary mesh for sweeps/tests (e.g. (8,) or (4,2))."""
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
