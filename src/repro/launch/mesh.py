"""Production mesh construction (as functions — importing this module never
touches jax device state).

The paper's `taskset` pinning maps here: ``pinned=True`` orders devices so
that the 'model' axis (which carries the heaviest collectives) lands on
physically contiguous chips of the ICI torus — see core/affinity.py for the
topology model and the hop-cost scoring used by benchmarks/pinning.py.
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False, pinned: bool = True):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if pinned:
        try:
            from jax.experimental import mesh_utils

            devs = mesh_utils.create_device_mesh(shape)
            return jax.sharding.Mesh(devs, axes)
        except Exception:
            pass  # CPU fake devices: fall through to enumeration order
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)


def make_mesh(shape, axes, *, pinned: bool = True):
    """Arbitrary mesh for sweeps/tests (e.g. (8,) or (4,2))."""
    devs = np.asarray(jax.devices()[: int(np.prod(shape))]).reshape(shape)
    return jax.sharding.Mesh(devs, axes)
