import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves (a) the sharding config is coherent (no GSPMD
errors), (b) the program fits per-device HBM (memory_analysis), and
(c) yields the FLOP/byte/collective numbers the roofline analysis consumes.

Usage:
  python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out runs/dryrun
"""
import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (SHAPES_BY_NAME, all_cells, get_config, input_specs,
                           skip_reason, param_count, active_param_count)
from repro.core import hlo_cost, memory_model
from repro.configs.base import ShapeCfg
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.optim.adamw import AdamWCfg, init_opt_state
from repro.optim.schedules import constant
from repro.parallel.sharding import (make_rules, param_specs, use_mesh)
from repro.serve.serve_step import decode_state_specs, make_serve_step
from repro.train.train_step import (batch_specs, init_train_state,
                                    make_train_step, train_state_specs)

COLLECTIVE_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)\b")


def _ns(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def _opt_cfg(cfg):
    big = param_count(cfg) > 50e9 or cfg.param_dtype == "bfloat16"
    return AdamWCfg(state_dtype="int8" if big else "float32")


def lower_cell(arch: str, shape_name: str, mesh, *, save_hlo=None,
               overrides=None):
    """Lower + compile one cell. Returns a result dict (see keys below).

    ``overrides``: ModelCfg.replace kwargs, plus the special keys
      heads_tp     — shard attention heads over 'model' (rules-level)
      microbatches — grad-accumulation count for train cells
      moe_impl     — "dispatch" | "ragged" for every MoE block
    """
    overrides = dict(overrides or {})
    heads_tp = overrides.pop("heads_tp", None)
    microbatches = overrides.pop("microbatches", None)
    moe_impl = overrides.pop("moe_impl", None)
    cfg = get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    if moe_impl is not None:
        cfg = _set_moe_impl(cfg, moe_impl)
    shape = SHAPES_BY_NAME[shape_name]
    if heads_tp is None:
        # auto: shard attention heads over 'model' when every attention
        # block's group count divides the TP width (glm4 on a 16-wide mesh)
        model_size = mesh.shape.get("model", 1)
        gs = [b.attn.num_heads // b.attn.num_kv_heads
              for st in cfg.stages for b in st.pattern if b.attn is not None]
        heads_tp = bool(gs) and all(g % model_size == 0 for g in gs)
    long_ctx = shape.kind == "decode" and shape.global_batch < 8
    rules = make_rules(mesh, decode=shape.kind == "decode", long_ctx=long_ctx,
                       heads_tp=heads_tp)
    if microbatches is None and shape.kind == "train":
        microbatches = 8 if param_count(cfg) > 50e9 else 1
    t0 = time.time()
    with use_mesh(mesh, rules):
        if shape.kind == "train":
            lowered = _lower_train(cfg, shape, mesh, rules,
                                   microbatches=microbatches or 1)
        elif shape.kind == "prefill":
            lowered = _lower_prefill(cfg, shape, mesh, rules)
        else:
            lowered = _lower_decode(cfg, shape, mesh, rules)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax returns [dict]
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    # loop-aware per-device costs (XLA's cost_analysis counts while bodies
    # once — useless for scanned programs; see core/hlo_cost.py)
    walked = hlo_cost.analyze(hlo)
    res = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_devices": mesh.devices.size,
        "params": param_count(cfg), "active_params": active_param_count(cfg),
        "flops_per_device": walked["flops"],
        "bytes_per_device": walked["traffic_bytes"],
        "collective_bytes_per_device": walked["collective_bytes"],
        "collective_breakdown": {k[5:]: v for k, v in walked.items()
                                 if k.startswith("coll_")},
        "xla_flops_per_device": cost.get("flops", 0.0),
        "xla_bytes_per_device": cost.get("bytes accessed", 0.0),
        "analytic_hbm_bytes": memory_model.estimate(
            cfg, shape, dict(zip(mesh.axis_names, mesh.devices.shape)),
            microbatches=microbatches or 1,
        )["total"],
        "argument_bytes": mem.argument_size_in_bytes,
        "output_bytes": mem.output_size_in_bytes,
        "temp_bytes": mem.temp_size_in_bytes,
        "alias_bytes": mem.alias_size_in_bytes,
        "peak_bytes": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    }
    if save_hlo:
        Path(save_hlo).parent.mkdir(parents=True, exist_ok=True)
        Path(save_hlo).write_text(hlo)
        res["hlo_path"] = str(save_hlo)
    return res


def _set_moe_impl(cfg, impl: str):
    import dataclasses

    def fix(blk):
        if blk.moe is not None:
            return dataclasses.replace(blk, moe=dataclasses.replace(
                blk.moe, impl=impl))
        return blk

    stages = tuple(dataclasses.replace(st, pattern=tuple(fix(b) for b in st.pattern))
                   for st in cfg.stages)
    return cfg.replace(stages=stages)


def collective_bytes(hlo_text: str) -> float:
    """Sum result-shape bytes of every collective op in compiled HLO.

    Parses post-SPMD optimized HLO: ``%name = <shape(s)> all-reduce(...)``.
    Only the result shape (between '=' and the op name) is counted; async
    '-done' halves are skipped to avoid double counting with '-start'.
    """
    total = 0.0
    for line in hlo_text.splitlines():
        m = COLLECTIVE_RE.search(line)
        if not m or "=" not in line:
            continue
        rhs = line.split("=", 1)[1]
        op = COLLECTIVE_RE.search(rhs)
        if op is None:
            continue  # collective name appeared on the LHS only
        if rhs[op.end():op.end() + 5] == "-done":
            continue
        total += _shape_bytes(rhs[: op.start()])
    return total


_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1}
_SHAPE_RE = re.compile(r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# ---------------------------------------------------------------------------
# Per-kind lowering


def _lower_train(cfg, shape: ShapeCfg, mesh, rules, microbatches=1):
    opt_cfg = _opt_cfg(cfg)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg, opt_cfg))
    sspecs = train_state_specs(state_shapes, rules)
    bshapes = input_specs(cfg, shape)
    bspecs = batch_specs(bshapes)
    step = make_train_step(cfg, opt_cfg, constant(1e-4), microbatches=microbatches)
    fn = jax.jit(step,
                 in_shardings=(_ns(mesh, sspecs), _ns(mesh, bspecs)),
                 out_shardings=(_ns(mesh, sspecs), None),
                 donate_argnums=(0,))
    return fn.lower(state_shapes, bshapes)


def _lower_prefill(cfg, shape: ShapeCfg, mesh, rules):
    bshapes = input_specs(cfg, shape)
    bspecs = batch_specs(bshapes)
    pshapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_specs(pshapes, rules=rules)

    def fwd(params, batch):
        logits, _ = M.forward(params, cfg, batch)
        return logits

    fn = jax.jit(fwd, in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
    return fn.lower(pshapes, bshapes)


def _lower_decode(cfg, shape: ShapeCfg, mesh, rules):
    long_ctx = shape.global_batch < 8
    pshapes = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = param_specs(pshapes, rules=rules)
    B = shape.global_batch
    enc_shape = None
    if cfg.frontend == "vision":
        enc_shape = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model // 2), jnp.dtype(cfg.dtype))
    if enc_shape is not None:
        state_shapes = jax.eval_shape(
            lambda p, e: M.init_decode_state(p, cfg, B, shape.seq_len,
                                             enc_feats=e),
            pshapes, enc_shape)
    else:
        state_shapes = jax.eval_shape(
            lambda p: M.init_decode_state(p, cfg, B, shape.seq_len),
            pshapes)
    st_specs = decode_state_specs(state_shapes, rules)
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tok_spec = P(rules["act_batch"], None) if rules["act_batch"] else P(None, None)
    # all decode cells use the distributed flash-decode: the KV-cache seq dim
    # is sharded ('model' normally; ('data','model') for batch=1 long ctx)
    step = make_serve_step(cfg, sp_decode=True)
    fn = jax.jit(step,
                 in_shardings=(_ns(mesh, pspecs), _ns(mesh, st_specs),
                               NamedSharding(mesh, tok_spec)),
                 out_shardings=(None, _ns(mesh, st_specs)),
                 donate_argnums=(1,))
    return fn.lower(pshapes, state_shapes, tok)


# ---------------------------------------------------------------------------
# CLI


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="runs/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    args = ap.parse_args(argv)

    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    results, failures = [], []
    for arch, shape_name in cells:
        r = skip_reason(arch, shape_name)
        if r:
            print(f"SKIP {arch} × {shape_name}: {r}")
            continue
        for multi in meshes:
            mesh = make_production_mesh(multi_pod=multi)
            tag = "multi" if multi else "single"
            hlo = (out_dir / arch / f"{shape_name}.{tag}.hlo.txt"
                   if args.save_hlo else None)
            try:
                res = lower_cell(arch, shape_name, mesh, save_hlo=hlo)
                results.append(res)
                # one cell per JSON line so partial runs are usable
                with open(out_dir / "results.jsonl", "a") as f:
                    f.write(json.dumps(res) + "\n")
                print(f"OK   {arch} × {shape_name} × {tag}: "
                      f"peak={res['peak_bytes']/2**30:.2f}GiB/dev "
                      f"flops={res['flops_per_device']:.3g} "
                      f"coll={res['collective_bytes_per_device']/2**30:.3f}GiB "
                      f"(lower {res['lower_s']}s compile {res['compile_s']}s)",
                      flush=True)
            except Exception as e:  # servelint: ignore[broad-except] — dry-run cell loop: one cell's lowering failure must not kill the sweep; recorded in `failures` and printed with traceback
                failures.append((arch, shape_name, tag, repr(e)))
                print(f"FAIL {arch} × {shape_name} × {tag}: {e}", flush=True)
                traceback.print_exc()

    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    for f_ in failures:
        print("  FAIL", *f_)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
