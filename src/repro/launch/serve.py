"""Serving launcher: batched requests through the continuous-batching engine.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --requests 12
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, skip_reason
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=8)
    args = ap.parse_args(argv)

    if skip_reason(args.arch, "decode_32k"):
        raise SystemExit(f"{args.arch}: {skip_reason(args.arch, 'decode_32k')}")
    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_size=args.batch_size,
                         cache_len=max(128, args.prompt_len + args.max_tokens))
    rng = np.random.RandomState(0)
    uids = [engine.submit(rng.randint(0, cfg.vocab_size, args.prompt_len),
                          max_tokens=args.max_tokens)
            for _ in range(args.requests)]
    results = engine.run()
    for uid in uids:
        print(f"req {uid:3d}: {results[uid]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
