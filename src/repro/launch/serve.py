"""Serving launcher: batched requests through the ragged token-budget
engine (``--engine chunked`` runs the PR 1 two-phase paged engine,
``--engine reference`` the seed lock-step engine, for A/B).
``--scheduler`` swaps the admission/packing policy (fifo | prefix-aware |
slo); with ``slo``, ``--interactive-every N`` marks every Nth request
priority 1 so the policy has two classes to separate.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --requests 12
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_config, skip_reason
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.reference import ReferenceEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen2-1.5b")
    ap.add_argument("--engine", choices=("ragged", "chunked", "reference"),
                    default="ragged")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--page-size", type=int, default=16)
    ap.add_argument("--max-pages", type=int, default=None,
                    help="physical page-pool budget (default: full)")
    ap.add_argument("--prefill-chunk", type=int, default=32)
    ap.add_argument("--token-budget", type=int, default=128,
                    help="tokens per ragged tick (prefill + decode blend)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy; > 0 samples with --top-k/--seed")
    ap.add_argument("--top-k", type=int, default=None)
    ap.add_argument("--seed", type=int, default=None,
                    help="per-request sampling seed base")
    ap.add_argument("--flash-decode", action="store_true",
                    help="route global-layer decode through the Pallas "
                         "paged kernel")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the refcounted prefix cache / COW pages "
                         "(sharing is auto-disabled for hybrid models)")
    ap.add_argument("--kv-dtype", choices=("float32", "bfloat16", "int8"),
                    default=None,
                    help="paged KV pool storage dtype (default: activation "
                         "dtype); int8 quantizes on write with per-entry-"
                         "per-head scales and holds 2-4x the pages in the "
                         "same pool bytes")
    ap.add_argument("--scheduler", choices=("fifo", "prefix-aware", "slo"),
                    default="fifo",
                    help="admission/packing policy (fifo reproduces the "
                         "pre-policy engine exactly)")
    ap.add_argument("--interactive-every", type=int, default=0, metavar="N",
                    help="mark every Nth request priority 1 (the "
                         "interactive class the slo scheduler serves first)")
    args = ap.parse_args(argv)

    if skip_reason(args.arch, "decode_32k"):
        raise SystemExit(f"{args.arch}: {skip_reason(args.arch, 'decode_32k')}")
    cfg = get_config(args.arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    cache_len = max(128, args.prompt_len + args.max_tokens)
    if args.engine == "reference":
        engine = ReferenceEngine(params, cfg, batch_size=args.batch_size,
                                 cache_len=cache_len)
    else:
        engine = ServeEngine(params, cfg, batch_size=args.batch_size,
                             cache_len=cache_len, page_size=args.page_size,
                             max_pages=args.max_pages,
                             prefill_chunk=args.prefill_chunk,
                             token_budget=args.token_budget,
                             ragged=args.engine == "ragged",
                             flash_decode=args.flash_decode,
                             prefix_cache=not args.no_prefix_cache,
                             kv_dtype=args.kv_dtype,
                             scheduler=args.scheduler)
    rng = np.random.RandomState(0)
    sample_kw = {}
    if args.engine != "reference" and args.temperature > 0:
        sample_kw = dict(temperature=args.temperature, top_k=args.top_k)
    def _priority(i):
        if args.engine == "reference" or not args.interactive_every:
            return {}
        return {"priority": int((i + 1) % args.interactive_every == 0)}

    uids = [engine.submit(rng.randint(0, cfg.vocab_size, args.prompt_len),
                          max_tokens=args.max_tokens,
                          **(dict(sample_kw, seed=(args.seed or 0) + i)
                             if sample_kw else {}),
                          **_priority(i))
            for i in range(args.requests)]
    results = engine.run()
    for uid in uids:
        print(f"req {uid:3d}: {results[uid]}")
    if args.engine != "reference":
        print(f"stats: {engine.stats}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
