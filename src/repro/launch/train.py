"""Training launcher: ``--arch <id>`` selects any assigned architecture.

Single-host (reduced/smoke widths):
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke --steps 50

On a pod slice the same entry point runs the full config under
make_production_mesh(); this container is CPU-only, so full-size runs are
exercised via the dry-run (launch/dryrun.py) instead.
"""
import argparse

from repro.configs import ARCH_NAMES, get_config
from repro.configs.base import ShapeCfg, SHAPES_BY_NAME
from repro.optim.adamw import AdamWCfg
from repro.train.loop import TrainLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, required=True)
    ap.add_argument("--shape", default=None,
                    help="assigned shape name (e.g. train_4k); default: tiny")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--int8-opt", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if args.shape:
        shape = SHAPES_BY_NAME[args.shape]
    else:
        shape = ShapeCfg("tiny", 64, 8, "train")
    opt = AdamWCfg(state_dtype="int8" if args.int8_opt else "float32")
    loop = TrainLoop(cfg, shape, opt_cfg=opt, lr=args.lr,
                     total_steps=args.steps, microbatches=args.microbatches,
                     ckpt_dir=args.ckpt_dir)
    hist = loop.run(args.steps)
    print(f"{cfg.name}: loss {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"({args.steps} steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
