import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")

"""Run the paper's Nproc×Nthread × memory-mode sweep on the fake-device pod
(dry-run lowering; see core/sweep.py) and write runs/sweep/results.json.

  python -m repro.launch.sweep [--n-units 256] [--quick]
"""
import argparse
import json
from pathlib import Path

from repro.core.sweep import factorizations, run_sweep


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-units", type=int, default=256)
    ap.add_argument("--out", default="runs/sweep")
    ap.add_argument("--quick", action="store_true",
                    help="power-of-4 splits only (5 instead of 9)")
    args = ap.parse_args(argv)

    splits = factorizations(args.n_units)
    if args.quick:
        splits = [s for i, s in enumerate(splits) if i % 2 == 0]
    rows = run_sweep(args.n_units, splits=splits)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "results.json").write_text(json.dumps(rows, indent=1))

    print(f"{'Nproc':>6} {'Nthr':>5} {'placement':>9} {'memory':>7} "
          f"{'N':>7} {'GF/chip':>9} {'peak%':>7} dominant")
    for r in rows:
        print(f"{r['nproc']:6d} {r['nthread']:5d} {r['placement']:>9} "
              f"{r['memory']:>7} {r['N']:7d} {r['gflops_per_chip']:9.0f} "
              f"{r['peak_fraction']:7.1%} {r['dominant']}")
    best = max(rows, key=lambda r: r["peak_fraction"])
    print(f"\nbest: {best['placement']}-{best['memory']} @ "
          f"{best['nproc']}x{best['nthread']} -> {best['peak_fraction']:.1%} "
          f"of practical peak (paper: all2all-cache @ 66%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
