"""The jit-compiled training step: loss -> grads -> AdamW update.

State is a plain pytree {"params": ..., "opt": {m, v, step}} so checkpointing
and elastic resharding treat it uniformly.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.optim.adamw import AdamWCfg, apply_updates, init_opt_state
from repro.optim.quantized_state import is_quantized
from repro.parallel.sharding import constrain_like_params, logical_spec, param_specs


def init_train_state(key, cfg: ModelCfg, opt_cfg: AdamWCfg):
    params = M.init_params(key, cfg)
    return {"params": params, "opt": init_opt_state(params, opt_cfg)}


def make_train_step(cfg: ModelCfg, opt_cfg: AdamWCfg, lr_fn: Callable,
                    microbatches: int = 1):
    def train_step(state, batch):
        params = state["params"]

        def lfn(p, b):
            return M.loss_fn(p, cfg, b)

        if microbatches == 1:
            (loss, mets), grads = jax.value_and_grad(lfn, has_aux=True)(params, batch)
            grads = constrain_like_params(grads)
        else:
            mbs = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def body(acc, mb):
                (l, m), g = jax.value_and_grad(lfn, has_aux=True)(params, mb)
                g = constrain_like_params(g)
                acc = jax.tree.map(
                    lambda a, gi: a + gi.astype(a.dtype) / microbatches, acc, g)
                return acc, (l, m)

            # accumulate in the parameter dtype: f32 for ≤50B archs, bf16 for
            # the ≥398B ones (an f32 accumulator alone is 6.2 GiB/dev there)
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
            grads, (ls, ms) = jax.lax.scan(body, acc0, mbs)
            loss = jnp.mean(ls)
            mets = jax.tree.map(lambda x: jnp.mean(x), ms)

        lr = lr_fn(state["opt"]["step"])
        new_params, new_opt, om = apply_updates(params, grads, state["opt"],
                                                opt_cfg, lr)
        metrics = {"loss": loss, "lr": lr, **mets, **om}
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


# ---------------------------------------------------------------------------
# Sharding specs for the train state


def train_state_specs(state_shapes, rules=None):
    """PartitionSpec tree mirroring a {"params","opt"} state pytree.

    Moment leaves mirror the param spec; int8-quantized leaves carry a
    rowwise scale whose (size-1) last axis is unsharded.
    """
    pspecs = param_specs(state_shapes["params"], rules=rules)

    def moment_spec(ps, leaf):
        if is_quantized(leaf) or (isinstance(leaf, dict) and "q" in leaf):
            axes = tuple(ps)
            scale_axes = axes[:-1] + (None,) if axes else ()
            return {"q": ps, "qscale": P(*scale_axes)}
        return ps

    # walk m/v against param specs
    flat_ps, treedef = jax.tree.flatten(pspecs, is_leaf=lambda x: isinstance(x, P))
    flat_m = treedef.flatten_up_to(state_shapes["opt"]["m"])
    flat_v = treedef.flatten_up_to(state_shapes["opt"]["v"])
    m_specs = treedef.unflatten([moment_spec(p, l) for p, l in zip(flat_ps, flat_m)])
    v_specs = treedef.unflatten([moment_spec(p, l) for p, l in zip(flat_ps, flat_v)])
    return {"params": pspecs,
            "opt": {"m": m_specs, "v": v_specs, "step": P()}}


def batch_specs(batch_shapes):
    """Inputs: leading dim is global batch -> sharded over all data axes."""
    from repro.parallel.sharding import current_mesh, sanitize_spec

    mesh = current_mesh()

    def spec(x):
        if x.ndim == 0:
            return P()
        s = logical_spec(("act_batch",) + (None,) * (x.ndim - 1))
        return sanitize_spec(s, x.shape, mesh) if mesh is not None else s
    return jax.tree.map(spec, batch_shapes)
