"""Elastic rescale: move a training state onto a different mesh.

On a node failure the launcher picks the largest healthy factorization
(``rescale_plan``), and the checkpoint (stored in logical layout —
train/checkpoint.py) restores onto the new mesh.  ``reshard_state`` handles
the live-state path (same process, e.g. shrinking within a reservation).
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import make_rules, use_mesh
from repro.train.train_step import train_state_specs


def rescale_plan(n_healthy: int, prefer_model: int = 16) -> Tuple[int, int]:
    """Largest (data, model) mesh ≤ n_healthy, keeping TP width if we can.

    Preference order keeps the TP width, but never at the cost of idling
    >10% of the healthy nodes (a 3-node remainder should run 3×1, not 1×2).
    """
    candidates = []
    for model in (prefer_model, prefer_model // 2, prefer_model * 2,
                  8, 4, 2, 1):
        if model and model <= n_healthy:
            data = n_healthy // model
            used = data * model
            if used >= 0.9 * n_healthy:
                return (data, model)
            candidates.append((used, data, model))
    used, data, model = max(candidates)
    return (data, model)


def reshard_state(state, new_mesh: Mesh, rules=None):
    """Re-place every leaf of a train state onto ``new_mesh``."""
    rules = rules or make_rules(new_mesh)
    with use_mesh(new_mesh, rules):
        shapes = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        specs = train_state_specs(shapes, rules)

    def place(x, spec):
        return jax.device_put(jax.device_get(x), NamedSharding(new_mesh, spec))

    return jax.tree.map(place, state, specs,
                        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))
