"""Fault-tolerant checkpointing: tensor-chunked npz + JSON manifest.

Checkpoints store *logical* (unsharded) arrays keyed by pytree path, so a
checkpoint written on one mesh restores onto ANY mesh (elastic rescale) —
the restore path re-shards each tensor with the target mesh's NamedSharding.
Writes are atomic (tmp dir + rename) and optionally async (the state is
snapshotted to host first; a worker thread does the IO), so a preemption
mid-write never corrupts the latest checkpoint.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(state):
    flat = jax.tree_util.tree_flatten_with_path(state)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def save_checkpoint(ckpt_dir, state, step: int, *, background: bool = False,
                    keep: int = 3) -> Optional[threading.Thread]:
    """Write ``<ckpt_dir>/step_<N>/``.  If background=True, snapshot to host
    synchronously and write asynchronously (returns the writer thread)."""
    ckpt_dir = Path(ckpt_dir)
    host_state = {k: np.asarray(jax.device_get(v))
                  for k, v in _flatten(state).items()}

    def _write():
        final = ckpt_dir / f"step_{step:08d}"
        tmp = ckpt_dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        np.savez(tmp / "tensors.npz", **host_state)
        manifest = {"step": step,
                    "keys": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                             for k, v in host_state.items()}}
        (tmp / _MANIFEST).write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if background:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: Path, keep: int):
    steps = sorted(p for p in ckpt_dir.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir) -> Optional[int]:
    ckpt_dir = Path(ckpt_dir)
    if not ckpt_dir.exists():
        return None
    steps = [int(m.group(1)) for p in ckpt_dir.glob("step_*")
             if (m := re.match(r"step_(\d+)$", p.name))
             and (p / _MANIFEST).exists()]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir, state_like, *, step: Optional[int] = None,
                       mesh=None, specs=None) -> Dict[str, Any]:
    """Restore into the structure of ``state_like`` (a pytree of arrays or
    ShapeDtypeStructs).  With ``mesh`` + ``specs`` the tensors are placed
    sharded (elastic restore onto any mesh)."""
    ckpt_dir = Path(ckpt_dir)
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    data = np.load(ckpt_dir / f"step_{step:08d}" / "tensors.npz")

    flat, treedef = jax.tree_util.tree_flatten_with_path(state_like)
    spec_flat = None
    if specs is not None:
        spec_flat = [s for _, s in jax.tree_util.tree_flatten_with_path(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]]
    leaves = []
    for i, (path, like) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        arr = data[key]
        if mesh is not None and spec_flat is not None:
            sh = jax.sharding.NamedSharding(mesh, spec_flat[i])
            leaves.append(jax.device_put(arr, sh))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves)
