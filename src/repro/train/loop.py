"""The training loop: jit'd step + checkpoint/resume + failure handling.

Fault-tolerance contract (exercised by tests/test_fault_tolerance.py):
  - checkpoints every ``save_every`` steps (async host snapshot + atomic dir)
  - on restart, resumes from the latest checkpoint and the data pipeline
    skips to exactly the next unseen batch (deterministic ``batch_at``)
  - a transient step failure (``FailureInjector`` in tests; an XLA error or
    preempted host in production) triggers restore-from-last-checkpoint and
    replay, bounded by ``max_retries``
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg
from repro.data.pipeline import SyntheticLMData
from repro.models import model as M  # noqa: F401  (re-export convenience)
from repro.optim.adamw import AdamWCfg
from repro.optim.schedules import warmup_cosine
from repro.train import checkpoint as ckpt_lib
from repro.train.train_step import init_train_state, make_train_step


class TrainLoop:
    def __init__(self, cfg: ModelCfg, shape: ShapeCfg, *,
                 opt_cfg: Optional[AdamWCfg] = None,
                 lr: float = 3e-4, total_steps: int = 1000,
                 microbatches: int = 1,
                 ckpt_dir: Optional[str] = None, save_every: int = 50,
                 seed: int = 0, batch_override: Optional[int] = None,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 max_retries: int = 3):
        self.cfg = cfg
        self.opt_cfg = opt_cfg or AdamWCfg()
        self.lr_fn = warmup_cosine(lr, max(1, total_steps // 20), total_steps)
        self.step_fn = jax.jit(
            make_train_step(cfg, self.opt_cfg, self.lr_fn, microbatches),
            donate_argnums=(0,))
        self.data = SyntheticLMData(cfg, shape, seed, batch_override)
        self.ckpt_dir = Path(ckpt_dir) if ckpt_dir else None
        self.save_every = save_every
        self.failure_hook = failure_hook
        self.max_retries = max_retries
        self.seed = seed

    # -- state ------------------------------------------------------------
    def init_or_restore(self):
        start = 0
        if self.ckpt_dir is not None:
            latest = ckpt_lib.latest_step(self.ckpt_dir)
            if latest is not None:
                like = jax.eval_shape(lambda: init_train_state(
                    jax.random.PRNGKey(self.seed), self.cfg, self.opt_cfg))
                state = ckpt_lib.restore_checkpoint(self.ckpt_dir, like,
                                                    step=latest)
                return state, latest
        state = init_train_state(jax.random.PRNGKey(self.seed), self.cfg,
                                 self.opt_cfg)
        return state, start

    # -- run --------------------------------------------------------------
    def run(self, num_steps: int) -> List[Dict[str, float]]:
        state, step = self.init_or_restore()
        history: List[Dict[str, float]] = []
        retries = 0
        writer = None
        while step < num_steps:
            batch = {k: jax.numpy.asarray(v)
                     for k, v in self.data.batch_at(step).items()}
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)  # may raise (test injection)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                loss = float(metrics["loss"])
                history.append({"step": step, "loss": loss,
                                "time_s": time.perf_counter() - t0})
                if np.isnan(loss):
                    raise FloatingPointError(f"NaN loss at step {step}")
                retries = 0
                step += 1
            except FloatingPointError:
                raise
            except Exception:  # servelint: ignore[broad-except] — crash-recovery retry: any step failure restores from checkpoint and replays; re-raised once max_retries is exhausted
                retries += 1
                if retries > self.max_retries or self.ckpt_dir is None:
                    raise
                state, step = self.init_or_restore()  # restore + replay
                continue
            if (self.ckpt_dir is not None and step % self.save_every == 0):
                if writer is not None:
                    writer.join()
                writer = ckpt_lib.save_checkpoint(self.ckpt_dir, state, step,
                                                  background=True)
        if writer is not None:
            writer.join()
        if self.ckpt_dir is not None:
            ckpt_lib.save_checkpoint(self.ckpt_dir, state, step)
        self.final_state = state
        return history
