"""Shared ``interpret`` default for every Pallas entry point.

A dependency-free leaf module so the kernel modules (flash_attention,
matmul, rmsnorm) can import it at the top level without a cycle through
``kernels.ops`` (which imports all of them); ``kernels.ops`` re-exports
``default_interpret`` as the public name.

IMPORTANT: callers must resolve the flag BEFORE a jit boundary (pass a
concrete bool as the static ``interpret`` argument).  Resolving inside a
jitted body would bake the environment's value into the cached trace under
the static key ``None`` — later changes to REPRO_PALLAS_INTERPRET would be
silently ignored.
"""
from __future__ import annotations

import os

import jax


def default_interpret() -> bool:
    """False iff the active backend is a real TPU (the kernels then compile
    through Mosaic); True everywhere else (CPU CI runs the kernels in
    interpret mode).  ``REPRO_PALLAS_INTERPRET=0|1`` (also ``false|true``)
    forces either mode — e.g. ``=0`` to exercise the compile path in a TPU
    simulator, ``=1`` to debug numerics on a TPU host.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env.strip().lower() in ("0", "1", "false", "true"):
        return env.strip().lower() in ("1", "true")
    return jax.default_backend() != "tpu"
