"""Flash attention (causal, GQA, optional sliding window) as a Pallas kernel.

TPU adaptation of the classic GPU flash algorithm (DESIGN.md §2): instead of
warp-level shuffles, the online softmax state (m, l, acc) lives in VMEM
scratch across the sequential K-block grid dimension; the (bq × bk) score
tile is MXU-shaped.  Fully-masked K blocks are skipped with pl.when — this
is what removes the 2× causal overcount of the jnp fallback path (visible in
EXPERIMENTS.md §Perf).

Layouts: q (BH, S, hd); k, v (BKV, S, hd) with BH = B·kvH·G, BKV = B·kvH.
Grid = (BH, nq, nk), K innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, scale: float, window):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # causal block skip: K block strictly above the diagonal contributes 0
    in_reach = k_start <= q_start + bq - 1
    if window is not None:  # block entirely older than the window
        in_reach &= (q_start - (k_start + bk - 1)) < window

    @pl.when(in_reach)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = rows >= cols
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot(p.astype(v_ref.dtype).astype(jnp.float32),
                                      v_ref[0].astype(jnp.float32)))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "window", "interpret"))
def flash_attention(q, k, v, *, bq: int = 128, bk: int = 128, window=None,
                    interpret: bool = True):
    """Causal flash attention.

    q: (BH, S, hd); k, v: (BKV, S, hd); BH must be a multiple of BKV
    (grouped queries).  Returns (BH, S, hd).
    """
    BH, S, hd = q.shape
    BKV = k.shape[0]
    assert BH % BKV == 0
    G = BH // BKV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                          window=window),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
