"""Flash attention (causal, GQA, optional sliding window) as a Pallas kernel.

TPU adaptation of the classic GPU flash algorithm (DESIGN.md §2): instead of
warp-level shuffles, the online softmax state (m, l, acc) lives in VMEM
scratch across the sequential K-block grid dimension; the (bq × bk) score
tile is MXU-shaped.  Fully-masked K blocks are skipped with pl.when — this
is what removes the 2× causal overcount of the jnp fallback path (visible in
EXPERIMENTS.md §Perf).

Layouts: q (BH, S, hd); k, v (BKV, S, hd) with BH = B·kvH·G, BKV = B·kvH.
Grid = (BH, nq, nk), K innermost.

The two paged serving kernels (``paged_flash_decode``,
``ragged_paged_flash``) additionally support **int8 quantized KV pools**:
when the pool dtype is int8, per-entry-per-KV-head float32 scale pools
(``ks``/``vs``, shape (n_pages, page, kvH)) ride in through the same
block-table indirection, and each page tile is dequantized IN VMEM right
after its DMA — ``k = int8_tile * scale_row`` feeding the unchanged fp32
online-softmax accumulate.  HBM traffic per page is therefore the int8
bytes plus one scale row (~hd/4× less than fp32 KV), never a dequantized
copy — the serving analogue of the paper's point that keeping the working
set in fast memory, not adding FLOPs, is what moves the bound.

``interpret=None`` on every entry point resolves through
``kernels.ops.default_interpret()``: compiled for real on TPU backends,
interpret mode elsewhere (CPU CI), overridable via REPRO_PALLAS_INTERPRET.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import default_interpret

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            bq: int, bk: int, nk: int, scale: float, window):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * bq
    k_start = ki * bk

    # causal block skip: K block strictly above the diagonal contributes 0
    in_reach = k_start <= q_start + bq - 1
    if window is not None:  # block entirely older than the window
        in_reach &= (q_start - (k_start + bk - 1)) < window

    @pl.when(in_reach)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale  # (bq, hd)
        k = k_ref[0].astype(jnp.float32)  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        rows = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = rows >= cols
        if window is not None:
            mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (acc_ref[...] * corr
                        + jax.lax.dot(p.astype(v_ref.dtype).astype(jnp.float32),
                                      v_ref[0].astype(jnp.float32)))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


# ---------------------------------------------------------------------------
# Paged flash decode (serving): single-token attention over a block-table-
# indexed KV pool.  The block table rides in as a scalar-prefetch argument so
# the BlockSpec index_map can resolve page -> pool-row indirection before
# each grid step's DMA — the kernel body itself never sees the indirection,
# only a dense (page_size, hd) tile (plus, for int8 pools, its (page_size,)
# scale row, dequantized here in VMEM).  Grid = (B, kvH, n_pages_per_slot)
# with the page dimension innermost (sequential online-softmax state in
# VMEM).


def _paged_decode_kernel(ptab_ref, lens_ref, q_ref, k_ref, v_ref, *rest,
                         page: int, npages: int, scale: float,
                         quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    b, ji = pl.program_id(0), pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_valid = lens_ref[b] - ji * page  # written entries in this page

    @pl.when(n_valid > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        if quantized:  # fused dequant: int8 page tile × its scale row
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, page)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < n_valid, s, NEG_INF)

        m_prev = m_ref[...]  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(cols < n_valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ji == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_flash_decode(q, kp, vp, ptab, lens, ks=None, vs=None, *,
                       interpret=None):
    """Decode-step attention over a paged KV pool.

    q: (B, kvH, G, hd); kp, vp: (n_pages, page, kvH, hd);
    ptab: (B, pps) int32 block table (entries >= n_pages = unmapped);
    lens: (B,) int32 valid entries per slot.  Returns (B, kvH, G, hd).
    Full (non-windowed) causal layers only — every written entry is visible
    to the single query token.

    int8 pools: pass ``ks``/``vs`` ((n_pages, page, kvH) float32 scale
    pools); the page tiles are dequantized in VMEM inside the online-softmax
    loop, so the fp32 accumulate is unchanged while the page DMA moves ~4×
    fewer bytes.
    """
    # resolve OUTSIDE the jit boundary: a concrete bool is the static key,
    # so a later REPRO_PALLAS_INTERPRET change retraces instead of silently
    # reusing a cache entry keyed on None
    if interpret is None:
        interpret = default_interpret()
    return _paged_flash_decode(q, kp, vp, ptab, lens, ks, vs,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _paged_flash_decode(q, kp, vp, ptab, lens, ks, vs, *, interpret):
    B, kvH, G, hd = q.shape
    npages, page = kp.shape[0], kp.shape[1]
    pps = ptab.shape[1]
    scale = hd ** -0.5
    quantized = ks is not None

    def _page_idx(b, h, j, ptab_ref, lens_ref):
        # unmapped sentinel pages clamp to a real pool row; their entries
        # are dead via the lens mask in the kernel body
        return (jnp.minimum(ptab_ref[b, j], npages - 1), 0, h, 0)

    def _scale_idx(b, h, j, ptab_ref, lens_ref):
        return (jnp.minimum(ptab_ref[b, j], npages - 1), 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, j, pt, ln: (b, h, 0, 0)),
        pl.BlockSpec((1, page, 1, hd), _page_idx),
        pl.BlockSpec((1, page, 1, hd), _page_idx),
    ]
    args = [ptab, lens, q, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), _scale_idx),
                     pl.BlockSpec((1, page, 1), _scale_idx)]
        args += [ks, vs]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, kvH, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, j, pt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, page=page, npages=npages,
                          scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, kvH, G, hd), q.dtype),
        interpret=interpret,
    )(*args)


# ---------------------------------------------------------------------------
# Ragged paged flash (serving): attention for a flat pack of T query tokens
# from arbitrary slots — the kernel-level half of the engine's single ragged
# program.  Each pack token carries its own slot index and visible length, so
# prefill-chunk tokens and decode tokens run through the same grid; the slot
# index rides in as scalar prefetch and resolves the per-token block-table
# row in the BlockSpec index_map (a double indirection: token -> slot ->
# page -> pool row), before each grid step's DMA.  Grid = (T, kvH, pps),
# pages innermost (sequential online-softmax state in VMEM).


def _ragged_decode_kernel(slot_ref, lens_ref, ptab_ref, q_ref, k_ref, v_ref,
                          *rest, page: int, npages: int, scale: float,
                          quantized: bool):
    if quantized:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    t, ji = pl.program_id(0), pl.program_id(2)

    @pl.when(ji == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # visible entries of this page for this token: positions 0..lens-1 are
    # contiguous per slot, so the causal mask is just a length cutoff —
    # intra-pack keys written at positions beyond this token stay invisible
    n_valid = lens_ref[t] - ji * page

    @pl.when(n_valid > 0)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale  # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)  # (page, hd)
        if quantized:  # fused dequant: int8 page tile × its scale row
            k = k * ks_ref[0, :, 0][:, None]
            v = v * vs_ref[0, :, 0][:, None]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (G, page)
        cols = jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(cols < n_valid, s, NEG_INF)

        m_prev = m_ref[...]  # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(cols < n_valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(p, v)
        m_ref[...] = m_new

    @pl.when(ji == pl.num_programs(2) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks=None, vs=None, *,
                       interpret=None):
    """Ragged-pack attention over a paged KV pool (one serving tick).

    q: (T, kvH, G, hd) — T pack tokens from arbitrary slots; slot: (T,)
    int32 per-token slot index; lens: (T,) int32 visible entries for each
    token (``q_pos + 1``; 0 = invalid token, output is zeros);
    kp, vp: (n_pages, page, kvH, hd); ptab: (B, pps) int32 block table.
    Returns (T, kvH, G, hd).  Full (non-windowed) causal layers only.

    Refcounted prefix-shared pages (serve.engine) require NO kernel change:
    every K/V tile is fetched through the token -> slot -> page indirection
    above, so block-table rows of different slots aliasing the same pool
    page read the same bytes, and copy-on-write happens before the step in
    the allocator (a ``kernels.ops.copy_pages`` call), never in here.

    int8 quantized pools require only the scale-row side channel: pass
    ``ks``/``vs`` ((n_pages, page, kvH) float32) and each page tile is
    dequantized in VMEM right after its DMA — aliased (prefix-shared) pages
    alias their scale rows through the same indirection, so sharing, COW,
    and quantization compose without further machinery.
    """
    if interpret is None:  # resolve outside the jit boundary (see above)
        interpret = default_interpret()
    return _ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks, vs,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks, vs, *, interpret):
    T, kvH, G, hd = q.shape
    npages, page = kp.shape[0], kp.shape[1]
    pps = ptab.shape[1]
    scale = hd ** -0.5
    quantized = ks is not None

    def _page_idx(t, h, j, slot_ref, lens_ref, ptab_ref):
        # token -> slot -> page -> pool row; unmapped sentinel pages clamp
        # to a real row whose entries are dead via the lens cutoff
        return (jnp.minimum(ptab_ref[slot_ref[t], j], npages - 1), 0, h, 0)

    def _scale_idx(t, h, j, slot_ref, lens_ref, ptab_ref):
        return (jnp.minimum(ptab_ref[slot_ref[t], j], npages - 1), 0, h)

    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda t, h, j, sl, ln, pt: (t, h, 0, 0)),
        pl.BlockSpec((1, page, 1, hd), _page_idx),
        pl.BlockSpec((1, page, 1, hd), _page_idx),
    ]
    args = [slot, lens, ptab, q, kp, vp]
    if quantized:
        in_specs += [pl.BlockSpec((1, page, 1), _scale_idx),
                     pl.BlockSpec((1, page, 1), _scale_idx)]
        args += [ks, vs]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(T, kvH, pps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda t, h, j, sl, ln, pt: (t, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_decode_kernel, page=page, npages=npages,
                          scale=scale, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((T, kvH, G, hd), q.dtype),
        interpret=interpret,
    )(*args)


def flash_attention(q, k, v, *, bq: int = 128, bk: int = 128, window=None,
                    interpret=None):
    """Causal flash attention.

    q: (BH, S, hd); k, v: (BKV, S, hd); BH must be a multiple of BKV
    (grouped queries).  Returns (BH, S, hd).
    """
    if interpret is None:  # resolve outside the jit boundary (see above)
        interpret = default_interpret()
    return _flash_attention(q, k, v, bq=bq, bk=bk, window=window,
                            interpret=interpret)


@functools.partial(jax.jit, static_argnames=("bq", "bk", "window", "interpret"))
def _flash_attention(q, k, v, *, bq, bk, window, interpret):
    BH, S, hd = q.shape
    BKV = k.shape[0]
    assert BH % BKV == 0
    G = BH // BKV
    bq = min(bq, S)
    bk = min(bk, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    scale = hd ** -0.5

    return pl.pallas_call(
        functools.partial(_kernel, bq=bq, bk=bk, nk=nk, scale=scale,
                          window=window),
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda h, i, j: (h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
