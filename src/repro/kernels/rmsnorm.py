"""Fused RMSNorm Pallas kernel (row-blocked, f32 statistics in-register)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._interpret import default_interpret


def _kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps)
                  * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm(x, scale, *, eps: float = 1e-6, block_rows: int = 256,
            interpret=None):
    """x: (..., D); scale: (D,).

    ``interpret=None`` resolves through ``kernels.ops.default_interpret()``:
    compiled on TPU backends, interpret mode elsewhere (resolved OUTSIDE the
    jit boundary so a REPRO_PALLAS_INTERPRET change retraces)."""
    if interpret is None:
        interpret = default_interpret()
    return _rmsnorm(x, scale, eps=eps, block_rows=block_rows,
                    interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def _rmsnorm(x, scale, *, eps, block_rows, interpret):
    shape = x.shape
    D = shape[-1]
    R = 1
    for d in shape[:-1]:
        R *= d
    x2 = x.reshape(R, D)
    br = min(block_rows, R)
    pr = (-R) % br
    if pr:
        x2 = jnp.pad(x2, ((0, pr), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(x2.shape[0] // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale)
    if pr:
        out = out[:R]
    return out.reshape(shape)
