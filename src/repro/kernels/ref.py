"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a, b, out_dtype=None):
    out = jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))
    return out.astype(out_dtype or a.dtype)


def flash_attention_ref(q, k, v, window=None):
    """q: (BH,S,hd); k,v: (BKV,S,hd). Causal softmax attention."""
    BH, S, hd = q.shape
    BKV = k.shape[0]
    G = BH // BKV
    kk = jnp.repeat(k, G, axis=0)
    vv = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * (hd ** -0.5)
    rows = jnp.arange(S)[:, None]
    cols = jnp.arange(S)[None, :]
    mask = rows >= cols
    if window is not None:
        mask &= (rows - cols) < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p, vv.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)
