"""Tiled MXU matmul — the paper's central operation as a Pallas TPU kernel.

The BlockSpec tiling (bm, bk, bn) and the accumulation policy are the
"memory mode" knobs (DESIGN.md §2): how the iteration space hashes onto the
fast near memory (VMEM) mirrors the paper's MCDRAM/NUMA configurations.

  accum="vmem"  ("cache" mode)  — fp32 accumulator lives in a VMEM scratch;
                                  each C tile is written to HBM exactly once.
  accum="hbm"   ("flat" mode)   — C (fp32) is revisited in HBM on every K
                                  step; max HBM traffic, min VMEM footprint.

Grid = (M/bm, N/bn, K/bk), K innermost (sequential on TPU, so accumulation
across K steps is well-defined).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._interpret import default_interpret


def _kernel_vmem(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _kernel_hbm(a_ref, b_ref, o_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                          preferred_element_type=jnp.float32).astype(o_ref.dtype)


def matmul(a, b, *, block=(256, 256, 256), accum="vmem", interpret=None,
           out_dtype=None):
    """C = A·B with explicit VMEM tiling.  A: (M,K), B: (K,N).

    ``interpret=None`` resolves through ``kernels.ops.default_interpret()``:
    compiled on TPU backends, interpret mode elsewhere (resolved OUTSIDE the
    jit boundary so a REPRO_PALLAS_INTERPRET change retraces)."""
    if interpret is None:
        interpret = default_interpret()
    return _matmul(a, b, block=block, accum=accum, interpret=interpret,
                   out_dtype=out_dtype)


@functools.partial(jax.jit, static_argnames=("block", "accum", "interpret",
                                             "out_dtype"))
def _matmul(a, b, *, block, accum, interpret, out_dtype):
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bk, bn = (min(block[0], M), min(block[1], K), min(block[2], N))
    pm, pk, pn = (-M) % bm, (-K) % bk, (-N) % bn
    if pm or pk or pn:  # zero-pad to tile multiples (zeros are matmul-safe)
        a = jnp.pad(a, ((0, pm), (0, pk)))
        b = jnp.pad(b, ((0, pk), (0, pn)))
    Mp, Kp = a.shape
    Np = b.shape[1]
    k_steps = Kp // bk
    grid = (Mp // bm, Np // bn, k_steps)
    out_dtype = out_dtype or a.dtype

    if accum == "vmem":
        out = pl.pallas_call(
            functools.partial(_kernel_vmem, k_steps=k_steps),
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                      pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), out_dtype),
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(a, b)
    else:  # "hbm": fp32 output revisited per K step, cast at the end
        out = pl.pallas_call(
            functools.partial(_kernel_hbm, k_steps=k_steps),
            grid=grid,
            in_specs=[pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
                      pl.BlockSpec((bk, bn), lambda i, j, k: (k, j))],
            out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            out_shape=jax.ShapeDtypeStruct((Mp, Np), jnp.float32),
            interpret=interpret,
        )(a, b).astype(out_dtype)

    if pm or pn:
        out = out[:M, :N]
    return out
