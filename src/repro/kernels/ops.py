"""Jit'd public wrappers around the Pallas kernels.

Every Pallas entry point resolves its ``interpret`` flag through
``default_interpret()``: interpret mode off-TPU (this container is CPU-only;
the kernels target TPU), compiled for real on a TPU backend, overridable via
``REPRO_PALLAS_INTERPRET`` for forcing either mode.

This module also owns the serving cache's int8 machinery: the paged KV pool
can store K/V pages as symmetric int8 with one float32 scale per pool entry
per KV head (``quantize_kv`` / ``dequantize_kv``, absmax over the head dim),
written by the fused quantize-on-write scatter (``kv_scatter_quantized``)
and read back by the fused-dequant paths of ``paged_flash_decode`` /
``ragged_paged_flash`` (dequant in VMEM right after the page DMA).
``copy_pages`` carries the scale rows along with their pages so
copy-on-write stays correct for quantized pools.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import rmsnorm as _rn
from repro.kernels._interpret import default_interpret  # noqa: F401 (public)


def matmul(a, b, *, block=(256, 256, 256), accum="vmem", out_dtype=None):
    return _mm.matmul(a, b, block=block, accum=accum,
                      interpret=default_interpret(), out_dtype=out_dtype)


def flash_attention(q, k, v, *, bq=128, bk=128, window=None):
    return _fa.flash_attention(q, k, v, bq=bq, bk=bk, window=window,
                               interpret=default_interpret())


def paged_flash_decode(q, kp, vp, ptab, lens, ks=None, vs=None):
    """Serving decode attention over a block-table-paged KV pool.
    q: (B,kvH,G,hd); kp/vp: (n_pages,page,kvH,hd) -> (B,kvH,G,hd).

    int8 pools ride with per-entry-per-head scale pools ``ks``/``vs``
    ((n_pages, page, kvH) float32): the kernel dequantizes each page tile in
    VMEM right after its DMA, inside the same online-softmax loop."""
    return _fa.paged_flash_decode(q, kp, vp, ptab, lens, ks=ks, vs=vs,
                                  interpret=default_interpret())


def ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks=None, vs=None):
    """Ragged-pack serving attention over a block-table-paged KV pool.
    q: (T,kvH,G,hd); slot/lens: (T,); kp/vp: (n_pages,page,kvH,hd)
    -> (T,kvH,G,hd).

    Prefix-shared pages need no kernel support: the kernel resolves
    token -> slot -> page through ``ptab`` per grid step, so two slots whose
    block-table rows point at the same pool page simply DMA the same tile —
    sharing and copy-on-write are entirely a host-side allocator concern.
    int8 pools pass scale pools ``ks``/``vs`` ((n_pages, page, kvH) f32);
    dequant is fused into the kernel's inner loop, so the HBM traffic per
    page is the int8 bytes plus one scale row — not a dequantized copy."""
    return _fa.ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks=ks, vs=vs,
                                  interpret=default_interpret())


# ---------------------------------------------------------------------------
# int8 KV quantization (paged serving pools)


def quantize_kv(x):
    """Symmetric int8 quantization of KV rows: one scale per (.., kvH) row.

    x: (..., kvH, hd) float rows -> (int8 rows, float32 scales (..., kvH)).
    scale = absmax(|row|)/127 over the head dim (clamped away from zero so
    all-zero rows round-trip to zeros), values round-to-nearest into
    [-127, 127].  The worst-case per-element reconstruction error is
    scale/2 = absmax/254.
    """
    xf = x.astype(jnp.float32)
    s = jnp.max(jnp.abs(xf), axis=-1) / 127.0
    s = jnp.maximum(s, 1e-12)
    q = jnp.clip(jnp.round(xf / s[..., None]), -127.0, 127.0).astype(jnp.int8)
    return q, s


def dequantize_kv(q, s, dtype=jnp.float32):
    """Inverse of ``quantize_kv``: q (..., kvH, hd) int8, s (..., kvH)."""
    return (q.astype(jnp.float32) * s[..., None].astype(jnp.float32)).astype(dtype)


def kv_scatter_quantized(pool, scales, rows, page, off):
    """Fused quantize-on-write KV scatter for int8 paged pools.

    Quantizes ``rows`` ((..., kvH, hd), any float dtype) and scatters values
    into ``pool[page, off]`` (int8) and their scales into
    ``scales[page, off]`` (f32, (n_pages, page_size, kvH)) in one traced
    program — the write-side half of the quantized-pool lifecycle (the
    read side is the fused dequant in the flash kernels).  OOB sentinel
    pages drop both writes, exactly like the unquantized scatter."""
    q, s = quantize_kv(rows)
    pool = pool.at[page, off].set(q, mode="drop")
    scales = scales.at[page, off].set(s, mode="drop")
    return pool, scales


def copy_pages(pool, src, dst, axis=None):
    """Copy-on-write page copy: ``pool[..., dst[i], :, ...] = pool[..., src[i], ...]``.

    pool: (..., n_pages, page, kvH, hd) KV pool (``axis=None`` resolves the
    page axis as ``ndim - 4``) or a (..., n_pages, page, kvH) scale pool
    (pass ``axis = ndim - 3``); an optional leading layer axis from scanned
    stages rides along in each slice.  Scale rows MUST travel with their
    pages — a COW'd int8 page dequantized against another page's scales
    would silently corrupt the copied prefix.  src/dst: (K,) int32 with a
    FIXED K (the engine pads unused pairs with the ``n_pages`` sentinel), so
    the op stays one traced program.  Implemented as K unrolled
    dynamic-slice updates rather than one batched scatter: with the pool
    donated, each update is an in-place page-sized memcpy (the same pattern
    as a KV-cache write), whereas a scatter with leading batch-dim slices
    makes XLA CPU rewrite the whole pool (~2 model steps per call when
    measured).  Sentinel pairs clamp to a self-copy of the last page — a
    byte-identical no-op."""
    ax = pool.ndim - 4 if axis is None else axis
    n = pool.shape[ax]
    for i in range(src.shape[0]):
        v = jax.lax.dynamic_index_in_dim(pool, jnp.minimum(src[i], n - 1),
                                         axis=ax, keepdims=True)
        pool = jax.lax.dynamic_update_slice_in_dim(
            pool, v, jnp.minimum(dst[i], n - 1), axis=ax)
    return pool


def _flash_grouped_local(q, k, v, window):
    """Single-shard grouped-layout kernel call.
    q: (B,S,kvH,G,hd); k,v: (B,S,kvH,hd) -> (B,S,kvH,G,hd)."""
    B, S, kvH, G, hd = q.shape
    qk = jnp.moveaxis(q, 1, 3).reshape(B * kvH * G, S, hd)
    kk = jnp.moveaxis(k, 1, 2).reshape(B * kvH, S, hd)
    vk = jnp.moveaxis(v, 1, 2).reshape(B * kvH, S, hd)
    bq = bk = max(min(128, S), 1)
    o = flash_attention(qk, kk, vk, bq=bq, bk=bk, window=window)
    return jnp.moveaxis(o.reshape(B, kvH, G, S, hd), 3, 1)


def _flash_grouped_fwd_impl(q, k, v, window):
    """Kernel forward, shard_mapped over the batch axes under a mesh (the
    kernel is a per-device program; GSPMD cannot partition a pallas_call)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import current_mesh, current_rules, shard_map

    mesh = current_mesh()
    if mesh is None:
        return _flash_grouped_local(q, k, v, window)
    batch_ax = current_rules().get("act_batch") or None
    qs = P(batch_ax, None, None, None, None)
    kvs = P(batch_ax, None, None, None)
    return shard_map(
        lambda q, k, v: _flash_grouped_local(q, k, v, window),
        mesh=mesh, in_specs=(qs, kvs, kvs), out_specs=qs)(q, k, v)


def _ref_grouped(q, k, v, window):
    """Memory-safe jnp oracle used for the backward pass (a production
    deployment adds the flash backward kernel; the dominant fwd win — causal
    block skipping — is already in the Pallas kernel)."""
    from repro.models.layers.attention import _chunked_attn

    S = q.shape[1]
    pos = jnp.arange(S)
    return _chunked_attn(q, k, v, pos, pos, True, window,
                         min(128, S) if S % min(128, S) == 0 else S)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_grouped(q, k, v, window):
    return _flash_grouped_fwd_impl(q, k, v, window)


def _flash_fwd(q, k, v, window):
    return _flash_grouped_fwd_impl(q, k, v, window), (q, k, v)


def _flash_bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref_grouped(q, k, v, window), q, k, v)
    return vjp(g)


_flash_grouped.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_grouped(q, k, v, *, window=None):
    """Differentiable grouped-layout flash attention (custom VJP).

    q: (B,S,kvH,G,hd); k,v: (B,S,kvH,hd) -> (B,S,kvH,G,hd)."""
    return _flash_grouped(q, k, v, window)


def rmsnorm(x, scale, *, eps=1e-6):
    return _rn.rmsnorm(x, scale, eps=eps, interpret=default_interpret())
