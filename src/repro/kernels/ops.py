"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True off-TPU (this container is CPU-only; the
kernels target TPU and are validated in interpret mode per the brief).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import matmul as _mm
from repro.kernels import rmsnorm as _rn


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def matmul(a, b, *, block=(256, 256, 256), accum="vmem", out_dtype=None):
    return _mm.matmul(a, b, block=block, accum=accum, interpret=_interpret(),
                      out_dtype=out_dtype)


def flash_attention(q, k, v, *, bq=128, bk=128, window=None):
    return _fa.flash_attention(q, k, v, bq=bq, bk=bk, window=window,
                               interpret=_interpret())


def paged_flash_decode(q, kp, vp, ptab, lens):
    """Serving decode attention over a block-table-paged KV pool.
    q: (B,kvH,G,hd); kp/vp: (n_pages,page,kvH,hd) -> (B,kvH,G,hd)."""
    return _fa.paged_flash_decode(q, kp, vp, ptab, lens,
                                  interpret=_interpret())


def ragged_paged_flash(q, kp, vp, ptab, slot, lens):
    """Ragged-pack serving attention over a block-table-paged KV pool.
    q: (T,kvH,G,hd); slot/lens: (T,); kp/vp: (n_pages,page,kvH,hd)
    -> (T,kvH,G,hd).

    Prefix-shared pages need no kernel support: the kernel resolves
    token -> slot -> page through ``ptab`` per grid step, so two slots whose
    block-table rows point at the same pool page simply DMA the same tile —
    sharing and copy-on-write are entirely a host-side allocator concern."""
    return _fa.ragged_paged_flash(q, kp, vp, ptab, slot, lens,
                                  interpret=_interpret())


def copy_pages(pool, src, dst):
    """Copy-on-write page copy: ``pool[..., dst[i], :, :, :] = pool[..., src[i], ...]``.

    pool: (..., n_pages, page, kvH, hd) — an optional leading layer axis from
    scanned stages rides along in each slice.  src/dst: (K,) int32 with a
    FIXED K (the engine pads unused pairs with the ``n_pages`` sentinel), so
    the op stays one traced program.  Implemented as K unrolled
    dynamic-slice updates rather than one batched scatter: with the pool
    donated, each update is an in-place page-sized memcpy (the same pattern
    as a KV-cache write), whereas a scatter with leading batch-dim slices
    makes XLA CPU rewrite the whole pool (~2 model steps per call when
    measured).  Sentinel pairs clamp to a self-copy of the last page — a
    byte-identical no-op."""
    ax = pool.ndim - 4
    n = pool.shape[ax]
    for i in range(src.shape[0]):
        v = jax.lax.dynamic_index_in_dim(pool, jnp.minimum(src[i], n - 1),
                                         axis=ax, keepdims=True)
        pool = jax.lax.dynamic_update_slice_in_dim(
            pool, v, jnp.minimum(dst[i], n - 1), axis=ax)
    return pool


def _flash_grouped_local(q, k, v, window):
    """Single-shard grouped-layout kernel call.
    q: (B,S,kvH,G,hd); k,v: (B,S,kvH,hd) -> (B,S,kvH,G,hd)."""
    B, S, kvH, G, hd = q.shape
    qk = jnp.moveaxis(q, 1, 3).reshape(B * kvH * G, S, hd)
    kk = jnp.moveaxis(k, 1, 2).reshape(B * kvH, S, hd)
    vk = jnp.moveaxis(v, 1, 2).reshape(B * kvH, S, hd)
    bq = bk = max(min(128, S), 1)
    o = flash_attention(qk, kk, vk, bq=bq, bk=bk, window=window)
    return jnp.moveaxis(o.reshape(B, kvH, G, S, hd), 3, 1)


def _flash_grouped_fwd_impl(q, k, v, window):
    """Kernel forward, shard_mapped over the batch axes under a mesh (the
    kernel is a per-device program; GSPMD cannot partition a pallas_call)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import current_mesh, current_rules

    mesh = current_mesh()
    if mesh is None:
        return _flash_grouped_local(q, k, v, window)
    batch_ax = current_rules().get("act_batch") or None
    qs = P(batch_ax, None, None, None, None)
    kvs = P(batch_ax, None, None, None)
    return jax.shard_map(
        lambda q, k, v: _flash_grouped_local(q, k, v, window),
        mesh=mesh, in_specs=(qs, kvs, kvs), out_specs=qs,
        check_vma=False)(q, k, v)


def _ref_grouped(q, k, v, window):
    """Memory-safe jnp oracle used for the backward pass (a production
    deployment adds the flash backward kernel; the dominant fwd win — causal
    block skipping — is already in the Pallas kernel)."""
    from repro.models.layers.attention import _chunked_attn

    S = q.shape[1]
    pos = jnp.arange(S)
    return _chunked_attn(q, k, v, pos, pos, True, window,
                         min(128, S) if S % min(128, S) == 0 else S)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _flash_grouped(q, k, v, window):
    return _flash_grouped_fwd_impl(q, k, v, window)


def _flash_fwd(q, k, v, window):
    return _flash_grouped_fwd_impl(q, k, v, window), (q, k, v)


def _flash_bwd(window, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q, k, v: _ref_grouped(q, k, v, window), q, k, v)
    return vjp(g)


_flash_grouped.defvjp(_flash_fwd, _flash_bwd)


def flash_attention_grouped(q, k, v, *, window=None):
    """Differentiable grouped-layout flash attention (custom VJP).

    q: (B,S,kvH,G,hd); k,v: (B,S,kvH,hd) -> (B,S,kvH,G,hd)."""
    return _flash_grouped(q, k, v, window)


def rmsnorm(x, scale, *, eps=1e-6):
    return _rn.rmsnorm(x, scale, eps=eps, interpret=_interpret())
