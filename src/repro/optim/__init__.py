from repro.optim.adamw import AdamWCfg, init_opt_state, apply_updates  # noqa: F401
from repro.optim.schedules import warmup_cosine  # noqa: F401
