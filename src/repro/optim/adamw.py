"""AdamW with optional int8-quantized moment storage (for ≥100B archs).

Plain-pytree optimizer (no optax dependency).  State layout:
  {"m": tree, "v": tree, "step": scalar}
where each leaf of m/v is either an fp32 array or {"q": int8, "qscale": f32}.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.quantized_state import dequantize, is_quantized, quantize


@dataclass(frozen=True)
class AdamWCfg:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    state_dtype: str = "float32"  # "float32" | "int8"


def _zeros_like_state(p, cfg: AdamWCfg):
    z = jnp.zeros(p.shape, jnp.float32)
    if cfg.state_dtype == "int8":
        return quantize(z)
    return z


def init_opt_state(params, cfg: AdamWCfg):
    return {
        "m": jax.tree.map(lambda p: _zeros_like_state(p, cfg), params),
        "v": jax.tree.map(lambda p: _zeros_like_state(p, cfg), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), gn


def apply_updates(params, grads, state, cfg: AdamWCfg, lr):
    """Returns (new_params, new_state, metrics)."""
    metrics = {}
    if cfg.grad_clip is not None:
        grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
        metrics["grad_norm"] = gn
    step = state["step"] + 1
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        mf = dequantize(m) if is_quantized(m) else m
        vf = dequantize(v) if is_quantized(v) else v
        mf = cfg.b1 * mf + (1 - cfg.b1) * gf
        vf = cfg.b2 * vf + (1 - cfg.b2) * gf * gf
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (u + cfg.weight_decay * pf)
        new_p = pf.astype(p.dtype)
        new_m = quantize(mf) if is_quantized(m) else mf
        new_v = quantize(vf) if is_quantized(v) else vf
        return new_p, new_m, new_v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
