"""Error-feedback int8 gradient compression for data-parallel reduction.

``compressed_psum`` replaces the f32 gradient all-reduce on the 'data' axis
with: rowwise-absmax int8 quantization -> int8 all-gather -> local dequant
sum.  Wire bytes: ~N/4 × (world)/(ring 2×) vs fp32 all-reduce.  Quantization
error is carried in an error-feedback residual (``EFState``) added back
before the next step's compression, which restores convergence (tested in
tests/test_compress.py).

Composition note (DESIGN.md): this applies to the pure-DP regime (params
replicated over 'data'); with FSDP the reduction is a reduce-scatter fused
by GSPMD and compression there is future work — the same trade the original
DP-compression literature makes.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import shard_map


def _quant(x):
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum_local(x, axis_name):
    """Inside shard_map: int8-compressed mean over ``axis_name``."""
    q, s = _quant(x)
    qs = jax.lax.all_gather(q, axis_name)  # (W, ...) int8 — wire = N/4
    ss = jax.lax.all_gather(s, axis_name)
    total = jnp.sum(_dequant(qs, ss), axis=0)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    err = x - _dequant(q, s)  # local error feedback
    return total / n, err


class EFState(NamedTuple):
    residual: jax.Array


def ef_init(x):
    return EFState(jnp.zeros_like(x, dtype=jnp.float32))


def ef_compressed_mean(x, ef: EFState, axis_name):
    """Error-feedback compressed mean: compress (x + residual), keep the
    quantization error as the next residual."""
    xc = x.astype(jnp.float32) + ef.residual
    mean, err = compressed_psum_local(xc, axis_name)
    return mean.astype(x.dtype), EFState(err)


def ef_init_tree(params, world: int):
    """Per-shard residuals: leading axis = DP world size, sharded over it."""
    return jax.tree.map(
        lambda p: jnp.zeros((world,) + p.shape, jnp.float32), params)


def make_ddp_value_and_grad(loss_fn, mesh, axis: str = "data"):
    """DDP gradient step with int8 error-feedback compressed reduction.

    Returns ``fn(params, ef, batch) -> (loss, grads, new_ef)`` where params
    are replicated, batch is sharded over ``axis``, and ef leaves carry a
    leading world-size dim sharded over ``axis`` (per-shard residuals).
    """
    def fn(params, ef, batch):
        leaves, treedef = jax.tree.flatten(params)
        n = len(leaves)

        def local(params, batch, *ef_leaves):
            loss, g = jax.value_and_grad(loss_fn)(params, batch)
            g_leaves = treedef.flatten_up_to(g)
            means, news = [], []
            for gl, el in zip(g_leaves, ef_leaves):
                m, ne = ef_compressed_mean(gl, EFState(el[0]), axis)
                means.append(m)
                news.append(ne.residual[None])
            loss = jax.lax.pmean(loss, axis)
            return (loss, *means, *news)

        out = shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(axis)) + (P(axis),) * n,
            out_specs=(P(),) + (P(),) * n + (P(axis),) * n,
        )(params, batch, *treedef.flatten_up_to(ef))
        loss = out[0]
        grads = treedef.unflatten(list(out[1 : 1 + n]))
        new_ef = treedef.unflatten(list(out[1 + n :]))
        return loss, grads, new_ef

    return fn
