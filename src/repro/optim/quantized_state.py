"""Int8 block-quantized optimizer-state storage.

The ≥398B assigned archs cannot hold fp32 Adam moments in a 4 TB/pod HBM
budget (480e9 × 8 B = 3.8 TB for the moments alone).  Moments are stored as
int8 with an fp32 scale per last-axis row (absmax scaling), dequantized to
fp32 inside the (jit-fused) update, and requantized — a standard 8-bit-Adam
construction.
"""
from __future__ import annotations

import jax.numpy as jnp


def quantize(x):
    """x: fp32 -> {"q": int8, "qscale": fp32 rowwise}."""
    absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    scale = jnp.where(absmax > 0, absmax / 127.0, 1.0)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return {"q": q, "qscale": scale.astype(jnp.float32)}


def dequantize(qs):
    return qs["q"].astype(jnp.float32) * qs["qscale"]


def is_quantized(leaf) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "qscale"}
