"""Block / stage composition with lax.scan over repeated layer groups.

A ``Stage`` with ``repeats > 1`` stacks each pattern-position's params along a
leading "layer" axis and scans, keeping HLO size O(|pattern|) regardless of
depth — required for compiling 72-layer models for 512 fake devices on CPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import BlockCfg, ModelCfg, Stage
from repro.parallel.sharding import constrain_like_params
from repro.models.layers import attention as attn
from repro.models.layers import mamba as mamba_lib
from repro.models.layers import xlstm as xlstm_lib
from repro.models.layers.mlp import init_mlp, mlp_fwd
from repro.models.layers.moe import init_moe, moe_fwd
from repro.models.layers.norms import init_rmsnorm, rmsnorm

ZERO_AUX = {"moe_lb_loss": jnp.zeros((), jnp.float32),
            "moe_z_loss": jnp.zeros((), jnp.float32)}


def _add_aux(a, b):
    return {k: a[k] + b[k] for k in a}


# ---------------------------------------------------------------------------
# Single block


def init_block(key, cfg: ModelCfg, blk: BlockCfg):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p = {"mixer_norm": init_rmsnorm(d)}
    if blk.mixer in ("attn", "cross_attn"):
        p["mixer"] = attn.init_attention(ks[0], d, blk.attn)
    elif blk.mixer == "mamba":
        p["mixer"] = mamba_lib.init_mamba(ks[0], d, blk.mamba)
    elif blk.mixer == "mlstm":
        p["mixer"] = xlstm_lib.init_mlstm(ks[0], d, blk.xlstm)
    elif blk.mixer == "slstm":
        p["mixer"] = xlstm_lib.init_slstm(ks[0], d, blk.xlstm)
    else:
        raise ValueError(f"unknown mixer {blk.mixer}")
    if blk.ffn == "mlp":
        p["ffn_norm"] = init_rmsnorm(d)
        p["ffn"] = init_mlp(ks[1], d, blk.mlp)
    elif blk.ffn == "moe":
        p["ffn_norm"] = init_rmsnorm(d)
        p["ffn"] = init_moe(ks[1], d, blk.moe)
    return p


def block_fwd(params, cfg: ModelCfg, blk: BlockCfg, x, *, positions=None, enc=None):
    """Returns (x, aux) — aux always has ZERO_AUX structure (scan-uniform)."""
    aux = dict(ZERO_AUX)
    h = rmsnorm(params["mixer_norm"], x, cfg.norm_eps)
    if blk.mixer == "attn":
        m = attn.attention_fwd(params["mixer"], blk.attn, h, positions=positions,
                               q_chunk=cfg.attn_q_chunk, use_flash=cfg.use_flash)
    elif blk.mixer == "cross_attn":
        m = attn.attention_fwd(params["mixer"], blk.attn, h, enc=enc,
                               q_chunk=cfg.attn_q_chunk)
    elif blk.mixer == "mamba":
        m = mamba_lib.mamba_fwd(params["mixer"], blk.mamba, h)
    elif blk.mixer == "mlstm":
        m = xlstm_lib.mlstm_fwd(params["mixer"], blk.xlstm, h)
    else:
        m = xlstm_lib.slstm_fwd(params["mixer"], blk.xlstm, h)
    x = x + m
    if blk.ffn is not None:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        if blk.ffn == "mlp":
            f = mlp_fwd(params["ffn"], blk.mlp, h)
        else:
            f, moe_aux = moe_fwd(params["ffn"], blk.moe, h)
            aux = _add_aux(aux, moe_aux)
        x = x + f
    return x, aux


# ---------------------------------------------------------------------------
# Stages (scan over repeats)


def init_stage(key, cfg: ModelCfg, stage: Stage):
    reps = []
    for r in range(stage.repeats):
        kr = jax.random.fold_in(key, r)
        reps.append([init_block(jax.random.fold_in(kr, i), cfg, blk)
                     for i, blk in enumerate(stage.pattern)])
    if stage.repeats == 1:
        return reps[0]
    return [jax.tree.map(lambda *xs: jnp.stack(xs), *[reps[r][i] for r in range(stage.repeats)])
            for i in range(len(stage.pattern))]


@jax.custom_jvp
def _barrier(xs):
    """``lax.optimization_barrier`` with a differentiation rule.

    The barrier serializes FSDP param gathers block-by-block (see
    ``stage_fwd.group``), but jax defines no JVP for the primitive, which
    made every remat'd-scan train step non-differentiable (the seed-era
    xfail group).  The barrier is semantically the identity, so the custom
    JVP keeps the scheduling fence on the PRIMAL path and passes tangents
    straight through; the tangent map is the identity, so transposition
    (grad) is exact and the fence never constrains the backward schedule —
    the xs-grad accumulators already serialize along the scan carry."""
    return jax.lax.optimization_barrier(xs)


@_barrier.defjvp
def _barrier_jvp(primals, tangents):
    (xs,), (dxs,) = primals, tangents
    return _barrier(xs), dxs


def _remat(fn, mode: str):
    if mode == "none":
        return fn
    if mode == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
    else:
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol, prevent_cse=False)


def stage_fwd(params, cfg: ModelCfg, stage: Stage, x, *, positions=None, enc=None):
    from repro.parallel.sharding import lshard

    def one_block(block_params, blk, x):
        x, a = block_fwd(block_params, cfg, blk, x, positions=positions, enc=enc)
        if (cfg.remat != "none" and cfg.seq_shard_residuals
                and x.shape[1] > 1):
            # seq-shard the saved boundary over 'model' (Megatron-SP style):
            # stored residuals must not be replicated across the TP axis
            x = lshard(x, "act_batch", "act_res_seq", None)
        return x, a

    def group(x, group_params):
        # Constrain params at USE, inside the scan body: GSPMD does not
        # propagate outer constraints into while-loop bodies, so without
        # this both the per-layer param gathers (forward) and the xs-grad
        # accumulators (backward) end up replicated across mesh axes
        # (measured: +100 GiB/dev on jamba-398b).
        group_params = constrain_like_params(group_params)
        # nested remat: the group is checkpointed (scan stores only group
        # boundaries) and each block inside is checkpointed again (the
        # recomputed forward stores only block boundaries; block internals
        # are rematerialized one block at a time during backward)
        aux = dict(ZERO_AUX)
        for i, blk in enumerate(stage.pattern):
            if i > 0:
                # serialize FSDP param gathers block-by-block: without the
                # barrier the scheduler gathers the whole group's params up
                # front (~10 GiB/dev live at jamba scale)
                x, p_i = _barrier((x, group_params[i]))
            else:
                p_i = group_params[i]
            blk_fn = _remat(lambda p, y, b=blk: one_block(p, b, y), cfg.remat)
            x, a = blk_fn(p_i, x)
            aux = _add_aux(aux, a)
        return x, aux

    group = _remat(group, cfg.remat)

    if stage.repeats == 1:
        return group(x, params)

    def body(carry, group_params):
        x, aux = carry
        x, a = group(x, group_params)
        return (x, _add_aux(aux, a)), None

    (x, aux), _ = jax.lax.scan(body, (x, dict(ZERO_AUX)), tuple(params))
    return x, aux


# ---------------------------------------------------------------------------
# Decode (single-token step with per-layer cache/state)


def init_block_state(params, cfg: ModelCfg, blk: BlockCfg, batch: int,
                     cache_len: int, dtype, enc=None):
    if blk.mixer == "attn":
        return attn.init_cache(blk.attn, batch, cache_len, dtype)
    if blk.mixer == "cross_attn":
        return attn.init_cross_cache(params["mixer"], blk.attn, enc)
    if blk.mixer == "mamba":
        return mamba_lib.init_mamba_state(blk.mamba, cfg.d_model, batch, dtype)
    if blk.mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(blk.xlstm, cfg.d_model, batch, dtype)
    return xlstm_lib.init_slstm_state(blk.xlstm, cfg.d_model, batch, dtype)


def block_decode(params, cfg: ModelCfg, blk: BlockCfg, x, state, *,
                 sp_decode: bool = False):
    h = rmsnorm(params["mixer_norm"], x, cfg.norm_eps)
    if blk.mixer in ("attn", "cross_attn"):
        m, state = attn.attention_decode(params["mixer"], blk.attn, h, state,
                                         sp_decode=sp_decode and blk.mixer == "attn")
    elif blk.mixer == "mamba":
        m, state = mamba_lib.mamba_decode(params["mixer"], blk.mamba, h, state)
    elif blk.mixer == "mlstm":
        m, state = xlstm_lib.mlstm_decode(params["mixer"], blk.xlstm, h, state)
    else:
        m, state = xlstm_lib.slstm_decode(params["mixer"], blk.xlstm, h, state)
    x = x + m
    if blk.ffn is not None:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        if blk.ffn == "mlp":
            f = mlp_fwd(params["ffn"], blk.mlp, h)
        else:
            f, _ = moe_fwd(params["ffn"], blk.moe, h)
        x = x + f
    return x, state


def init_stage_state(params, cfg: ModelCfg, stage: Stage, batch: int,
                     cache_len: int, dtype, enc=None):
    if stage.repeats == 1:
        return [init_block_state(params[i], cfg, blk, batch, cache_len, dtype, enc)
                for i, blk in enumerate(stage.pattern)]
    out = []
    for i, blk in enumerate(stage.pattern):
        if blk.mixer == "cross_attn":
            # enc projections differ per repeat: vmap over stacked params
            out.append(jax.vmap(
                lambda p: attn.init_cross_cache(p["mixer"], blk.attn, enc))(params[i]))
            continue
        one_params = jax.tree.map(lambda x: x[0], params[i])
        one = init_block_state(one_params, cfg, blk, batch, cache_len, dtype, enc)
        out.append(jax.tree.map(
            lambda x: jnp.broadcast_to(x, (stage.repeats,) + x.shape).copy(), one))
    return out


# ---------------------------------------------------------------------------
# Paged serving step (per-slot positions; C >= 1 tokens per slot per call)


def init_block_state_paged(params, cfg: ModelCfg, blk: BlockCfg, batch: int,
                           cache_len: int, dtype, *, page_size: int,
                           n_pages: int, window_extra: int = 0,
                           kv_dtype=None):
    if blk.mixer == "attn":
        return attn.init_paged_cache(blk.attn, batch, cache_len, dtype,
                                     page_size=page_size, n_pages=n_pages,
                                     window_extra=window_extra,
                                     kv_dtype=kv_dtype)
    if blk.mixer == "cross_attn":
        raise NotImplementedError("paged serving covers token models only")
    if blk.mixer == "mamba":
        return mamba_lib.init_mamba_state(blk.mamba, cfg.d_model, batch, dtype)
    if blk.mixer == "mlstm":
        return xlstm_lib.init_mlstm_state(blk.xlstm, cfg.d_model, batch, dtype)
    return xlstm_lib.init_slstm_state(blk.xlstm, cfg.d_model, batch, dtype)


def init_stage_state_paged(params, cfg: ModelCfg, stage: Stage, batch: int,
                           cache_len: int, dtype, *, page_size: int,
                           n_pages: int, window_extra: int = 0,
                           kv_dtype=None):
    mk = lambda: [init_block_state_paged(None, cfg, blk, batch, cache_len,
                                         dtype, page_size=page_size,
                                         n_pages=n_pages,
                                         window_extra=window_extra,
                                         kv_dtype=kv_dtype)
                  for blk in stage.pattern]
    if stage.repeats == 1:
        return mk()
    one = mk()
    return [jax.tree.map(
        lambda x: jnp.broadcast_to(x, (stage.repeats,) + x.shape).copy(), s)
        for s in one]


def _masked_recurrent_roll(dec, p, c, h, s, valid):
    """Scan a single-step recurrent decode over the C chunk positions,
    advancing state only where ``valid`` — pad tails and idle slots keep
    their state bit-identical.  h: (B,C,D), valid: (B,C)."""

    def step(s, inp):
        h_t, v_t = inp
        y, s_new = dec(p, c, h_t[:, None, :], s)
        s = jax.tree.map(
            lambda a, b: jnp.where(
                v_t.reshape((-1,) + (1,) * (a.ndim - 1)), a, b), s_new, s)
        return s, y[:, 0]

    s, ys = jax.lax.scan(
        step, s, (jnp.moveaxis(h, 1, 0), jnp.moveaxis(valid, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), s


def block_step_paged(params, cfg: ModelCfg, blk: BlockCfg, x, state, q_pos,
                     valid, *, flash_decode: bool = False):
    h = rmsnorm(params["mixer_norm"], x, cfg.norm_eps)
    if blk.mixer == "attn":
        m, state = attn.paged_attention_step(params["mixer"], blk.attn, h,
                                             state, q_pos, valid,
                                             flash_decode=flash_decode)
    elif blk.mixer == "mamba":
        m, state = _masked_recurrent_roll(
            mamba_lib.mamba_decode, params["mixer"], blk.mamba, h, state, valid)
    elif blk.mixer == "mlstm":
        m, state = _masked_recurrent_roll(
            xlstm_lib.mlstm_decode, params["mixer"], blk.xlstm, h, state, valid)
    elif blk.mixer == "slstm":
        m, state = _masked_recurrent_roll(
            xlstm_lib.slstm_decode, params["mixer"], blk.xlstm, h, state, valid)
    else:
        raise NotImplementedError(f"paged serving: unsupported mixer {blk.mixer}")
    x = x + m
    if blk.ffn is not None:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        if blk.ffn == "mlp":
            f = mlp_fwd(params["ffn"], blk.mlp, h)
        else:
            f, _ = moe_fwd(params["ffn"], blk.moe, h)
        x = x + f
    return x, state


def stage_step_paged(params, cfg: ModelCfg, stage: Stage, x, states, q_pos,
                     valid, *, flash_decode: bool = False):
    if stage.repeats == 1:
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_step_paged(params[i], cfg, blk, x, states[i], q_pos,
                                    valid, flash_decode=flash_decode)
            new_states.append(s)
        return x, new_states

    def body(x, xs):
        group_params, group_states = xs
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_step_paged(group_params[i], cfg, blk, x,
                                    group_states[i], q_pos, valid,
                                    flash_decode=flash_decode)
            new_states.append(s)
        return x, tuple(new_states)

    x, new_states = jax.lax.scan(body, x, (tuple(params), tuple(states)))
    return x, list(new_states)


def _ragged_recurrent_roll(dec, p, c, h, s, slot, seq_idx, valid, width: int):
    """Ragged pack -> per-slot dense -> masked roll -> scatter back.

    Recurrent mixers must consume a slot's tokens in position order, but the
    pack interleaves slots.  The scheduler guarantees (a) at most ``width``
    tokens per slot per pack and (b) in-pack position order, so a scatter by
    (slot, intra-slot ordinal) into a dense (B, width) layout makes the
    existing masked roll apply unchanged; outputs gather back by the same
    indices.  h: (1,T,D); slot/seq_idx/valid: (T,).
    """
    B = next(iter(jax.tree.leaves(s))).shape[0]
    h0 = h[0]  # (T,D)
    col = jnp.where(valid, seq_idx, width)
    dense = jnp.zeros((B, width, h0.shape[-1]), h0.dtype)
    dense = dense.at[slot, col].set(h0, mode="drop")
    vdense = jnp.zeros((B, width), bool).at[slot, col].set(valid, mode="drop")
    y_dense, s = _masked_recurrent_roll(dec, p, c, dense, s, vdense)
    y = y_dense[slot, jnp.minimum(col, width - 1)]  # (T,D); invalid rows junk
    return y[None], s


def block_step_ragged(params, cfg: ModelCfg, blk: BlockCfg, x, state, slot,
                      q_pos, seq_idx, valid, *, width: int,
                      flash_decode: bool = False):
    h = rmsnorm(params["mixer_norm"], x, cfg.norm_eps)
    if blk.mixer == "attn":
        m, state = attn.ragged_attention_step(params["mixer"], blk.attn, h,
                                              state, slot, q_pos, valid,
                                              flash_decode=flash_decode)
    elif blk.mixer == "mamba":
        m, state = _ragged_recurrent_roll(
            mamba_lib.mamba_decode, params["mixer"], blk.mamba, h, state,
            slot, seq_idx, valid, width)
    elif blk.mixer == "mlstm":
        m, state = _ragged_recurrent_roll(
            xlstm_lib.mlstm_decode, params["mixer"], blk.xlstm, h, state,
            slot, seq_idx, valid, width)
    elif blk.mixer == "slstm":
        m, state = _ragged_recurrent_roll(
            xlstm_lib.slstm_decode, params["mixer"], blk.xlstm, h, state,
            slot, seq_idx, valid, width)
    else:
        raise NotImplementedError(f"ragged serving: unsupported mixer {blk.mixer}")
    x = x + m
    if blk.ffn is not None:
        h = rmsnorm(params["ffn_norm"], x, cfg.norm_eps)
        if blk.ffn == "mlp":
            f = mlp_fwd(params["ffn"], blk.mlp, h)
        else:
            f, _ = moe_fwd(params["ffn"], blk.moe, h)
        x = x + f
    return x, state


def stage_step_ragged(params, cfg: ModelCfg, stage: Stage, x, states, slot,
                      q_pos, seq_idx, valid, *, width: int,
                      flash_decode: bool = False):
    if stage.repeats == 1:
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_step_ragged(params[i], cfg, blk, x, states[i], slot,
                                     q_pos, seq_idx, valid, width=width,
                                     flash_decode=flash_decode)
            new_states.append(s)
        return x, new_states

    def body(x, xs):
        group_params, group_states = xs
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_step_ragged(group_params[i], cfg, blk, x,
                                     group_states[i], slot, q_pos, seq_idx,
                                     valid, width=width,
                                     flash_decode=flash_decode)
            new_states.append(s)
        return x, tuple(new_states)

    x, new_states = jax.lax.scan(body, x, (tuple(params), tuple(states)))
    return x, list(new_states)


def reset_stage_slots(stage: Stage, states, init_states, mask, ptab_rows,
                      prefix_len):
    """Reset per-slot rows (admission): install ``ptab_rows`` into block
    tables, restore every other per-row leaf from the fresh-init template.
    KV pools are shared across slots and left alone — stale pages are dead
    via kpos/ptab, and pages owned by the prefix cache must survive slot
    churn.  ``prefix_len`` (B,) is the number of leading tokens whose KV the
    slot inherits from shared prefix pages already present in the pool: those
    positions get live ``kpos`` (0..prefix_len-1 at their natural cache
    index, which for paged layers is the absolute position) and ``slen``
    starts at ``prefix_len``, so attention sees the reused prefix without a
    single prefill token being recomputed.  A zero prefix_len reproduces the
    old cold-slot reset exactly.  mask: (B,), ptab_rows: (B, pages_per_slot),
    prefix_len: (B,) int32."""
    lead = 1 if stage.repeats > 1 else 0
    out = []
    for s_blk, i_blk in zip(states, init_states):
        new = {}
        for name, leaf in s_blk.items():
            # shared pool leaves survive slot churn: KV pages AND their
            # int8 scale rows (a reset must never zero scales a prefix-
            # cached page still dequantizes against)
            if name in ("kp", "vp", "ks", "vs"):
                new[name] = leaf
                continue
            m = mask.reshape((1,) * lead + (-1,) + (1,) * (leaf.ndim - lead - 1))
            if name == "kpos":
                iota = jnp.arange(leaf.shape[-1], dtype=jnp.int32)[None, :]
                src = jnp.where(iota < prefix_len[:, None], iota, -1)
            elif name == "slen":
                src = prefix_len.astype(leaf.dtype)
            elif name == "ptab":
                src = ptab_rows
            else:
                src = i_blk[name]
            new[name] = jnp.where(m, src, leaf)
        out.append(new)
    return out


def rollback_stage_slots(stage: Stage, states, mask, new_len):
    """Speculative rejection: for masked slots, kill the position metadata
    of every KV row written past ``new_len`` — ``kpos`` entries holding a
    value >= new_len drop to -1 (unwritten) and ``slen`` clamps down.  KV
    pools, scale pools, block tables and recurrent/windowed state all pass
    through untouched: rejected draft bytes stay in their pages, dead via
    kpos, until the next tick's scatter overwrites them.  The value-based
    test (rather than cache-index iota) works because ``kpos`` stores
    absolute positions wherever they land.  mask: (B,), new_len: (B,)."""
    lead = 1 if stage.repeats > 1 else 0
    out = []
    for s_blk in states:
        new = {}
        for name, leaf in s_blk.items():
            if name == "kpos":
                m = mask.reshape((1,) * lead + (-1,) + (1,) * (leaf.ndim - lead - 1))
                nl = new_len[:, None]
                new[name] = jnp.where(m & (leaf >= nl), -1, leaf)
            elif name == "slen":
                m = mask.reshape((1,) * lead + (-1,) + (1,) * (leaf.ndim - lead - 1))
                nl = new_len.astype(leaf.dtype)
                new[name] = jnp.where(m, jnp.minimum(leaf, nl), leaf)
            else:
                new[name] = leaf
        out.append(new)
    return out


def stage_decode(params, cfg: ModelCfg, stage: Stage, x, states, *,
                 sp_decode: bool = False):
    if stage.repeats == 1:
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_decode(params[i], cfg, blk, x, states[i], sp_decode=sp_decode)
            new_states.append(s)
        return x, new_states

    def body(x, xs):
        group_params, group_states = xs
        new_states = []
        for i, blk in enumerate(stage.pattern):
            x, s = block_decode(group_params[i], cfg, blk, x, group_states[i],
                                sp_decode=sp_decode)
            new_states.append(s)
        return x, tuple(new_states)

    x, new_states = jax.lax.scan(body, x, (tuple(params), tuple(states)))
    return x, list(new_states)
