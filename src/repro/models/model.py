"""Model entry points: init / forward / loss / prefill / decode.

All functions are pure; params and decode state are plain pytrees.  The
vision and audio frontends are stubs — inputs arrive as precomputed patch /
frame embeddings (see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg
from repro.models import transformer as tfm
from repro.models.layers import embeddings as emb
from repro.models.layers.norms import init_rmsnorm, rmsnorm
from repro.parallel.sharding import lshard

MOE_LB_WEIGHT = 0.01
MOE_Z_WEIGHT = 1e-3


# ---------------------------------------------------------------------------
# Init


def init_params(key, cfg: ModelCfg) -> Dict:
    ks = jax.random.split(key, 4 + len(cfg.stages))
    p: Dict = {}
    if cfg.frontend == "audio":
        # stub frontend: project precomputed frame features (feat dim = d/2)
        p["frontend"] = emb.init_frontend(ks[0], cfg.d_model // 2, cfg.d_model)
    else:
        p["embed"] = emb.init_tok_embed(ks[0], cfg.vocab_size, cfg.d_model)
    if cfg.frontend == "vision":
        p["frontend"] = emb.init_frontend(ks[1], cfg.d_model // 2, cfg.d_model)
    p["stages"] = [tfm.init_stage(ks[3 + i], cfg, st) for i, st in enumerate(cfg.stages)]
    p["final_norm"] = init_rmsnorm(cfg.d_model)
    if not cfg.tie_embeddings or cfg.frontend == "audio":
        p["head"] = emb.init_out_head(ks[2], cfg.d_model, cfg.vocab_size)
    dt = jnp.dtype(cfg.param_dtype)
    if dt != jnp.float32:
        p = jax.tree.map(lambda x: x.astype(dt) if x.dtype == jnp.float32 else x, p)
    return p


# ---------------------------------------------------------------------------
# Forward / loss


def _embed_inputs(params, cfg: ModelCfg, batch):
    dt = jnp.dtype(cfg.dtype)
    if cfg.frontend == "audio":
        x = emb.apply_frontend(params["frontend"], batch["feats"], dt)
    else:
        x = emb.embed_tokens(params["embed"], batch["tokens"], dt)
    if cfg.abs_pos == "sinusoidal":
        x = x + emb.sinusoidal_pos(x.shape[1], cfg.d_model, dt)
    enc = None
    if cfg.frontend == "vision":
        enc = emb.apply_frontend(params["frontend"], batch["img_feats"], dt)
        enc = lshard(enc, "act_batch", None, None)
    return lshard(x, "act_batch", "act_seq", None), enc


def forward(params, cfg: ModelCfg, batch) -> Tuple[jax.Array, Dict]:
    """-> (logits (B,S,V) vocab-sharded, aux dict)."""
    x, enc = _embed_inputs(params, cfg, batch)
    S = x.shape[1]
    positions = jnp.arange(S)
    aux = dict(tfm.ZERO_AUX)
    for st, sp in zip(cfg.stages, params["stages"]):
        x, a = tfm.stage_fwd(sp, cfg, st, x, positions=positions, enc=enc)
        aux = tfm._add_aux(aux, a)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    tied = params["embed"]["tok_embed"] if (cfg.tie_embeddings and "embed" in params) else None
    logits = emb.logits_from_hidden(params.get("head", {}), x, tied_embed=tied)
    return logits, aux


def _xent(logits, labels):
    """CE over vocab-sharded logits without gathering the vocab axis.

    logits: (B,S,V) sharded P(batch, None, 'model'); labels: (B,S) int32.
    """
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    V = logits.shape[-1]
    hit = jnp.equal(labels[..., None], jax.lax.broadcasted_iota(jnp.int32, lf.shape, 2))
    tgt = jnp.sum(jnp.where(hit, lf, 0.0), axis=-1)
    return lse - tgt  # (B,S)


def loss_fn(params, cfg: ModelCfg, batch) -> Tuple[jax.Array, Dict]:
    logits, aux = forward(params, cfg, batch)
    per_tok = _xent(logits, batch["labels"])
    if "loss_mask" in batch:
        mask = batch["loss_mask"].astype(jnp.float32)
        loss = jnp.sum(per_tok * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(per_tok)
    total = (loss + MOE_LB_WEIGHT * aux["moe_lb_loss"]
             + MOE_Z_WEIGHT * aux["moe_z_loss"])
    metrics = {"ce_loss": loss, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# Decode


def init_decode_state(params, cfg: ModelCfg, batch: int, cache_len: int,
                      enc_feats=None) -> Dict:
    """Fresh per-layer caches/states for autoregressive decoding."""
    dt = jnp.dtype(cfg.dtype)
    enc = None
    if cfg.frontend == "vision":
        enc = emb.apply_frontend(params["frontend"], enc_feats, dt)
    states = [tfm.init_stage_state(sp, cfg, st, batch, cache_len, dt, enc)
              for st, sp in zip(cfg.stages, params["stages"])]
    return {"layers": states, "pos": jnp.zeros((), jnp.int32)}


def decode_step(params, cfg: ModelCfg, state, tokens_t, *,
                sp_decode: bool = False) -> Tuple[jax.Array, Dict]:
    """tokens_t: (B,1) int32 -> (logits (B,1,V), new state)."""
    dt = jnp.dtype(cfg.dtype)
    x = emb.embed_tokens(params["embed"], tokens_t, dt)
    if cfg.abs_pos == "sinusoidal":
        x = x + emb.sinusoidal_pos(1, cfg.d_model, dt, offset=state["pos"])
    new_layers = []
    for st, sp, ss in zip(cfg.stages, params["stages"], state["layers"]):
        x, ns = tfm.stage_decode(sp, cfg, st, x, ss, sp_decode=sp_decode)
        new_layers.append(ns)
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    tied = params["embed"]["tok_embed"] if cfg.tie_embeddings else None
    logits = emb.logits_from_hidden(params.get("head", {}), x, tied_embed=tied)
    return logits, {"layers": new_layers, "pos": state["pos"] + 1}


# ---------------------------------------------------------------------------
# Paged serving (per-slot positions; chunked prefill and decode share one step)


def init_paged_state(params, cfg: ModelCfg, batch: int, cache_len: int, *,
                     page_size: int, n_pages: int, window_extra: int = 0,
                     kv_dtype=None) -> Dict:
    """Decode state for the paged serving engine: global-attention layers get
    block-table-indexed KV pools (``n_pages`` pages of ``page_size``),
    windowed layers per-slot circular buffers, recurrent mixers per-row
    states.  Every slot tracks its own position — no lock-step ``pos``.

    ``kv_dtype`` (None | "float32" | "bfloat16" | "int8") selects the paged
    pools' storage representation; int8 pools carry per-entry-per-head
    scale pools (see ``attention.init_paged_cache``).

    ``window_extra`` must be ``prefill_chunk - 1`` when chunked prefill is
    used (see ``attention.init_paged_cache``)."""
    if cfg.frontend is not None or cfg.is_encoder:
        raise NotImplementedError("paged serving covers decoder token models")
    dt = jnp.dtype(cfg.dtype)
    states = [tfm.init_stage_state_paged(sp, cfg, st, batch, cache_len, dt,
                                         page_size=page_size, n_pages=n_pages,
                                         window_extra=window_extra,
                                         kv_dtype=kv_dtype)
              for st, sp in zip(cfg.stages, params["stages"])]
    return {"layers": states}


def paged_step(params, cfg: ModelCfg, state, tokens, q_pos, valid, *,
               with_logits: bool = True, flash_decode: bool = False):
    """One serving step: C tokens per slot at per-slot absolute positions.

    tokens/q_pos/valid: (B, C).  C == 1 is a decode tick (returns logits);
    C > 1 is a prefill chunk (``with_logits=False`` skips the LM head — the
    engine only samples from decode ticks).  Invalid entries write nothing
    and leave recurrent state untouched, so idle slots ride along for free.
    """
    dt = jnp.dtype(cfg.dtype)
    x = emb.embed_tokens(params["embed"], tokens, dt)
    if cfg.abs_pos == "sinusoidal":
        x = x + emb.sinusoidal_at(q_pos, cfg.d_model, dt)
    new_layers = []
    for st, sp, ss in zip(cfg.stages, params["stages"], state["layers"]):
        x, ns = tfm.stage_step_paged(sp, cfg, st, x, ss, q_pos, valid,
                                     flash_decode=flash_decode)
        new_layers.append(ns)
    new_state = {"layers": new_layers}
    if not with_logits:
        return None, new_state
    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    tied = params["embed"]["tok_embed"] if cfg.tie_embeddings else None
    logits = emb.logits_from_hidden(params.get("head", {}), x, tied_embed=tied)
    return logits, new_state


def ragged_step(params, cfg: ModelCfg, state, tokens, slot, q_pos, seq_idx,
                valid, logit_idx, *, width: int,
                flash_decode: bool = False):
    """One ragged token-budget step: T tokens from any mix of slots/phases.

    The single compiled program of the ragged serving engine.  tokens /
    slot / q_pos / seq_idx / valid are flat (T,) vectors — each entry is one
    token of one slot at one absolute position (seq_idx is its intra-slot
    ordinal within the pack, for the recurrent repack; the scheduler packs at
    most ``width`` tokens per slot, in position order).  Prefill chunks and
    decode tokens are indistinguishable at this level; causality between
    them falls out of the per-token position masks.

    logit_idx: (B,) index into the pack of each slot's sampled token (T ==
    no sample this tick; those rows return garbage logits the engine
    ignores).  Returns (logits (B, V), new state).  A speculative engine
    passes (B, R) instead — row 0 is the slot's base decode token and rows
    1..R-1 its packed draft tokens — and gets (B, R, V) back: one forward
    verifies the whole draft chain, the engine samples row j to check
    draft j.  Unused rows carry T like the 1-D case.  The shape is fixed
    per engine, so either way there is exactly one compiled program.

    Callers must jit this with the state donated
    (``serve_step.STATE_DONATE_ARGNUM``): the KV page pools (and, for int8
    pools, their scale pools) plus the recurrent-state carries dominate the
    pytree, and donation turns every tick's pool update into an in-place
    scatter instead of a whole-pool copy.
    """
    dt = jnp.dtype(cfg.dtype)
    x = emb.embed_tokens(params["embed"], tokens[None], dt)  # (1,T,D)
    if cfg.abs_pos == "sinusoidal":
        x = x + emb.sinusoidal_at(q_pos, cfg.d_model, dt)
    new_layers = []
    for st, sp, ss in zip(cfg.stages, params["stages"], state["layers"]):
        x, ns = tfm.stage_step_ragged(sp, cfg, st, x, ss, slot, q_pos,
                                      seq_idx, valid, width=width,
                                      flash_decode=flash_decode)
        new_layers.append(ns)
    # gather only the sampled tokens before the LM head: the pack is T wide
    # but at most B slots (times R verify rows) sample per tick, so the
    # head runs at (B, V) / (B*R, V) instead of (T, V)
    flat_idx = logit_idx.reshape(-1)
    sel = jnp.take(x[0], jnp.minimum(flat_idx, x.shape[1] - 1), axis=0)
    sel = rmsnorm(params["final_norm"], sel[:, None, :], cfg.norm_eps)
    tied = params["embed"]["tok_embed"] if cfg.tie_embeddings else None
    logits = emb.logits_from_hidden(params.get("head", {}), sel,
                                    tied_embed=tied)
    logits = logits[:, 0]
    if logit_idx.ndim == 2:
        logits = logits.reshape(logit_idx.shape + logits.shape[-1:])
    return logits, {"layers": new_layers}


def reset_paged_slots(cfg: ModelCfg, state, init_state, mask, ptab_rows,
                      prefix_len) -> Dict:
    """Admission/eviction: for slots where ``mask`` is set, install the
    host-allocated block-table rows and restore all other per-row state from
    the fresh-init template (KV pools are shared and untouched — they double
    as the cross-request prefix cache).  ``prefix_len`` (B,) marks how many
    leading positions each admitted slot inherits from shared prefix pages:
    their kpos/slen come up live so the reused KV is visible immediately
    (see ``transformer.reset_stage_slots``)."""
    new_layers = [tfm.reset_stage_slots(st, ss, is0, mask, ptab_rows,
                                        prefix_len)
                  for st, ss, is0 in zip(cfg.stages, state["layers"],
                                         init_state["layers"])]
    return {"layers": new_layers}


def rollback_paged_slots(cfg: ModelCfg, state, mask, new_len) -> Dict:
    """Speculative rejection: for slots where ``mask`` is set, invalidate
    every written KV row at positions >= ``new_len`` (the slot's next write
    position after accepting the agreeing draft prefix) by resetting its
    ``kpos`` entry to -1 and clamping ``slen``.

    Only per-slot position metadata moves — the K/V pools themselves (and
    int8 scale rows) are untouched, so shared COW prefix pages and their
    scales can never be corrupted by a rejected draft tail: drafts only
    ever write beyond the prompt, into pages the slot privately owns (see
    ``serve.pool``).  ``kpos`` stores absolute positions, so the rejected
    tail is exactly the entries holding a value >= new_len; the stale K/V
    bytes they pointed at stay dead until the next tick's scatter
    overwrites them (writes always precede attention within a tick).

    mask: (B,) bool; new_len: (B,) int32.  One trace per engine — the
    engine jits this donated and dispatches it only on ticks that actually
    rejected drafts."""
    new_layers = [tfm.rollback_stage_slots(st, ss, mask, new_len)
                  for st, ss in zip(cfg.stages, state["layers"])]
    return {"layers": new_layers}


def copy_kv_pages(cfg: ModelCfg, state, src, dst) -> Dict:
    """Copy-on-write support: duplicate pool pages ``src[i] -> dst[i]`` in
    every paged global-attention layer (all layers share one page allocator,
    so a single (src, dst) pair list covers the whole stack).

    Used by the serving engine when a request's prompt diverges from a cached
    prefix mid-page: the matched part of the page is copied into a private
    page the new request owns, then prefill overwrites the divergent tail
    (stale offsets stay masked via kpos until written).  src/dst: (K,) int32;
    padding entries carry src == dst == n_pages and clamp to a harmless
    self-copy no-op (see ``kernels.ops.copy_pages``).
    Windowed circular buffers and recurrent states have no shareable pages
    and pass through untouched."""
    from repro.kernels import ops as kops

    def leaf_copy(path, leaf):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        if name in ("kp", "vp"):
            return kops.copy_pages(leaf, src, dst)
        if name in ("ks", "vs"):
            # int8 pools: a page's scale row travels with its values — a
            # COW'd page dequantized against the wrong scales would corrupt
            # the shared prefix (scale pools have no trailing head dim, so
            # the page axis sits at ndim-3)
            return kops.copy_pages(leaf, src, dst, axis=leaf.ndim - 3)
        return leaf

    return jax.tree_util.tree_map_with_path(leaf_copy, state)


def _paged_leaf_axis(path, leaf) -> Optional[int]:
    """Page axis of a paged-pool leaf (kp/vp values at ndim-4, ks/vs scale
    rows at ndim-3 — leading layer dims of scanned stages ride along), or
    ``None`` for per-slot state with no shareable pages."""
    name = None
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            name = p.key
            break
    if name in ("kp", "vp"):
        return leaf.ndim - 4
    if name in ("ks", "vs"):
        return leaf.ndim - 3
    return None


def gather_kv_page(cfg: ModelCfg, state, page) -> Dict[str, jax.Array]:
    """Pull one pool page's rows out of every paged leaf — K/V values AND,
    for int8 pools, their per-entry scale rows — as a flat {path: rows}
    dict: the unit the tiered pool DEMOTES to host RAM.  Scale rows travel
    with their page, so a demoted int8 page survives a later promotion
    bit-exact (the cross-tier analogue of COW-carries-scales).

    ``page`` is a traced scalar, so the engine's jit of this traces once;
    the dict keys (stringified tree paths) are static structure that
    ``insert_kv_page`` looks up symmetrically."""
    out: Dict[str, jax.Array] = {}

    def leaf_gather(path, leaf):
        ax = _paged_leaf_axis(path, leaf)
        if ax is not None:
            out["".join(str(p) for p in path)] = \
                jax.lax.dynamic_index_in_dim(leaf, page, axis=ax,
                                             keepdims=False)

    jax.tree_util.tree_map_with_path(leaf_gather, state)
    return out


def insert_kv_page(cfg: ModelCfg, state, page_data, page) -> Dict:
    """Scatter one demoted page's rows (``gather_kv_page`` layout) back
    into every paged leaf at device page ``page`` — the PROMOTION write.
    Non-paged leaves pass through untouched, so the engine jits this with
    the state donated (like the COW copy) and the pools update in place;
    issuing it at admission lets jax's async dispatch overlap the copy with
    the tick's compute, the data dependency through the donated state
    keeping it correct regardless of overlap."""
    def leaf_insert(path, leaf):
        ax = _paged_leaf_axis(path, leaf)
        key = "".join(str(p) for p in path)
        if ax is None or key not in page_data:
            return leaf
        return jax.lax.dynamic_update_index_in_dim(
            leaf, page_data[key].astype(leaf.dtype), page, axis=ax)

    return jax.tree_util.tree_map_with_path(leaf_insert, state)


def prefill(params, cfg: ModelCfg, state, tokens, enc_feats=None) -> Dict:
    """Teacher-forced prompt ingestion: fills every attention cache and rolls
    recurrent states forward. tokens: (B,S)."""
    from repro.models.layers import attention as attn_lib

    dt = jnp.dtype(cfg.dtype)
    x, enc = _embed_inputs(params, cfg, {"tokens": tokens,
                                         "img_feats": enc_feats})
    S = tokens.shape[1]
    positions = jnp.arange(S)
    new_layers = []
    for st, sp, ss in zip(cfg.stages, params["stages"], state["layers"]):
        x, ns = _stage_prefill(sp, cfg, st, x, ss, positions, enc)
        new_layers.append(ns)
    return {"layers": new_layers, "pos": jnp.asarray(S, jnp.int32)}


def _stage_prefill(params, cfg, st, x, states, positions, enc):
    """Runs stage_fwd for hidden states while re-deriving caches layerwise.

    Implemented blockwise (no scan) only for repeats==1 stages; scanned stages
    prefill inside the scan.
    """
    from repro.models.layers import attention as attn_lib
    from repro.models.layers import mamba as mamba_lib
    from repro.models.layers import xlstm as xlstm_lib

    def one_block(bp, blk, x, s):
        h = rmsnorm(bp["mixer_norm"], x, cfg.norm_eps)
        if blk.mixer == "attn":
            m = attn_lib.attention_fwd(bp["mixer"], blk.attn, h,
                                       positions=positions, q_chunk=cfg.attn_q_chunk)
            s = attn_lib.prefill_cache(bp["mixer"], blk.attn, s, h, positions)
        elif blk.mixer == "cross_attn":
            m = attn_lib.attention_fwd(bp["mixer"], blk.attn, h, enc=enc,
                                       q_chunk=cfg.attn_q_chunk)
        elif blk.mixer == "mamba":
            m, s = _roll_recurrent(mamba_lib.mamba_fwd, mamba_lib.mamba_decode,
                                   bp["mixer"], blk.mamba, h, s)
        elif blk.mixer == "mlstm":
            m, s = _roll_recurrent(xlstm_lib.mlstm_fwd, xlstm_lib.mlstm_decode,
                                   bp["mixer"], blk.xlstm, h, s)
        else:
            m, s = _roll_recurrent(xlstm_lib.slstm_fwd, xlstm_lib.slstm_decode,
                                   bp["mixer"], blk.xlstm, h, s)
        x = x + m
        if blk.ffn is not None:
            h2 = rmsnorm(bp["ffn_norm"], x, cfg.norm_eps)
            if blk.ffn == "mlp":
                from repro.models.layers.mlp import mlp_fwd
                x = x + mlp_fwd(bp["ffn"], blk.mlp, h2)
            else:
                from repro.models.layers.moe import moe_fwd
                f, _ = moe_fwd(bp["ffn"], blk.moe, h2)
                x = x + f
        return x, s

    if st.repeats == 1:
        new_states = []
        for i, blk in enumerate(st.pattern):
            x, s = one_block(params[i], blk, x, states[i])
            new_states.append(s)
        return x, new_states

    def body(x, xs):
        gp, gs = xs
        ns = []
        for i, blk in enumerate(st.pattern):
            x, s = one_block(gp[i], blk, x, gs[i])
            ns.append(s)
        return x, tuple(ns)

    x, new_states = jax.lax.scan(body, x, (tuple(params), tuple(states)))
    return x, list(new_states)


def _roll_recurrent(fwd, dec, p, c, h, s):
    """Prefill a recurrent mixer: full-seq output + state from stepping the
    last position (cheap approximation is wrong — we must step the whole
    prompt).  We scan the single-step decode over time for the state while
    using the parallel form for the outputs."""
    m = fwd(p, c, h)

    def step(s, h_t):
        _, s = dec(p, c, h_t[:, None, :], s)
        return s, None

    s, _ = jax.lax.scan(step, s, jnp.moveaxis(h, 1, 0))
    return m, s
