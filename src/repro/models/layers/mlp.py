"""Dense FFN (optionally gated / SwiGLU)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MLPCfg
from repro.models.layers.common import dense_init
from repro.parallel.sharding import lshard

_ACTS = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}


def init_mlp(key, d: int, cfg: MLPCfg):
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, cfg.d_ff)),
        "w_down": dense_init(ks[1], (cfg.d_ff, d), in_axis_size=cfg.d_ff),
    }
    if cfg.gated:
        p["w_gate"] = dense_init(ks[2], (d, cfg.d_ff))
    return p


def mlp_fwd(params, cfg: MLPCfg, x):
    dt = x.dtype
    act = _ACTS[cfg.act]
    up = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(dt))
    up = lshard(up, "act_batch", "act_seq", "act_ff")
    if cfg.gated:
        gate = jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(dt))
        gate = lshard(gate, "act_batch", "act_seq", "act_ff")
        h = act(gate) * up
    else:
        h = act(up)
    out = jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(dt))
    return lshard(out, "act_batch", "act_seq", None)
