"""Shared initializers / dtype helpers for the functional layer library."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def dense_init(key, shape, in_axis_size=None, dtype=jnp.float32):
    """Truncated-normal fan-in init (LeCun-style), stored in fp32."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = (1.0 / max(1, fan_in)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)).astype(dtype)


def cast(x, dtype_str):
    return x.astype(jnp.dtype(dtype_str))
