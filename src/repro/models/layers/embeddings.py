"""Token embeddings, output heads, RoPE, and modality-frontend stubs.

``[audio]`` / ``[vlm]`` archs specify the transformer backbone only; the
modality frontend is a STUB — ``input_specs()`` provides precomputed
frame/patch embeddings, and ``frontend_proj`` maps them into ``d_model``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init, embed_init
from repro.parallel.sharding import lshard


def init_tok_embed(key, vocab: int, d: int):
    return {"tok_embed": embed_init(key, (vocab, d))}


def embed_tokens(params, tokens, dtype):
    # one-hot matmul keeps the vocab-sharded table local (no gather over
    # the 'model' axis); XLA folds this to a take on a single device.
    emb = params["tok_embed"].astype(dtype)
    out = jnp.take(emb, tokens, axis=0)
    return lshard(out, "act_batch", "act_seq", None)


def init_out_head(key, d: int, vocab: int):
    return {"out_head": dense_init(key, (d, vocab))}


def logits_from_hidden(params, h, *, tied_embed=None):
    """(B,S,D) -> (B,S,V) with V sharded over 'model' (never replicated)."""
    if tied_embed is not None:
        w = tied_embed.T.astype(h.dtype)
    else:
        w = params["out_head"].astype(h.dtype)
    logits = jnp.einsum("bsd,dv->bsv", h, w)
    return lshard(logits, "act_batch", "act_seq", "act_vocab")


def init_frontend(key, d_in: int, d: int):
    """Modality frontend stub: a single linear projection of precomputed
    frame/patch embeddings into d_model."""
    return {"frontend_proj": dense_init(key, (d_in, d))}


def apply_frontend(params, feats, dtype):
    w = params["frontend_proj"].astype(dtype)
    return jnp.einsum("bsf,fd->bsd", feats.astype(dtype), w)


# ---------------------------------------------------------------------------
# Positional encodings


def sinusoidal_pos(seq_len: int, d: int, dtype, offset=0):
    return sinusoidal_at(jnp.arange(seq_len) + offset, d, dtype)


def sinusoidal_at(positions, d: int, dtype):
    """Sinusoidal encodings at explicit (possibly per-row) positions.

    positions: (S,) or (B, S) int -> (S, d) / (B, S, d).  The per-row form
    is what the paged serving path needs: slots sit at different absolute
    positions within one batched step.
    """
    half = d // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def rope_freqs(head_dim: int, theta: float):
    return theta ** (-jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (B, S, *head_axes, hd); positions: (S,) shared across the batch,
    or (B, S) per-row (mixed-length serving slots rotate at their own
    absolute positions)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    # broadcast (S, hd/2) -> (1, S, 1...1, hd/2) against x;
    # (B, S, hd/2) -> (B, S, 1...1, hd/2)
    lead = (1,) if positions.ndim == 1 else (x.shape[0],)
    shape = lead + (x.shape[1],) + (1,) * (x.ndim - 3) + (hd // 2,)
    cos = jnp.cos(ang).reshape(shape)
    sin = jnp.sin(ang).reshape(shape)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
