"""Mamba-1 selective SSM mixer (Jamba's sequence mixer).

The recurrence is evaluated with a time-major ``lax.scan`` that builds the
(B, d_in, d_state) discretized operands *per step* — the (B,S,d_in,d_state)
tensor is never materialized (it would be ~PB-scale at jamba sizes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MambaCfg
from repro.models.layers.common import dense_init
from repro.models.layers.conv import causal_depthwise_conv, conv_step
from repro.parallel.sharding import lshard


def _dims(d: int, cfg: MambaCfg):
    d_in = cfg.expand * d
    dt_rank = cfg.dt_rank or -(-d // 16)
    return d_in, dt_rank


def init_mamba(key, d: int, cfg: MambaCfg):
    d_in, dt_rank = _dims(d, cfg)
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": dense_init(ks[1], (cfg.d_conv, d_in), in_axis_size=cfg.d_conv),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "x_proj": dense_init(ks[2], (d_in, dt_rank + 2 * cfg.d_state), in_axis_size=d_in),
        "dt_w": dense_init(ks[3], (dt_rank, d_in), in_axis_size=dt_rank),
        "dt_b": jnp.full((d_in,), -4.6, jnp.float32),  # softplus^-1(0.01)
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, cfg.d_state + 1, dtype=jnp.float32), (d_in, cfg.d_state)
        ).copy()),
        "ssm_D": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), in_axis_size=d_in),
    }
    return p


def _preprocess(params, cfg: MambaCfg, x):
    """Everything before the recurrence (parallel over time)."""
    dt_ = x.dtype
    d_in, dt_rank = _dims(x.shape[-1], cfg)
    xz = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(dt_))
    xz = lshard(xz, "act_batch", "act_seq", "act_ff")
    x_in, z = jnp.split(xz, 2, axis=-1)
    return x_in, z, d_in, dt_rank


def _ssm_inputs(params, cfg: MambaCfg, x_c, dt_rank):
    dt_ = x_c.dtype
    proj = jnp.einsum("bse,ep->bsp", x_c, params["x_proj"].astype(dt_))
    dt_low, Bmat, Cmat = jnp.split(proj, [dt_rank, dt_rank + cfg.d_state], axis=-1)
    dt_full = jnp.einsum("bsp,pe->bse", dt_low, params["dt_w"].astype(dt_))
    dt = jax.nn.softplus(dt_full.astype(jnp.float32) + params["dt_b"])
    return dt, Bmat.astype(jnp.float32), Cmat.astype(jnp.float32)


def mamba_fwd(params, cfg: MambaCfg, x, chunk: int = 64):
    """x: (B,S,D) -> (B,S,D).

    Nested scan (chunks × steps) with remat on the chunk body: the selective
    recurrence is sequential (data-dependent elementwise decay has no cheap
    parallel form for Mamba-1), but backward-pass residuals are bounded to
    S/chunk state snapshots instead of S (the flat scan stores the (B,d_in,N)
    carry per step — PB-scale at jamba sizes).
    """
    B, S, D = x.shape
    dt_ = x.dtype
    x_in, z, d_in, dt_rank = _preprocess(params, cfg, x)
    x_c = jax.nn.silu(causal_depthwise_conv(x_in, params["conv_w"], params["conv_b"]))
    dt, Bmat, Cmat = _ssm_inputs(params, cfg, x_c, dt_rank)
    A = -jnp.exp(params["A_log"])  # (d_in, N)

    # scan inputs stay in the model dtype (bf16 in production) — f32 copies
    # of (B,S,d_in) tensors are residual-storage poison at jamba scale;
    # the step upcasts per-timestep.  The state h and outputs y_t keep the
    # d_in axis sharded over 'model' (without the constraint GSPMD leaves
    # the whole recurrence replicated across the TP axis).
    def step(h, xs):
        x_t, dt_t, B_t, C_t = (t.astype(jnp.float32) for t in xs)
        dA = jnp.exp(dt_t[:, :, None] * A[None])  # (B,d_in,N)
        dBx = (dt_t * x_t)[:, :, None] * B_t[:, None, :]
        h = lshard(dA * h + dBx, "act_batch", "act_ff", None)
        y_t = jnp.einsum("ben,bn->be", h, C_t).astype(dt_)
        return h, lshard(y_t, "act_batch", "act_ff")

    L = min(chunk, S)
    if S % L:
        L = S
    nc = S // L

    def inner(h, xs_chunk):
        return jax.lax.scan(step, h, xs_chunk)

    inner = jax.checkpoint(inner, prevent_cse=False)
    h0 = jnp.zeros((B, d_in, cfg.d_state), jnp.float32)
    h0 = lshard(h0, "act_batch", "act_ff", None)
    xs = tuple(jnp.swapaxes(jnp.moveaxis(t.astype(dt_).reshape(B, nc, L, t.shape[-1]), 1, 0), 1, 2)
               for t in (x_c, dt, Bmat, Cmat))  # (nc, L, B, F)
    _, ys = jax.lax.scan(inner, h0, xs)  # (nc, L, B, d_in)
    y = jnp.moveaxis(ys, 2, 0).reshape(B, S, d_in)
    y = lshard(y, "act_batch", "act_seq", "act_ff")
    y = (y + (x_c * params["ssm_D"].astype(dt_)).astype(dt_)) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(dt_))
    return lshard(out, "act_batch", "act_seq", None)


# ---------------------------------------------------------------------------
# Decode


def init_mamba_state(cfg: MambaCfg, d: int, batch: int, dtype):
    d_in, _ = _dims(d, cfg)
    return {
        "h": jnp.zeros((batch, d_in, cfg.d_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.d_conv - 1, d_in), dtype),
    }


def mamba_decode(params, cfg: MambaCfg, x_t, state):
    """x_t: (B,1,D) -> (B,1,D)."""
    B = x_t.shape[0]
    dt_ = x_t.dtype
    x_in, z, d_in, dt_rank = _preprocess(params, cfg, x_t)
    x_in, z = x_in[:, 0], z[:, 0]
    xc_t, conv_state = conv_step(x_in, state["conv"], params["conv_w"], params["conv_b"])
    xc_t = jax.nn.silu(xc_t)
    dt, Bmat, Cmat = _ssm_inputs(params, cfg, xc_t[:, None, :], dt_rank)
    dt_t, B_t, C_t = dt[:, 0], Bmat[:, 0], Cmat[:, 0]
    A = -jnp.exp(params["A_log"])
    xf = xc_t.astype(jnp.float32)
    dA = jnp.exp(dt_t[:, :, None] * A[None])
    h = dA * state["h"] + (dt_t * xf)[:, :, None] * B_t[:, None, :]
    y = jnp.einsum("ben,bn->be", h, C_t) + xf * params["ssm_D"]
    y = y.astype(dt_) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, params["out_proj"].astype(dt_))[:, None, :]
    return lshard(out, "act_batch", "act_seq", None), {"h": h, "conv": conv_state}
