"""RMSNorm (the framework's only norm; all assigned archs are RMSNorm-family)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_rmsnorm(d: int):
    return {"scale": jnp.ones((d,), jnp.float32)}


def rmsnorm(params, x, eps: float = 1e-6, *, use_kernel: bool = False):
    if use_kernel:
        from repro.kernels import ops as kops

        return kops.rmsnorm(x, params["scale"], eps=eps)
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"]).astype(dtype)
