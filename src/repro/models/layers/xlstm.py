"""xLSTM mixers: mLSTM (matrix memory) and sLSTM (scalar memory with
recurrence), after arXiv:2405.04517.

Training-time mLSTM uses the **chunkwise-parallel form** (linear attention
with decay): intra-chunk work is a masked (L×L) quadratic form, inter-chunk
state is a (B,nh,hd,hd) recurrence at chunk granularity.  This bounds the
backward-pass residulas to S/L chunk boundaries instead of S timesteps —
the sequential scan stores the matrix memory C per step, which is ~240 GiB
per device at train_4k scale (measured; see EXPERIMENTS.md §Perf).
A sequential reference (``mlstm_fwd_seq``) is kept as the test oracle.

Simplifications (noted in DESIGN.md): sLSTM's block-diagonal recurrent matrix
is dense here; both use stabilized exponential gating as in the paper, with
the C̄ = C/exp(m) storage convention (m₀ = 0, denominator floor exp(-m)).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMCfg
from repro.models.layers.common import dense_init
from repro.models.layers.conv import causal_depthwise_conv, conv_step
from repro.parallel.sharding import lshard

_CONV_K = 4
NEG = -1e30


def _mlstm_dims(d: int, cfg: XLSTMCfg):
    d_in = int(cfg.proj_factor * d)
    hd = d_in // cfg.num_heads
    return d_in, hd


# ---------------------------------------------------------------------------
# mLSTM


def init_mlstm(key, d: int, cfg: XLSTMCfg):
    d_in, hd = _mlstm_dims(d, cfg)
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], (d, 2 * d_in)),
        "conv_w": dense_init(ks[1], (_CONV_K, d_in), in_axis_size=_CONV_K),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "xq": dense_init(ks[2], (d_in, d_in)),
        "xk": dense_init(ks[3], (d_in, d_in)),
        "xv": dense_init(ks[4], (d_in, d_in)),
        "wi": dense_init(ks[5], (d_in, cfg.num_heads)),
        "wf": dense_init(ks[6], (d_in, cfg.num_heads)),
        "bi": jnp.zeros((cfg.num_heads,), jnp.float32),
        "bf": jnp.full((cfg.num_heads,), 3.0, jnp.float32),  # open forget gates
        "out_norm": {"scale": jnp.ones((hd,), jnp.float32)},
        "down_proj": dense_init(ks[7], (d_in, d), in_axis_size=d_in),
    }


def _mlstm_qkv_gates(params, cfg: XLSTMCfg, x_c, x_m):
    """x_c, x_m: (B,S,d_in) -> q,k,v (B,S,nh,hd); log-i, log-f (B,S,nh) f32."""
    B, S, d_in = x_c.shape
    nh = cfg.num_heads
    hd = d_in // nh
    dt = x_c.dtype
    q = jnp.einsum("bse,ef->bsf", x_c, params["xq"].astype(dt)).reshape(B, S, nh, hd)
    k = jnp.einsum("bse,ef->bsf", x_c, params["xk"].astype(dt)).reshape(B, S, nh, hd)
    v = jnp.einsum("bse,ef->bsf", x_m, params["xv"].astype(dt)).reshape(B, S, nh, hd)
    k = k * (hd ** -0.5)
    i_pre = (jnp.einsum("bse,eh->bsh", x_c.astype(jnp.float32), params["wi"])
             + params["bi"])
    f_pre = (jnp.einsum("bse,eh->bsh", x_c.astype(jnp.float32), params["wf"])
             + params["bf"])
    f_pre = jax.nn.log_sigmoid(f_pre)  # log f-gate (≤ 0)
    return q, k, v, i_pre, f_pre


def _mlstm_cell(C, n, m, q_t, k_t, v_t, i_pre, f_pre):
    """One stabilized step (decode & test oracle).  C is the scaled memory C̄.
    Shapes: C (B,nh,hd,hd); q/k/v (B,nh,hd); i/f log-preacts (B,nh)."""
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)[..., None]  # (B,nh,1)
    f_g = jnp.exp(f_pre + m - m_new)[..., None]
    kf, vf, qf = (t.astype(jnp.float32) for t in (k_t, v_t, q_t))
    C = f_g[..., None] * C + i_g[..., None] * vf[..., :, None] * kf[..., None, :]
    n = f_g * n + i_g * kf
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)),
                      jnp.exp(-m_new))[..., None]
    h = num / den  # (B,nh,hd)
    return C, n, m_new, h


def _mlstm_chunk(carry, xs):
    """Chunkwise-parallel mLSTM step.  carry: (C̄ (B,nh,hd,hd), n̄ (B,nh,hd),
    m (B,nh)); xs: q,k,v (B,nh,L,hd) + log-i a, log-f g (B,nh,L)."""
    C, n, m = carry
    q, k, v, a, g = xs
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    L = q.shape[2]
    b = jnp.cumsum(g, axis=-1)  # (B,nh,L) inclusive decay
    bL = b[..., -1:]

    # intra-chunk log weights D_tj = b_t - b_j + a_j (j ≤ t)
    D = b[..., :, None] - b[..., None, :] + a[..., None, :]
    causal = jnp.tril(jnp.ones((L, L), bool))
    D = jnp.where(causal, D, NEG)

    scale = b + m[..., None]  # log weight of the incoming state per position
    m_t = jnp.maximum(jnp.max(D, axis=-1), scale)  # (B,nh,L)

    w_intra = jnp.exp(D - m_t[..., None])  # (B,nh,L,L)
    w_inter = jnp.exp(scale - m_t)  # (B,nh,L)

    qk = jnp.einsum("bhld,bhjd->bhlj", qf, kf)
    num = (jnp.einsum("bhlj,bhjd->bhld", w_intra * qk, vf)
           + jnp.einsum("bhvk,bhlk->bhlv", C, qf) * w_inter[..., None])
    den_dot = (jnp.einsum("bhlj,bhlj->bhl", w_intra, qk)
               + jnp.einsum("bhk,bhlk->bhl", n, qf) * w_inter)
    h = num / jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_t))[..., None]

    # chunk-end state
    a_rev = a + bL - b  # log weight of j's contribution at chunk end
    m_out = jnp.maximum((bL + m[..., None])[..., 0], jnp.max(a_rev, axis=-1))
    w_end = jnp.exp(a_rev - m_out[..., None])  # (B,nh,L)
    decay = jnp.exp(bL[..., 0] + m - m_out)  # (B,nh)
    C = (decay[..., None, None] * C
         + jnp.einsum("bhjv,bhjk,bhj->bhvk", vf, kf, w_end))
    n = decay[..., None] * n + jnp.einsum("bhjk,bhj->bhk", kf, w_end)
    return (C, n, m_out), h


def mlstm_fwd(params, cfg: XLSTMCfg, x, chunk: int = 128):
    B, S, D = x.shape
    dt = x.dtype
    d_in, hd = _mlstm_dims(D, cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dt))
    up = lshard(up, "act_batch", "act_seq", "act_ff")
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c = jax.nn.silu(causal_depthwise_conv(x_m, params["conv_w"], params["conv_b"]))
    q, k, v, a, g = _mlstm_qkv_gates(params, cfg, x_c, x_m)

    nh = cfg.num_heads
    L = min(chunk, S)
    if S % L:
        L = S  # fall back to a single chunk for odd test lengths
    nc = S // L

    def to_chunks(t):  # (B,S,nh,...) -> (nc,B,nh,L,...)
        t = t.reshape(B, nc, L, nh, *t.shape[3:])
        return jnp.moveaxis(jnp.swapaxes(t, 2, 3), 1, 0)

    xs = (to_chunks(q), to_chunks(k), to_chunks(v),
          to_chunks(a[..., None])[..., 0], to_chunks(g[..., None])[..., 0])
    C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, nh, hd), jnp.float32)
    m0 = jnp.zeros((B, nh), jnp.float32)
    body = jax.checkpoint(_mlstm_chunk, prevent_cse=False)
    _, hs = jax.lax.scan(body, (C0, n0, m0), xs)
    # hs: (nc,B,nh,L,hd) -> (B,S,nh,hd)
    h = jnp.moveaxis(hs, 0, 1).swapaxes(2, 3).reshape(B, S, nh, hd)
    h = _head_norm(params, h).reshape(B, S, d_in).astype(dt)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["down_proj"].astype(dt))
    return lshard(out, "act_batch", "act_seq", None)


def mlstm_fwd_seq(params, cfg: XLSTMCfg, x):
    """Sequential-scan reference (test oracle; memory-unsafe at scale)."""
    B, S, D = x.shape
    dt = x.dtype
    d_in, hd = _mlstm_dims(D, cfg)
    up = jnp.einsum("bsd,de->bse", x, params["up_proj"].astype(dt))
    x_m, z = jnp.split(up, 2, axis=-1)
    x_c = jax.nn.silu(causal_depthwise_conv(x_m, params["conv_w"], params["conv_b"]))
    q, k, v, a, g = _mlstm_qkv_gates(params, cfg, x_c, x_m)

    def step(carry, xs):
        C, n, m = carry
        q_t, k_t, v_t, ip, fp = xs
        C, n, m, h = _mlstm_cell(C, n, m, q_t, k_t, v_t, ip, fp)
        return (C, n, m), h

    nh = cfg.num_heads
    C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, nh, hd), jnp.float32)
    m0 = jnp.zeros((B, nh), jnp.float32)
    # time-major xs: (S,B,nh,hd) for q/k/v, (S,B,nh) for gates
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, a, g))
    _, hs = jax.lax.scan(step, (C0, n0, m0), xs)
    h = jnp.moveaxis(hs, 0, 1)  # (B,S,nh,hd) f32
    h = _head_norm(params, h).reshape(B, S, d_in).astype(dt)
    h = h * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", h, params["down_proj"].astype(dt))
    return out


def _head_norm(params, h):
    """RMS-norm over hd, per head. h: (..., nh, hd) f32."""
    var = jnp.mean(h * h, axis=-1, keepdims=True)
    return h * jax.lax.rsqrt(var + 1e-6) * params["out_norm"]["scale"]


def init_mlstm_state(cfg: XLSTMCfg, d: int, batch: int, dtype):
    d_in, hd = _mlstm_dims(d, cfg)
    nh = cfg.num_heads
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.zeros((batch, nh), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_K - 1, d_in), dtype),
    }


def mlstm_decode(params, cfg: XLSTMCfg, x_t, state):
    B, _, D = x_t.shape
    dt = x_t.dtype
    d_in, hd = _mlstm_dims(D, cfg)
    up = jnp.einsum("bsd,de->bse", x_t, params["up_proj"].astype(dt))
    x_m, z = jnp.split(up[:, 0], 2, axis=-1)
    xc, conv_state = conv_step(x_m, state["conv"], params["conv_w"], params["conv_b"])
    xc = jax.nn.silu(xc)
    q, k, v, i_pre, f_pre = _mlstm_qkv_gates(params, cfg, xc[:, None], x_m[:, None])
    C, n, m, h = _mlstm_cell(state["C"], state["n"], state["m"],
                             q[:, 0], k[:, 0], v[:, 0], i_pre[:, 0], f_pre[:, 0])
    h = _head_norm(params, h).reshape(B, d_in).astype(dt) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", h, params["down_proj"].astype(dt))[:, None]
    new = {"C": C, "n": n, "m": m, "conv": conv_state}
    return lshard(out, "act_batch", "act_seq", None), new


# ---------------------------------------------------------------------------
# sLSTM


def init_slstm(key, d: int, cfg: XLSTMCfg):
    ks = jax.random.split(key, 2)
    b = jnp.zeros((4 * d,), jnp.float32)
    b = b.at[d : 2 * d].set(3.0)  # forget-gate bias
    return {
        "w_ifzo": dense_init(ks[0], (d, 4 * d)),
        "r_ifzo": dense_init(ks[1], (d, 4 * d)),
        "b_ifzo": b,
    }


def _slstm_cell(params, carry, wx_t):
    """carry: (h,c,n,m) each (B,D) f32; wx_t: (B,4D) f32 precomputed x@W."""
    h, c, n, m = carry
    raw = wx_t + h @ params["r_ifzo"] + params["b_ifzo"]
    i_pre, f_pre, z_pre, o_pre = jnp.split(raw, 4, axis=-1)
    f_pre = jax.nn.log_sigmoid(f_pre)
    m_new = jnp.maximum(f_pre + m, i_pre)
    i_g = jnp.exp(i_pre - m_new)
    f_g = jnp.exp(f_pre + m - m_new)
    c = f_g * c + i_g * jnp.tanh(z_pre)
    n = f_g * n + i_g
    h = jax.nn.sigmoid(o_pre) * c / jnp.maximum(n, 1.0)
    return (h, c, n, m_new)


def slstm_fwd(params, cfg: XLSTMCfg, x, chunk: int = 64):
    """Nested scan (chunks × steps) with remat on the chunk body: backward
    stores only S/chunk boundary carries (the recurrence is inherently
    sequential — no parallel form exists for h-recurrent sLSTM)."""
    B, S, D = x.shape
    dt = x.dtype
    wx = jnp.einsum("bsd,df->bsf", x.astype(jnp.float32), params["w_ifzo"])

    L = min(chunk, S)
    if S % L:
        L = S
    nc = S // L
    wx_c = jnp.moveaxis(wx.reshape(B, nc, L, 4 * D), 1, 0)  # (nc,B,L,4D)

    def inner(carry, wx_chunk):
        def step(c, wx_t):
            c = _slstm_cell(params, c, wx_t)
            return c, c[0]

        return jax.lax.scan(step, carry, jnp.moveaxis(wx_chunk, 1, 0))

    inner = jax.checkpoint(inner, prevent_cse=False)
    z0 = jnp.zeros((B, D), jnp.float32)
    carry0 = (z0, z0, z0, jnp.full((B, D), -1e30, jnp.float32))
    _, hs = jax.lax.scan(inner, carry0, wx_c)  # (nc,L,B,D)
    out = jnp.moveaxis(hs, 2, 0).reshape(B, S, D).astype(dt)
    return lshard(out, "act_batch", "act_seq", None)


def init_slstm_state(cfg: XLSTMCfg, d: int, batch: int, dtype):
    z = jnp.zeros((batch, d), jnp.float32)
    return {"sh": z, "sc": z, "sn": z, "sm": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(params, cfg: XLSTMCfg, x_t, state):
    dt = x_t.dtype
    wx = jnp.einsum("bd,df->bf", x_t[:, 0].astype(jnp.float32), params["w_ifzo"])
    carry = (state["sh"], state["sc"], state["sn"], state["sm"])
    h, c, n, m = _slstm_cell(params, carry, wx)
    out = h.astype(dt)[:, None]
    return lshard(out, "act_batch", "act_seq", None), {"sh": h, "sc": c, "sn": n, "sm": m}
