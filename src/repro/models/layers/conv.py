"""Causal depthwise 1-D convolution (shared by Mamba and mLSTM blocks)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def causal_depthwise_conv(x, conv_w, conv_b):
    """x: (B,S,C); conv_w: (K,C); conv_b: (C,). Causal (left-pad K-1)."""
    K = conv_w.shape[0]
    dt = x.dtype
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    # sum of shifted slices — K is tiny (4), unrolled adds beat a grouped conv
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(K):
        out = out + xp[:, i : i + S, :] * conv_w[i].astype(dt)
    return out + conv_b.astype(dt)


def conv_step(x_t, state, conv_w, conv_b):
    """Single decode step. x_t: (B,C); state: (B,K-1,C) past inputs."""
    dt = x_t.dtype
    K = conv_w.shape[0]
    window = jnp.concatenate([state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,kc->bc", window, conv_w.astype(dt)) + conv_b.astype(dt)
    return out, window[:, 1:, :]
