"""Attention: GQA self/cross attention with RoPE, sliding windows, and a
memory-safe chunked-softmax path (pure-jnp flash) for long sequences.

Layouts
-------
- q is kept grouped as (B, S, kvH, G, hd) with G = num_heads // num_kv_heads,
  so GQA never materializes repeated KV.
- Full-softmax path for short sequences / tests; q-chunked online path
  otherwise (peak scores bytes ~ B·kvH·G·q_chunk·T·4).
- Decode keeps a KV cache of capacity ``cache_len``; windowed layers use a
  circular buffer of size ``window`` with per-slot absolute positions, so a
  gemma3 local layer at 500k context stores only 1024 slots.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg
from repro.models.layers.common import dense_init
from repro.models.layers.embeddings import apply_rope
from repro.parallel.sharding import lshard

NEG_INF = -1e30


def init_attention(key, d: int, cfg: AttnCfg):
    """Weights are stored GROUPED — wq (D,kvH,G,hd), wo (kvH,G,hd,D) — so no
    reshape ever crosses the head dims.  With heads-TP active the G dim is
    'model'-sharded, and a flat<->grouped reshape across a sharded dim makes
    GSPMD fall back to full rematerialization (measured: +70 s/step of
    collectives on glm4)."""
    ks = jax.random.split(key, 4)
    kvH, hd = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // kvH
    p = {
        "wq": dense_init(ks[0], (d, kvH, G, hd)),
        "wk": dense_init(ks[1], (d, kvH, hd)),
        "wv": dense_init(ks[2], (d, kvH, hd)),
        "wo": dense_init(ks[3], (kvH, G, hd, d), in_axis_size=kvH * G * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((kvH, G, hd), jnp.float32)
        p["bk"] = jnp.zeros((kvH, hd), jnp.float32)
        p["bv"] = jnp.zeros((kvH, hd), jnp.float32)
    return p


def _project_q(params, cfg: AttnCfg, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dkgh->bskgh", x, params["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
    return lshard(q, "act_batch", "act_seq", "act_kv_heads", "act_heads", None)


def _project_kv(params, cfg: AttnCfg, x):
    dt = x.dtype
    k = jnp.einsum("bsd,dkh->bskh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dkh->bskh", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    k = lshard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = lshard(v, "act_batch", "act_seq", "act_kv_heads", None)
    return k, v


def _out_proj(params, cfg: AttnCfg, o):
    # contraction over (kvH, G, hd): with G 'model'-sharded this is a local
    # dot + psum — no cross-shard reshape
    dt = o.dtype
    out = jnp.einsum("bskgh,kghd->bsd", o, params["wo"].astype(dt))
    return lshard(out, "act_batch", "act_seq", None)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) additive bias in f32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    ok &= k_pos[None, :] >= 0  # invalid (unwritten) cache slots carry pos=-1
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _softmax_attn(q, k, v, bias):
    """q: (B,Sq,kvH,G,hd)  k,v: (B,Sk,kvH,hd)  bias: (Sq,Sk) -> (B,Sq,kvH,G,hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32) * scale
    s = s + bias[None, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v)
    return o


def _chunked_attn(q, k, v, q_positions, k_positions, causal, window, q_chunk):
    """lax.scan over query chunks; memory ~ one (Sq_chunk × Sk) score block."""
    B, S, kvH, G, hd = q.shape
    nq = S // q_chunk
    qc = q.reshape(B, nq, q_chunk, kvH, G, hd)
    qc = jnp.moveaxis(qc, 1, 0)  # (nq, B, C, kvH, G, hd)
    qp = q_positions.reshape(nq, q_chunk)

    def body(_, xs):
        q_i, qp_i = xs
        bias = _mask_bias(qp_i, k_positions, causal, window)
        o_i = _softmax_attn(q_i, k, v, bias)
        return None, o_i

    _, oc = jax.lax.scan(body, None, (qc, qp))
    o = jnp.moveaxis(oc, 0, 1).reshape(B, S, kvH, G, hd)
    return o


def attention_fwd(params, cfg: AttnCfg, x, *, positions=None, enc=None,
                  q_chunk: int = 128, use_flash: bool = False):
    """Full-sequence attention (train / prefill).

    enc: (B, T, D) encoder states for cross-attention (vision stub).
    """
    B, S, _ = x.shape
    q = _project_q(params, cfg, x)
    kv_src = enc if cfg.cross else x
    k, v = _project_kv(params, cfg, kv_src)
    T = k.shape[1]

    if positions is None:
        positions = jnp.arange(S)
    if cfg.rope_theta is not None and not cfg.cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    causal = cfg.causal and not cfg.cross
    k_positions = jnp.arange(T)

    if use_flash and causal and not cfg.cross and cfg.window is None and S == T:
        from repro.kernels import ops as kops

        o = kops.flash_attention_grouped(q, k, v)
    elif S <= 2 * q_chunk or S % q_chunk != 0:
        bias = _mask_bias(positions, k_positions, causal, cfg.window)
        o = _softmax_attn(q, k, v, bias)
    else:
        o = _chunked_attn(q, k, v, positions, k_positions, causal, cfg.window, q_chunk)
    return _out_proj(params, cfg, o)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)


def init_cache(cfg: AttnCfg, batch: int, max_len: int, dtype):
    """Cache capacity = min(max_len, window) (circular for windowed layers)."""
    cap = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "k_pos": jnp.full((cap,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_cross_cache(params, cfg: AttnCfg, enc):
    k, v = _project_kv(params, cfg, enc)
    return {"k": k, "v": v}


def prefill_cache(params, cfg: AttnCfg, cache, x, positions):
    """Write a full prompt into the cache (teacher-forced prefill)."""
    k, v = _project_kv(params, cfg, x)
    if cfg.rope_theta is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    cap = cache["k"].shape[1]
    if S >= cap:  # keep last `cap` positions (windowed layer)
        k, v = k[:, -cap:], v[:, -cap:]
        kp = positions[-cap:]
        slots = kp % cap
        cache = dict(cache)
        cache["k"] = jnp.zeros_like(cache["k"]).at[:, slots].set(k)
        cache["v"] = jnp.zeros_like(cache["v"]).at[:, slots].set(v)
        cache["k_pos"] = jnp.full_like(cache["k_pos"], -1).at[slots].set(kp)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        cache["k_pos"] = cache["k_pos"].at[:S].set(positions)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache


def attention_decode(params, cfg: AttnCfg, x, cache, *, sp_decode: bool = False):
    """x: (B,1,D). Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    pos = cache["pos"] if not cfg.cross else None
    q = _project_q(params, cfg, x)  # (B,1,kvH,G,hd)

    if cfg.cross:
        k, v = cache["k"], cache["v"]
        bias = jnp.zeros((1, k.shape[1]), jnp.float32)
        o = _softmax_attn(q, k, v, bias)
        return _out_proj(params, cfg, o), cache

    k_new, v_new = _project_kv(params, cfg, x)  # (B,1,kvH,hd)
    if cfg.rope_theta is not None:
        ppos = pos[None]
        q = apply_rope(q, ppos, cfg.rope_theta)
        k_new = apply_rope(k_new, ppos, cfg.rope_theta)

    cap = cache["k"].shape[1]
    slot = pos % cap
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)
    k_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pos"], pos[None].astype(jnp.int32), slot, 0
    )
    new_cache = {"k": k, "v": v, "k_pos": k_pos, "pos": pos + 1}

    if sp_decode:
        from repro.serve.decode_attention import sp_flash_decode

        o = sp_flash_decode(q, k, v, k_pos, pos, window=cfg.window)
    else:
        qp = pos[None]
        bias = _mask_bias(qp, k_pos, True, cfg.window)
        o = _softmax_attn(q, k, v, bias)
    return _out_proj(params, cfg, o), new_cache
