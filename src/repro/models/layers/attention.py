"""Attention: GQA self/cross attention with RoPE, sliding windows, and a
memory-safe chunked-softmax path (pure-jnp flash) for long sequences.

Layouts
-------
- q is kept grouped as (B, S, kvH, G, hd) with G = num_heads // num_kv_heads,
  so GQA never materializes repeated KV.
- Full-softmax path for short sequences / tests; q-chunked online path
  otherwise (peak scores bytes ~ B·kvH·G·q_chunk·T·4).
- Decode keeps a KV cache of capacity ``cache_len``; windowed layers use a
  circular buffer of size ``window`` with per-slot absolute positions, so a
  gemma3 local layer at 500k context stores only 1024 slots.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import AttnCfg
from repro.models.layers.common import dense_init
from repro.models.layers.embeddings import apply_rope
from repro.parallel.sharding import lshard

NEG_INF = -1e30


def init_attention(key, d: int, cfg: AttnCfg):
    """Weights are stored GROUPED — wq (D,kvH,G,hd), wo (kvH,G,hd,D) — so no
    reshape ever crosses the head dims.  With heads-TP active the G dim is
    'model'-sharded, and a flat<->grouped reshape across a sharded dim makes
    GSPMD fall back to full rematerialization (measured: +70 s/step of
    collectives on glm4)."""
    ks = jax.random.split(key, 4)
    kvH, hd = cfg.num_kv_heads, cfg.head_dim
    G = cfg.num_heads // kvH
    p = {
        "wq": dense_init(ks[0], (d, kvH, G, hd)),
        "wk": dense_init(ks[1], (d, kvH, hd)),
        "wv": dense_init(ks[2], (d, kvH, hd)),
        "wo": dense_init(ks[3], (kvH, G, hd, d), in_axis_size=kvH * G * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((kvH, G, hd), jnp.float32)
        p["bk"] = jnp.zeros((kvH, hd), jnp.float32)
        p["bv"] = jnp.zeros((kvH, hd), jnp.float32)
    return p


def _project_q(params, cfg: AttnCfg, x):
    dt = x.dtype
    q = jnp.einsum("bsd,dkgh->bskgh", x, params["wq"].astype(dt))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(dt)
    return lshard(q, "act_batch", "act_seq", "act_kv_heads", "act_heads", None)


def _project_kv(params, cfg: AttnCfg, x):
    dt = x.dtype
    k = jnp.einsum("bsd,dkh->bskh", x, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dkh->bskh", x, params["wv"].astype(dt))
    if cfg.qkv_bias:
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    k = lshard(k, "act_batch", "act_seq", "act_kv_heads", None)
    v = lshard(v, "act_batch", "act_seq", "act_kv_heads", None)
    return k, v


def _out_proj(params, cfg: AttnCfg, o):
    # contraction over (kvH, G, hd): with G 'model'-sharded this is a local
    # dot + psum — no cross-shard reshape
    dt = o.dtype
    out = jnp.einsum("bskgh,kghd->bsd", o, params["wo"].astype(dt))
    return lshard(out, "act_batch", "act_seq", None)


def _out_proj_replicated(params, cfg: AttnCfg, o):
    # Serving-step variant: replicate the attention output BEFORE the
    # head-contracting einsum.  Under the engine's KV-head TP mesh this is
    # the single op that contracts across the sharded axis; left to GSPMD it
    # becomes a partial dot + psum, whose summation order depends on the
    # device count — replicating first (an exact all-gather) keeps engine
    # outputs bit-identical across 1/2/4 devices, which the invariance
    # suite asserts.  No-op without a mesh.
    o = lshard(o, *([None] * o.ndim))
    return _out_proj(params, cfg, o)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Sq, Sk) additive bias in f32."""
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        ok &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        ok &= (q_pos[:, None] - k_pos[None, :]) < window
    ok &= k_pos[None, :] >= 0  # invalid (unwritten) cache slots carry pos=-1
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _softmax_attn(q, k, v, bias):
    """q: (B,Sq,kvH,G,hd)  k,v: (B,Sk,kvH,hd)  bias: (Sq,Sk) -> (B,Sq,kvH,G,hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32) * scale
    s = s + bias[None, None, None, :, :]
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p, v)
    return o


def _chunked_attn(q, k, v, q_positions, k_positions, causal, window, q_chunk):
    """lax.scan over query chunks; memory ~ one (Sq_chunk × Sk) score block."""
    B, S, kvH, G, hd = q.shape
    nq = S // q_chunk
    qc = q.reshape(B, nq, q_chunk, kvH, G, hd)
    qc = jnp.moveaxis(qc, 1, 0)  # (nq, B, C, kvH, G, hd)
    qp = q_positions.reshape(nq, q_chunk)

    def body(_, xs):
        q_i, qp_i = xs
        bias = _mask_bias(qp_i, k_positions, causal, window)
        o_i = _softmax_attn(q_i, k, v, bias)
        return None, o_i

    _, oc = jax.lax.scan(body, None, (qc, qp))
    o = jnp.moveaxis(oc, 0, 1).reshape(B, S, kvH, G, hd)
    return o


def attention_fwd(params, cfg: AttnCfg, x, *, positions=None, enc=None,
                  q_chunk: int = 128, use_flash: bool = False):
    """Full-sequence attention (train / prefill).

    enc: (B, T, D) encoder states for cross-attention (vision stub).
    """
    B, S, _ = x.shape
    q = _project_q(params, cfg, x)
    kv_src = enc if cfg.cross else x
    k, v = _project_kv(params, cfg, kv_src)
    T = k.shape[1]

    if positions is None:
        positions = jnp.arange(S)
    if cfg.rope_theta is not None and not cfg.cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    causal = cfg.causal and not cfg.cross
    k_positions = jnp.arange(T)

    if use_flash and causal and not cfg.cross and cfg.window is None and S == T:
        from repro.kernels import ops as kops

        o = kops.flash_attention_grouped(q, k, v)
    elif S <= 2 * q_chunk or S % q_chunk != 0:
        bias = _mask_bias(positions, k_positions, causal, cfg.window)
        o = _softmax_attn(q, k, v, bias)
    else:
        o = _chunked_attn(q, k, v, positions, k_positions, causal, cfg.window, q_chunk)
    return _out_proj(params, cfg, o)


# ---------------------------------------------------------------------------
# Decode (single token, KV cache)


def init_cache(cfg: AttnCfg, batch: int, max_len: int, dtype):
    """Cache capacity = min(max_len, window) (circular for windowed layers)."""
    cap = max_len if cfg.window is None else min(cfg.window, max_len)
    return {
        "k": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "v": jnp.zeros((batch, cap, cfg.num_kv_heads, cfg.head_dim), dtype),
        "k_pos": jnp.full((cap,), -1, jnp.int32),
        "pos": jnp.zeros((), jnp.int32),
    }


def init_cross_cache(params, cfg: AttnCfg, enc):
    k, v = _project_kv(params, cfg, enc)
    return {"k": k, "v": v}


def prefill_cache(params, cfg: AttnCfg, cache, x, positions):
    """Write a full prompt into the cache (teacher-forced prefill)."""
    k, v = _project_kv(params, cfg, x)
    if cfg.rope_theta is not None:
        k = apply_rope(k, positions, cfg.rope_theta)
    S = x.shape[1]
    cap = cache["k"].shape[1]
    if S >= cap:  # keep last `cap` positions (windowed layer)
        k, v = k[:, -cap:], v[:, -cap:]
        kp = positions[-cap:]
        slots = kp % cap
        cache = dict(cache)
        cache["k"] = jnp.zeros_like(cache["k"]).at[:, slots].set(k)
        cache["v"] = jnp.zeros_like(cache["v"]).at[:, slots].set(v)
        cache["k_pos"] = jnp.full_like(cache["k_pos"], -1).at[slots].set(kp)
    else:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, 0, 1)
        cache["v"] = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, 0, 1)
        cache["k_pos"] = cache["k_pos"].at[:S].set(positions)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return cache


# ---------------------------------------------------------------------------
# Paged decode (per-slot positions, block-table KV pool)
#
# The serving engine's cache layout.  Global (window=None) layers store KV in
# a pool of fixed-size pages indexed through a per-slot block table, so a
# slot holding a short sequence only pins ceil(len/page) pages and the engine
# can admit more slots than ``B × cache_len`` worth of physical cache.
# Windowed layers keep per-slot circular buffers (their KV is bounded by the
# window, so paging buys nothing) but gain per-slot positions/validity.
# Unmapped block-table entries hold the OOB sentinel ``n_pages``: scatters to
# them are dropped, gathers clamp to an arbitrary page whose entries are then
# masked via ``kpos`` (-1 = never written).
#
# Prefix sharing rides on the same indirection: the serving engine may point
# several slots' block-table rows at ONE pool page (a cached shared prompt
# prefix, refcounted host-side).  Reads go through ptab and need nothing new;
# writes never target a shared page because a slot's first unmatched position
# always lands in a privately allocated page (copy-on-write duplicates a
# partially matched page before admission).  kpos for inherited positions is
# preset by ``reset_paged_slots`` so the reused KV is visible immediately.


# The pool's MEMORY REPRESENTATION is configurable (``kv_dtype``): float32 /
# bfloat16 pools store KV verbatim; int8 pools store symmetric int8 values
# plus one float32 scale per pool entry per KV head (``ks``/``vs``,
# (n_pages, page, kvH)).  The lifecycle is write-quantize -> paged
# read-dequant -> COW-with-scales: K/V rows are quantized ONCE as they are
# scattered into the pool (``kernels.ops.kv_scatter_quantized``), every
# reader (prefill chunks, decode ticks, prefix hits, the Pallas kernels)
# dequantizes the same representation, and copy-on-write copies a page's
# scale row with its values.  Quantizing at write time means a page is
# byte-identical no matter which phase produced it — prefix hits on int8
# pools are exact replays of the cold path.


def kv_cache_dtype(kv_dtype, act_dtype):
    """Resolve a ``kv_dtype`` spec (None | str | dtype) to a jnp dtype.
    None means "follow the activation dtype" (the unquantized default)."""
    if kv_dtype is None:
        return jnp.dtype(act_dtype)
    return jnp.dtype(kv_dtype)


def init_paged_cache(cfg: AttnCfg, batch: int, cache_len: int, dtype, *,
                     page_size: int, n_pages: int, window_extra: int = 0,
                     kv_dtype=None):
    """Paged (global) or per-slot circular (windowed) decode cache.

    ``window_extra`` over-provisions windowed buffers: a C-token chunk write
    evicts the C oldest entries, so the earliest query in the chunk (which
    still needs keys up to ``window`` behind it) requires capacity
    ``window + C - 1`` — callers doing C-token chunked prefill must pass
    ``window_extra = C - 1``.  Stale entries beyond the window stay masked
    via ``kpos``, so extra capacity never changes attention results.

    ``kv_dtype`` (None | "float32" | "bfloat16" | "int8") sets the PAGED
    pool's storage dtype; int8 pools add per-entry-per-head scale pools
    ``ks``/``vs``.  Windowed circular buffers always store the activation
    dtype — their footprint is bounded by the window, so quantizing them
    buys little, and models with windowed layers serve with prefix sharing
    off anyway.
    """
    kvH, hd = cfg.num_kv_heads, cfg.head_dim
    if cfg.window is not None:
        cap = min(cfg.window, cache_len) + window_extra
        return {
            "k": jnp.zeros((batch, cap, kvH, hd), dtype),
            "v": jnp.zeros((batch, cap, kvH, hd), dtype),
            "kpos": jnp.full((batch, cap), -1, jnp.int32),
            "slen": jnp.zeros((batch,), jnp.int32),
        }
    kvd = kv_cache_dtype(kv_dtype, dtype)
    pps = -(-cache_len // page_size)  # block-table width (pages per slot)
    cache = {
        "kp": jnp.zeros((n_pages, page_size, kvH, hd), kvd),
        "vp": jnp.zeros((n_pages, page_size, kvH, hd), kvd),
        "ptab": jnp.full((batch, pps), n_pages, jnp.int32),
        "kpos": jnp.full((batch, pps * page_size), -1, jnp.int32),
        "slen": jnp.zeros((batch,), jnp.int32),
    }
    if kvd == jnp.int8:
        cache["ks"] = jnp.zeros((n_pages, page_size, kvH), jnp.float32)
        cache["vs"] = jnp.zeros((n_pages, page_size, kvH), jnp.float32)
    return cache


def _paged_masked_attn(q, k, v, kpos, q_pos, window):
    """Per-slot masked softmax: q (B,C,kvH,G,hd), k/v (B,T,kvH,hd),
    kpos (B,T), q_pos (B,C) -> (B,C,kvH,G,hd)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgqt", q, k).astype(jnp.float32) * scale
    ok = (kpos[:, None, :] >= 0) & (kpos[:, None, :] <= q_pos[:, :, None])
    if window is not None:
        ok &= (q_pos[:, :, None] - kpos[:, None, :]) < window
    s = jnp.where(ok[:, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    p = jnp.where(ok[:, None, None, :, :], p, 0.0)
    return jnp.einsum("bkgqt,btkd->bqkgd", p, v)


def _scatter_paged_kv(cache, k_new, v_new, page, off):
    """Scatter new K/V rows into the pool at (page, off) — the single write
    path shared by the two-phase and ragged steps.  int8 pools quantize on
    write (values + scale rows, ``kernels.ops.kv_scatter_quantized``); OOB
    sentinel pages drop the write either way.  Mutates the caller's cache
    dict (callers own a fresh copy)."""
    from repro.kernels import ops as kops

    if "ks" in cache:  # int8 pool: fused quantize-on-write
        cache["kp"], cache["ks"] = kops.kv_scatter_quantized(
            cache["kp"], cache["ks"], k_new, page, off)
        cache["vp"], cache["vs"] = kops.kv_scatter_quantized(
            cache["vp"], cache["vs"], v_new, page, off)
    else:
        cache["kp"] = cache["kp"].at[page, off].set(
            k_new.astype(cache["kp"].dtype), mode="drop")
        cache["vp"] = cache["vp"].at[page, off].set(
            v_new.astype(cache["vp"].dtype), mode="drop")


def _gather_paged_kv(cache, dtype):
    """Gather the whole block-table context from the pool, dequantizing int8
    pools against their per-entry scale rows (the jnp oracle of the fused
    kernel path).  Returns (k, v) of shape (B, pps, P, kvH, hd) in ``dtype``.
    """
    k = jnp.take(cache["kp"], cache["ptab"], axis=0, mode="clip")
    v = jnp.take(cache["vp"], cache["ptab"], axis=0, mode="clip")
    if "ks" in cache:
        from repro.kernels import ops as kops

        ks = jnp.take(cache["ks"], cache["ptab"], axis=0, mode="clip")
        vs = jnp.take(cache["vs"], cache["ptab"], axis=0, mode="clip")
        return kops.dequantize_kv(k, ks, dtype), kops.dequantize_kv(v, vs, dtype)
    return k.astype(dtype), v.astype(dtype)


def paged_attention_step(params, cfg: AttnCfg, x, cache, q_pos, valid, *,
                         flash_decode: bool = False):
    """One serving step against the paged cache: writes the C incoming
    tokens, then attends over everything written so far.

    x: (B, C, D) — C == 1 is a decode tick, C > 1 a prefill chunk.
    q_pos: (B, C) absolute positions (per-slot); valid: (B, C) marks real
    tokens (False rows/tails: no cache write, output ignored by the engine).
    """
    B, C, _ = x.shape
    q = _project_q(params, cfg, x)  # (B,C,kvH,G,hd)
    k_new, v_new = _project_kv(params, cfg, x)  # (B,C,kvH,hd)
    if cfg.rope_theta is not None:
        q = apply_rope(q, q_pos, cfg.rope_theta)
        k_new = apply_rope(k_new, q_pos, cfg.rope_theta)

    b_iota = jnp.broadcast_to(jnp.arange(B)[:, None], (B, C))
    paged = "kp" in cache
    cache = dict(cache)
    if paged:
        P = cache["kp"].shape[1]
        n_pages = cache["kp"].shape[0]
        pps = cache["ptab"].shape[-1]
        page_slot = jnp.clip(q_pos // P, 0, pps - 1)
        page = jnp.take_along_axis(cache["ptab"], page_slot, axis=1)
        page = jnp.where(valid, page, n_pages)  # OOB -> scatter dropped
        off = q_pos % P
        _scatter_paged_kv(cache, k_new, v_new, page, off)
        T = pps * P
        idx = jnp.where(valid, q_pos, T)
    else:
        cap = cache["k"].shape[1]
        # chunk positions are contiguous per row: when C > cap the circular
        # buffer wraps within one scatter, so keep only the last ``cap``
        # writes per row (duplicate scatter indices have unspecified order)
        row_max = jnp.max(jnp.where(valid, q_pos, -1), axis=1, keepdims=True)
        keep = valid & (q_pos > row_max - cap)
        idx = jnp.where(keep, q_pos % cap, cap)
        cache["k"] = cache["k"].at[b_iota, idx].set(k_new, mode="drop")
        cache["v"] = cache["v"].at[b_iota, idx].set(v_new, mode="drop")
        T = cap
    cache["kpos"] = cache["kpos"].at[b_iota, idx].set(q_pos, mode="drop")
    cache["slen"] = jnp.maximum(
        cache["slen"], jnp.max(jnp.where(valid, q_pos + 1, 0), axis=1))

    if paged and flash_decode and C == 1:
        # TP entry point: shard_maps the Pallas kernel over the KV-head axis
        # under the serving mesh, plain kernel call otherwise
        from repro.serve.decode_attention import tp_paged_flash_decode

        o = tp_paged_flash_decode(q[:, 0], cache["kp"], cache["vp"],
                                  cache["ptab"], cache["slen"],
                                  ks=cache.get("ks"),
                                  vs=cache.get("vs"))[:, None]
    elif paged:
        k, v = _gather_paged_kv(cache, q.dtype)
        kvH, hd = cfg.num_kv_heads, cfg.head_dim
        k = k.reshape(B, T, kvH, hd)
        v = v.reshape(B, T, kvH, hd)
        o = _paged_masked_attn(q, k, v, cache["kpos"], q_pos, cfg.window)
    else:
        o = _paged_masked_attn(q, cache["k"], cache["v"], cache["kpos"],
                               q_pos, cfg.window)
    return _out_proj_replicated(params, cfg, o), cache


def ragged_attention_step(params, cfg: AttnCfg, x, cache, slot, q_pos, valid,
                          *, flash_decode: bool = False):
    """One ragged serving step: a flat pack of T tokens from arbitrary slots.

    x: (1, T, D) hidden pack; slot/q_pos/valid: (T,) per-token slot index,
    absolute position, and validity.  Any mix of prefill-chunk tokens and
    decode tokens rides in one pack — the cache write and the per-token
    causal mask (``kpos <= q_pos``) make intra-pack causality fall out of the
    same machinery as cross-tick causality, so a prefill chunk and the decode
    tokens of other slots coexist in a single program.  Invalid tokens
    scatter nowhere and their outputs are garbage the engine never reads.
    """
    T = x.shape[1]
    q = _project_q(params, cfg, x)[0]  # (T,kvH,G,hd)
    k_new, v_new = (t[0] for t in _project_kv(params, cfg, x))  # (T,kvH,hd)
    if cfg.rope_theta is not None:
        q = apply_rope(q[None], q_pos[None], cfg.rope_theta)[0]
        k_new = apply_rope(k_new[None], q_pos[None], cfg.rope_theta)[0]

    paged = "kp" in cache
    cache = dict(cache)
    B = cache["slen"].shape[0]
    if paged:
        P = cache["kp"].shape[1]
        n_pages = cache["kp"].shape[0]
        pps = cache["ptab"].shape[-1]
        page_slot = jnp.clip(q_pos // P, 0, pps - 1)
        page = cache["ptab"][slot, page_slot]  # (T,)
        page = jnp.where(valid, page, n_pages)  # OOB -> scatter dropped
        off = q_pos % P
        _scatter_paged_kv(cache, k_new, v_new, page, off)
        Tc = pps * P
        idx = jnp.where(valid, q_pos, Tc)
    else:
        cap = cache["k"].shape[1]
        # a slot's pack tokens are contiguous positions: when they exceed
        # ``cap`` the circular buffer wraps within one scatter, so keep only
        # the last ``cap`` writes per slot (duplicate scatter indices have
        # unspecified order).  row_max is a per-slot segment max.
        row_max = jnp.full((B,), -1, jnp.int32).at[slot].max(
            jnp.where(valid, q_pos, -1), mode="drop")
        keep = valid & (q_pos > row_max[slot] - cap)
        idx = jnp.where(keep, q_pos % cap, cap)
        cache["k"] = cache["k"].at[slot, idx].set(k_new, mode="drop")
        cache["v"] = cache["v"].at[slot, idx].set(v_new, mode="drop")
        Tc = cap
    cache["kpos"] = cache["kpos"].at[slot, idx].set(q_pos, mode="drop")
    cache["slen"] = cache["slen"].at[slot].max(
        jnp.where(valid, q_pos + 1, 0), mode="drop")

    if paged and flash_decode:
        # TP entry point: shard_maps the Pallas kernel over the KV-head axis
        # under the serving mesh, plain kernel call otherwise
        from repro.serve.decode_attention import tp_ragged_paged_flash

        lens = jnp.where(valid, q_pos + 1, 0).astype(jnp.int32)
        o = tp_ragged_paged_flash(q, cache["kp"], cache["vp"],
                                  cache["ptab"], slot, lens,
                                  ks=cache.get("ks"),
                                  vs=cache.get("vs"))[None]
        return _out_proj_replicated(params, cfg, o), cache

    if paged:
        k_all, v_all = _gather_paged_kv(cache, q.dtype)
        kvH, hd = cfg.num_kv_heads, cfg.head_dim
        k_all = k_all.reshape(B, Tc, kvH, hd)
        v_all = v_all.reshape(B, Tc, kvH, hd)
    else:
        k_all, v_all = cache["k"], cache["v"]
    # gather each token's slot context and run T single-query attentions:
    # _paged_masked_attn with the pack as the batch axis and C == 1
    k_tok = k_all[slot]  # (T,Tc,kvH,hd)
    v_tok = v_all[slot]
    kpos_tok = cache["kpos"][slot]  # (T,Tc)
    o = _paged_masked_attn(q[:, None], k_tok, v_tok, kpos_tok,
                           q_pos[:, None], cfg.window)  # (T,1,kvH,G,hd)
    o = jnp.moveaxis(o, 1, 0)  # (1,T,kvH,G,hd)
    return _out_proj_replicated(params, cfg, o), cache


def attention_decode(params, cfg: AttnCfg, x, cache, *, sp_decode: bool = False):
    """x: (B,1,D). Returns (out (B,1,D), new_cache)."""
    B = x.shape[0]
    pos = cache["pos"] if not cfg.cross else None
    q = _project_q(params, cfg, x)  # (B,1,kvH,G,hd)

    if cfg.cross:
        k, v = cache["k"], cache["v"]
        bias = jnp.zeros((1, k.shape[1]), jnp.float32)
        o = _softmax_attn(q, k, v, bias)
        return _out_proj(params, cfg, o), cache

    k_new, v_new = _project_kv(params, cfg, x)  # (B,1,kvH,hd)
    if cfg.rope_theta is not None:
        ppos = pos[None]
        q = apply_rope(q, ppos, cfg.rope_theta)
        k_new = apply_rope(k_new, ppos, cfg.rope_theta)

    cap = cache["k"].shape[1]
    slot = pos % cap
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, 1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, 1)
    k_pos = jax.lax.dynamic_update_slice_in_dim(
        cache["k_pos"], pos[None].astype(jnp.int32), slot, 0
    )
    new_cache = {"k": k, "v": v, "k_pos": k_pos, "pos": pos + 1}

    if sp_decode:
        from repro.serve.decode_attention import sp_flash_decode

        o = sp_flash_decode(q, k, v, k_pos, pos, window=cfg.window)
    else:
        qp = pos[None]
        bias = _mask_bias(qp, k_pos, True, cfg.window)
        o = _softmax_attn(q, k, v, bias)
    return _out_proj(params, cfg, o), new_cache
