"""Mixture-of-Experts FFN.

Baseline (paper-era, GSPMD-friendly) implementation: capacity-bounded
dispatch/combine einsums (Mesh-TensorFlow / MaxText style).  Experts are
sharded over the 'model' axis (EP); tokens stay batch-sharded over 'data',
and because activations are replicated across 'model', each chip builds the
dispatch slice for *its* experts locally — no all-to-all in the baseline.

A dropless ``ragged_dot`` path (``impl="ragged"``) is provided as the
beyond-paper optimized variant (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoECfg
from repro.models.layers.common import dense_init
from repro.models.layers.mlp import init_mlp, mlp_fwd
from repro.parallel.sharding import lshard


def init_moe(key, d: int, cfg: MoECfg):
    ks = jax.random.split(key, 5)
    E, F = cfg.num_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], (d, E)),
        "we_gate": dense_init(ks[1], (E, d, F), in_axis_size=d),
        "we_up": dense_init(ks[2], (E, d, F), in_axis_size=d),
        "we_down": dense_init(ks[3], (E, F, d), in_axis_size=F),
    }
    if cfg.dense_residual is not None:
        p["dense"] = init_mlp(ks[4], d, cfg.dense_residual)
    return p


def _route(params, cfg: MoECfg, x):
    """Router in f32. Returns (gates (B,S,k), idx (B,S,k), probs (B,S,E))."""
    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    if cfg.top_k > 1:
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates, idx, probs, logits


def _aux_losses(probs, idx, logits, num_experts: int):
    """Load-balance loss (Switch-style) + router z-loss."""
    # fraction of tokens routed (top-1 assignment) per expert
    top1 = idx[..., 0]
    load = jnp.mean(jax.nn.one_hot(top1, num_experts, dtype=jnp.float32), axis=(0, 1))
    importance = jnp.mean(probs, axis=(0, 1))
    lb = num_experts * jnp.sum(load * importance)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return {"moe_lb_loss": lb, "moe_z_loss": z}


def moe_fwd(params, cfg: MoECfg, x) -> Tuple[jax.Array, dict]:
    if cfg.impl == "ragged":
        return _moe_fwd_ragged(params, cfg, x)
    return _moe_fwd_dispatch(params, cfg, x)


def _moe_fwd_dispatch(params, cfg: MoECfg, x) -> Tuple[jax.Array, dict]:
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    dt = x.dtype
    gates, idx, probs, logits = _route(params, cfg, x)
    aux = _aux_losses(probs, idx, logits, E)

    # capacity per (batch-row) group of S tokens
    C = max(k, int(-(-S * k * cfg.capacity_factor // E)))

    # flatten the k slots: (B, S*k) routing decisions, priority = token order
    flat_idx = idx.reshape(B, S * k)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)  # (B, S*k, E)
    pos = jnp.cumsum(onehot, axis=1) * onehot - 1  # position within expert
    keep = (pos >= 0) & (pos < C)
    pos = jnp.where(keep, pos, C)  # overflow slot (sliced away by one_hot)

    # dispatch tensor (B, S*k, E, C) — E sharded over 'model'
    disp = jax.nn.one_hot(pos, C, dtype=dt) * onehot.astype(dt)[..., None]
    disp = disp.reshape(B, S, k, E, C)
    dispatch = jnp.sum(disp, axis=2)  # (B,S,E,C)
    combine = jnp.sum(disp * gates.astype(dt)[..., None, None], axis=2)
    dispatch = lshard(dispatch, "act_batch", None, "act_expert", None)
    combine = lshard(combine, "act_batch", None, "act_expert", None)

    # expert compute, local in (data=batch, model=expert) tiles
    xin = jnp.einsum("bsec,bsd->becd", dispatch, x)
    xin = lshard(xin, "act_batch", "act_expert", None, None)
    g = jnp.einsum("becd,edf->becf", xin, params["we_gate"].astype(dt))
    u = jnp.einsum("becd,edf->becf", xin, params["we_up"].astype(dt))
    h = jax.nn.silu(g) * u
    h = lshard(h, "act_batch", "act_expert", None, None)
    eo = jnp.einsum("becf,efd->becd", h, params["we_down"].astype(dt))
    y = jnp.einsum("becd,bsec->bsd", eo, combine)
    y = lshard(y, "act_batch", "act_seq", None)

    if cfg.dense_residual is not None:
        y = y + mlp_fwd(params["dense"], cfg.dense_residual, x)
    return y, aux


def _moe_fwd_ragged(params, cfg: MoECfg, x) -> Tuple[jax.Array, dict]:
    """Dropless MoE via sort + ragged_dot (beyond-paper optimized path).

    Tokens (replicated over 'model') are sorted by expert id; each chip runs
    ragged group-matmuls for its expert shard.  No capacity, no drops.
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.top_k
    dt = x.dtype
    gates, idx, probs, logits = _route(params, cfg, x)
    aux = _aux_losses(probs, idx, logits, E)

    T = B * S
    xt = x.reshape(T, D)
    flat_idx = idx.reshape(T * k)
    flat_gate = gates.reshape(T * k).astype(dt)
    order = jnp.argsort(flat_idx)
    inv = jnp.argsort(order)
    tok_of = order // k  # source token for each sorted slot
    xs = jnp.take(xt, tok_of, axis=0)  # (T*k, D)
    group_sizes = jnp.bincount(flat_idx, length=E).astype(jnp.int32)

    g = jax.lax.ragged_dot(xs, params["we_gate"].astype(dt), group_sizes)
    u = jax.lax.ragged_dot(xs, params["we_up"].astype(dt), group_sizes)
    h = jax.nn.silu(g) * u
    eo = jax.lax.ragged_dot(h, params["we_down"].astype(dt), group_sizes)

    eo = jnp.take(eo, inv, axis=0) * flat_gate[:, None]  # back to slot order
    y = jnp.sum(eo.reshape(T, k, D), axis=1).reshape(B, S, D)
    y = lshard(y, "act_batch", "act_seq", None)

    if cfg.dense_residual is not None:
        y = y + mlp_fwd(params["dense"], cfg.dense_residual, x)
    return y, aux
