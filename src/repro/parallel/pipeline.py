"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

Provided for >pod scaling of the ≥398B archs (the default production mesh
saturates 256 chips with DP×TP; PP composes over the 'pod' axis when depth
must scale further).  Implementation: shard_map over 'pipe'; each device
holds one stage's params; microbatches stream through a collective_permute
ring with the classic (M + P - 1)-tick fill/drain schedule.

Differentiable end-to-end (ppermute has a transpose rule), so jax.grad
through ``pipeline_apply`` yields pipeline-parallel backward for free.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.parallel.sharding import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x_microbatches, mesh: Mesh,
                   axis: str = "pipe"):
    """Run ``stage_fn(params_p, x)`` over P pipeline stages.

    stage_params: pytree with leading dim P (one slice per stage), sharded
                  over ``axis``.
    x_microbatches: (M, B, ...) microbatches (replicated).
    Returns (M, B, ...) outputs of the final stage (replicated).
    """
    n_stages = mesh.shape[axis]

    def local(params, xs):
        params = jax.tree.map(lambda p: p[0], params)  # this stage's slice
        M = xs.shape[0]
        stage_id = jax.lax.axis_index(axis)
        T = M + n_stages - 1
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)
        perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            mb = jax.lax.dynamic_index_in_dim(
                xs, jnp.clip(t, 0, M - 1), 0, keepdims=False)
            buf = jnp.where(stage_id == 0,
                            jnp.where(t < M, mb, jnp.zeros_like(mb)), buf)
            y = stage_fn(params, buf)
            # last stage emits microbatch t - (P - 1)
            out_idx = t - (n_stages - 1)
            outs = jax.lax.cond(
                out_idx >= 0,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, jnp.clip(out_idx, 0, M - 1), 0),
                lambda o: o, outs)
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, T, tick, (buf, outs))
        # only the LAST stage's `outs` is meaningful: broadcast it
        outs = jax.lax.psum(
            jnp.where(stage_id == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(),
    )(stage_params, x_microbatches)
