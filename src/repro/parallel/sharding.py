"""Logical-axis sharding: the framework's single source of truth for layout.

Model code never mentions mesh axes.  It tags tensors with *logical* axis
names via ``lshard(x, "act_batch", "act_seq", None)``; a rules table maps
logical names to physical mesh axes.  Parameter layouts are derived from leaf
*names* (every weight leaf has a descriptive name) via ``PARAM_AXES``.

This indirection is the TPU analogue of the paper's pinning discipline: the
rules table decides, once, system-wide, how work binds to the machine — model
authors just write math (the paper's ``C = A*B`` users), operators set rules
(the paper's systems staff setting KMP_AFFINITY/taskset/memory-mode).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def shard_map(f, mesh: Mesh, in_specs, out_specs):
    """Version-portable ``shard_map`` with replication checking off.

    ``jax.shard_map`` (with ``check_vma``) only exists on newer JAX; this
    environment's 0.4.x line ships ``jax.experimental.shard_map.shard_map``
    (with ``check_rep``).  Every call site in the repo wants the same thing
    — per-shard execution with no replication verification — so route them
    all through one shim instead of version-guessing at each site."""
    top = getattr(jax, "shard_map", None)
    if top is not None:
        try:
            return top(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                       check_vma=False)
        except TypeError:  # newer-but-different keyword surface
            return top(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False)


# ---------------------------------------------------------------------------
# Ambient mesh + rules (thread-local so tests can nest)

_state = threading.local()


def _stack():
    if not hasattr(_state, "stack"):
        _state.stack = []
    return _state.stack


def current_mesh() -> Optional[Mesh]:
    st = _stack()
    return st[-1][0] if st else None


def current_rules() -> Dict[str, Any]:
    st = _stack()
    return st[-1][1] if st else {}


@contextlib.contextmanager
def use_mesh(mesh: Mesh, rules: Optional[Dict[str, Any]] = None):
    """Enter ``mesh`` (jax context) and install logical-axis rules."""
    if rules is None:
        rules = make_rules(mesh)
    _stack().append((mesh, rules))
    try:
        with mesh:
            yield mesh
    finally:
        _stack().pop()


@contextlib.contextmanager
def axis_rules(rules: Dict[str, Any]):
    """Override logical-axis rules inside the current mesh (e.g. SP decode)."""
    mesh = current_mesh()
    merged = dict(current_rules())
    merged.update(rules)
    _stack().append((mesh, merged))
    try:
        yield
    finally:
        _stack().pop()


# ---------------------------------------------------------------------------
# Rules

def make_rules(mesh: Mesh, *, heads_tp: bool = False, kv_seq_axis=None,
               decode: bool = False, long_ctx: bool = False) -> Dict[str, Any]:
    """Default logical→physical rules for a (pod,)data×model mesh.

    ``heads_tp``     — shard attention heads over 'model' (requires
                       num_heads % model_size == 0; the autotuner turns this
                       on per-arch).  Off = universal batch-local attention.
    ``kv_seq_axis``  — shard the KV-cache sequence dim (long-context SP).
    ``decode``       — weight-stationary serving layout: per-token
                       activations are MBs while FSDP-gathered weights are
                       GBs, so activations REPLICATE over 'data' and matmuls
                       contract over the data-sharded weight dim (psum of
                       activations).  KV caches / recurrent states stay
                       batch-sharded over 'data' ("act_kv_batch") and
                       KV-seq-sharded over 'model'; attention is local per
                       batch shard via the distributed flash-decode.
                       Measured: arctic-480b decode collectives drop from
                       81 GB/token (batch-sharded acts + weight gathers) to
                       activation-sized psums (EXPERIMENTS.md §Perf).
    ``long_ctx``     — batch=1 long-context serving: batch unshardable, KV
                       sequence sharded over ('data','model').
    """
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    fsdp = batch_axes  # params fully sharded over all data-parallel axes
    kv_batch_axes = batch_axes
    if long_ctx:
        # batch=1 long-context serving: batch unshardable; KV sequence takes
        # every axis and the distributed flash-decode combines partials
        batch_axes = ()
        kv_batch_axes = ()
        kv_seq_axis = kv_seq_axis or ("data", "model")
        decode = True
    elif decode:
        batch_axes = ()  # weight-stationary: step activations replicated
        if kv_seq_axis is None:
            # KV seq over 'model' (KV heads are NOT shardable in general —
            # qwen1.5 has 20, glm4 has 2)
            kv_seq_axis = ("model",)
    rules: Dict[str, Any] = {
        # ---- weights ----
        "fsdp": fsdp,
        "tensor": "model",
        "vocab": "model",
        "expert": "model",
        "layer": None,  # scan-stack dim
        None: None,
        # ---- activations ----
        "act_batch": batch_axes,
        "act_kv_batch": kv_batch_axes,  # decode caches/recurrent states
        "act_seq": None,
        # saved remat boundaries: seq-sharded over 'model' (Megatron-SP style)
        # so stored residuals are not replicated across the TP axis
        "act_res_seq": "model",
        "act_kv_seq": kv_seq_axis,
        "act_embed": None,
        "act_heads": "model" if heads_tp else None,
        # attention weights: replicated across 'model' by default (zero QKV
        # collectives; the weights are small next to FFN/experts).  The
        # autotuner can set this to 'model' (hd-sharding) for memory-starved
        # f32-param archs — measured on qwen1.5: hd-sharding costs 3× in
        # per-use activation gathers, so bf16 storage is preferred instead.
        "attn_hd": None,
        "act_kv_heads": None,
        "act_ff": "model",
        "act_vocab": "model",
        "act_expert": "model",
    }
    return rules


def make_serve_rules(mesh: Mesh) -> Dict[str, Any]:
    """KV-head tensor-parallel rules for the ragged serving engine.

    The paged KV pools (kp/vp and int8 scale pools ks/vs) split along the
    KV-head axis over the mesh's TP axis; everything else — block tables,
    per-slot positions, recurrent states, activations, weights — stays
    replicated, so the engine's host-side bookkeeping (PagePool, scheduler,
    pack vectors) is device-count-agnostic and only attention's per-head
    work shrinks per device.  Attention outputs are constrained back to
    replicated before the output projection, making every collective an
    exact all-gather (token-identical to the 1-device engine)."""
    ax = "model" if "model" in mesh.axis_names else mesh.axis_names[-1]
    rules = make_rules(mesh, decode=True)
    rules.update({
        "act_kv_seq": None,  # heads, not sequence, carry the split here
        "act_kv_heads": ax,
        "act_kv_batch": (),  # ptab/kpos/slen replicated: global bookkeeping
        "tensor": None,  # recurrent-state carries stay replicated
        "act_ff": None,
        "act_vocab": None,
        "act_expert": None,
        "vocab": None,
        "expert": None,
        "fsdp": None,  # serving params are replicated (weight-stationary)
    })
    return rules


def logical_spec(names: Sequence[Optional[str]], rules=None) -> P:
    rules = rules if rules is not None else current_rules()
    out = []
    for n in names:
        r = rules.get(n, None) if n is not None else None
        out.append(r)
    return P(*out)


def sanitize_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop mesh axes from dims they don't divide (replicate instead).

    pjit argument shardings require exact divisibility; model dims like a
    4/3-projection d_ff or tiny head counts can be indivisible by an axis.
    Axes are dropped right-to-left until the dim divides.
    """
    out = []
    for i, entry in enumerate(tuple(spec)):
        if entry is None:
            out.append(None)
            continue
        axes = list(entry) if isinstance(entry, tuple) else [entry]
        def tot(ax):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        while axes and shape[i] % tot(axes) != 0:
            axes.pop()
        if not axes:
            out.append(None)
        elif len(axes) == 1:
            out.append(axes[0])
        else:
            out.append(tuple(axes))
    return P(*out)


def lshard(x, *names: Optional[str]):
    """Constrain ``x`` to the logical axes ``names`` (no-op outside a mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    spec = logical_spec(names)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter layouts, derived from leaf names

# leaf name -> logical axes of the *unstacked* leaf (scan adds a "layer" dim)
PARAM_AXES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings / heads
    "tok_embed": ("vocab", "fsdp"),
    "pos_embed": (None, None),
    "out_head": ("fsdp", "vocab"),
    "frontend_proj": (None, "fsdp"),
    # norms
    "scale": (None,),
    "bias": (None,),
    # attention (grouped layout; exactly one of "act_heads"/"attn_hd" maps
    # to 'model' depending on the heads-TP rule)
    "wq": ("fsdp", None, "act_heads", "attn_hd"),
    "wk": ("fsdp", None, "attn_hd"),
    "wv": ("fsdp", None, "attn_hd"),
    "wo": (None, "act_heads", "attn_hd", "fsdp"),
    "bq": (None, "act_heads", "attn_hd"),
    "bk": (None, "attn_hd"),
    "bv": (None, "attn_hd"),
    # dense mlp
    "w_gate": ("fsdp", "tensor"),
    "w_up": ("fsdp", "tensor"),
    "w_down": ("tensor", "fsdp"),
    # moe
    "router": ("fsdp", None),
    "we_gate": ("expert", "fsdp", None),
    "we_up": ("expert", "fsdp", None),
    "we_down": ("expert", None, "fsdp"),
    # mamba
    "in_proj": ("fsdp", "tensor"),
    "conv_w": (None, "tensor"),
    "conv_b": ("tensor",),
    "x_proj": ("tensor", None),
    "dt_w": (None, "tensor"),
    "dt_b": ("tensor",),
    "A_log": ("tensor", None),
    "ssm_D": ("tensor",),
    "out_proj": ("tensor", "fsdp"),
    # xlstm
    "up_proj": ("fsdp", "tensor"),
    "xq": ("tensor", None),
    "xk": ("tensor", None),
    "xv": ("tensor", None),
    "wi": ("tensor", None),
    "wf": ("tensor", None),
    "bi": (None,),
    "bf": (None,),
    "wo_gate": ("tensor", None),
    "down_proj": ("tensor", "fsdp"),
    "w_ifzo": ("fsdp", "tensor"),
    "r_ifzo": (None, "tensor"),
    "b_ifzo": ("tensor",),
    "skip_scale": (None,),
}


def _leaf_spec(path, leaf, rules, mesh=None) -> P:
    name = None
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            name = p.key
            break
    if name is None or name not in PARAM_AXES:
        raise ValueError(f"no sharding rule for param leaf at path {path}")
    axes = PARAM_AXES[name]
    ndim = len(leaf.shape)
    if ndim == len(axes) + 1:  # scan-stacked
        axes = ("layer",) + axes
    elif ndim != len(axes):
        raise ValueError(
            f"param {name} has ndim {ndim}, rule expects {len(axes)} (+1 stacked)"
        )
    spec = logical_spec(axes, rules)
    if mesh is not None:
        spec = sanitize_spec(spec, leaf.shape, mesh)
    return spec


def param_specs(params, mesh: Optional[Mesh] = None, rules=None):
    """PartitionSpec pytree for a params pytree (works on ShapeDtypeStructs)."""
    mesh = mesh or current_mesh()
    rules = rules if rules is not None else (current_rules() or (make_rules(mesh) if mesh else {}))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _leaf_spec(path, leaf, rules, mesh), params
    )


def constrain_like_params(tree):
    """with_sharding_constraint a (grad) tree to the params' sharding rules.

    Without this, GSPMD is free to keep gradients replicated across the
    'model' axis through the whole backward + optimizer (measured: 48 GiB/dev
    of replicated grads on jamba-398b).  No-op outside a mesh.
    """
    mesh = current_mesh()
    if mesh is None:
        return tree
    specs = param_specs(tree, mesh)
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, NamedSharding(mesh, s)),
        tree, specs, is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, P))


def param_shardings(params, mesh: Optional[Mesh] = None, rules=None):
    mesh = mesh or current_mesh()
    specs = param_specs(params, mesh, rules)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))
