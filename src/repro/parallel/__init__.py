from repro.parallel.sharding import (  # noqa: F401
    axis_rules,
    lshard,
    logical_spec,
    make_rules,
    param_specs,
    use_mesh,
    current_mesh,
)
