"""FaultInjector — deterministic, seed-driven fault source for chaos runs.

The paper's result is a STABILITY claim: one set of system settings keeps
every oversubscribed workload mix near peak, degrading smoothly where an
untuned system collapses.  Claims like that are only believable when the
system is exercised OFF the happy path — so the engine takes a
``fault_injector=`` hook and this module supplies the faults:

- **alloc_fail** — the tick admits nothing (and preempts nothing): models
  a transient allocator stall.  Queued work waits; nothing breaks.
- **cancel** — one live or queued request is killed with a typed
  ``Cancelled`` (``serve.errors``): models clients disappearing mid-flight.
- **evict_storm** — the host cache tier is wiped (``PagePool.
  storm_host_cache``): models losing the second tier wholesale.  PARKED
  pages survive by construction — preempted live state is not cache — so a
  storm costs re-promotion and re-prefill time, never tokens.
- **stall** — the engine does nothing for a tick while the clock (and
  every deadline) advances: models a hiccup in the serving loop itself.

Determinism is the whole design: every draw is keyed by ``(seed, tick)``
with a FRESH generator per tick, so a fault schedule is a pure function of
the seed and replays identically however many times a tick's faults are
consulted — a failing chaos run is reproducible from its seed alone.  The
``log`` records every injected fault as ``(tick, kind, detail)`` so tests
can assert a schedule actually fired.

Usage::

    eng = ServeEngine(params, cfg, ...,
                      fault_injector=FaultInjector(seed=7, p_cancel=0.02,
                                                   p_alloc_fail=0.1))

``tests/test_chaos.py`` drives random interleavings under injection and
holds the line on the robustness contract: zero leaked pages on both
tiers, token-identical transcripts for every request that completes.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

__all__ = ["FaultInjector"]


class FaultInjector:
    """Seed-driven per-tick fault drawer (see module docstring).

    Each ``p_*`` is an independent per-tick probability in [0, 1];
    ``window`` optionally restricts injection to ticks in
    ``[start, stop)`` so a scenario can aim its fault wave at the loaded
    phase of a run."""

    def __init__(self, seed: int = 0, *, p_alloc_fail: float = 0.0,
                 p_cancel: float = 0.0, p_evict_storm: float = 0.0,
                 p_stall: float = 0.0, start_tick: int = 0,
                 stop_tick: Optional[int] = None):
        for name, p in (("p_alloc_fail", p_alloc_fail),
                        ("p_cancel", p_cancel),
                        ("p_evict_storm", p_evict_storm),
                        ("p_stall", p_stall)):
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"{name} must be in [0, 1], got {p}")
        self.seed = int(seed)
        self.p_alloc_fail = float(p_alloc_fail)
        self.p_cancel = float(p_cancel)
        self.p_evict_storm = float(p_evict_storm)
        self.p_stall = float(p_stall)
        self.start_tick = int(start_tick)
        self.stop_tick = stop_tick
        self.log: List[tuple] = []  # (tick, kind, detail)

    def faults(self, tick: int, live_uids: Sequence[int]) -> Dict:
        """Draw tick ``tick``'s faults: {"alloc_fail": bool, "cancel":
        Optional[uid], "evict_storm": bool, "stall": bool}.  The cancel
        target is drawn uniformly from ``live_uids`` (sorted first, so the
        draw is independent of the caller's iteration order)."""
        out: Dict = {"alloc_fail": False, "cancel": None,
                     "evict_storm": False, "stall": False}
        if tick < self.start_tick or (self.stop_tick is not None
                                      and tick >= self.stop_tick):
            return out
        # fresh generator per tick: the schedule is a pure function of
        # (seed, tick) — replayable, and immune to consultation order
        rng = np.random.default_rng((self.seed, tick))
        if rng.random() < self.p_alloc_fail:
            out["alloc_fail"] = True
            self.log.append((tick, "alloc_fail", None))
        # draw unconditionally: the stall/storm draws below must not shift
        # with how many requests happen to be live this tick
        cancel_roll, pick_roll = rng.random(), rng.random()
        uids = sorted(int(u) for u in live_uids)
        if uids and cancel_roll < self.p_cancel:
            out["cancel"] = uids[int(pick_roll * len(uids))]
            self.log.append((tick, "cancel", out["cancel"]))
        if rng.random() < self.p_evict_storm:
            out["evict_storm"] = True
            self.log.append((tick, "evict_storm", None))
        if rng.random() < self.p_stall:
            out["stall"] = True
            self.log.append((tick, "stall", None))
        return out
