"""Typed serving errors — the failure vocabulary of the engine.

Robust serving needs failures to be part of the API, not stack traces: a
client must be able to tell "your request can never fit" from "the engine
is momentarily full" from "your deadline passed" and react differently to
each.  Every class here still subclasses the builtin its pre-typed
predecessor raised (``ValueError`` for the submit-time rejections,
``TimeoutError`` for deadline/drain expiry), so existing ``except`` blocks
keep working while new clients can catch the precise type.

- ``RequestTooLarge`` — the request's page footprint exceeds the TOTAL
  pool, or its token span exceeds ``cache_len``: no amount of waiting,
  eviction, or preemption can ever admit it, so ``submit`` rejects it up
  front instead of letting it deadlock admission forever.
- ``EngineOverloaded`` — backpressure: the bounded admission queue
  (``ServeEngine(max_queue=)``) is full.  Transient — the caller should
  shed load or retry later; nothing about the request itself is wrong.
- ``DeadlineExceeded`` — the request's ``deadline_ticks`` budget elapsed
  before it completed; the engine aborted it (partial output preserved on
  the exception and the request record).
- ``Cancelled`` — the engine cancelled the request (fault injection, an
  administrative abort); raised from ``result()``/``tokens()`` so a
  consumer never mistakes an engine-side abort for normal completion.
  A CLIENT-initiated ``handle.cancel()`` keeps the historical contract
  instead: ``result()`` returns the partial output without raising.
"""
from __future__ import annotations

from typing import List, Optional

__all__ = ["ServeError", "RequestTooLarge", "EngineOverloaded",
           "DeadlineExceeded", "Cancelled"]


class ServeError(Exception):
    """Base class for every typed serving failure."""


class RequestTooLarge(ServeError, ValueError):
    """The request can NEVER be admitted (footprint exceeds the pool or
    the cache): rejected at ``submit`` time, before it takes a queue slot."""


class EngineOverloaded(ServeError, RuntimeError):
    """The bounded admission queue is full — shed load or retry later."""


class _AbortError(ServeError, TimeoutError):
    """Shared shape of engine-side aborts: carries the partial output."""

    def __init__(self, msg: str, tokens: Optional[List[int]] = None):
        super().__init__(msg)
        self.tokens = list(tokens) if tokens is not None else []


class DeadlineExceeded(_AbortError):
    """The request's ``deadline_ticks`` elapsed before completion; the
    engine aborted it.  ``.tokens`` holds what was generated in time."""


class Cancelled(_AbortError):
    """The ENGINE cancelled the request (fault injection, administrative
    abort).  ``.tokens`` holds the partial output."""
