"""Request lifecycle — the serving stack's client-facing layer.

``Request`` is the engine-internal record of one generation job;
``RequestHandle`` is what ``ServeEngine.submit`` returns to the caller: a
streaming, cancellable view of that job.

The handle subclasses ``int`` and IS the request uid — it hashes, compares,
sorts, and formats exactly like the integer ids the engine has always
returned, so every existing driver (``results[uid]``, ``sorted(uids)``,
``f"req {uid:3d}"``) keeps working unchanged while new clients get the
streaming surface:

- ``handle.tokens()`` — incremental iteration: yields each generated token
  as it is emitted, driving ``engine.tick()`` whenever it starves (the
  engine stays a pull-based, single-threaded tick loop — no background
  thread, no queue; a tick serves EVERY live request, so concurrent
  iterators interleave fairly).
- ``handle.cancel()`` — releases the request mid-flight: a queued request
  is dequeued; an admitted one has its slot freed and its page refcounts
  dropped.  Refcount-safe by construction: shared prefix pages survive as
  long as any sibling (or the prefix index) still holds them, and the
  cancelled request's own indexed prompt pages stay resident as cache.
- ``handle.done`` / ``handle.result()`` — completion flag and a blocking
  drain (ticks until this request finishes; other requests make progress
  on the same ticks).  ``result(timeout_ticks=)`` bounds the drain, and
  engine-side aborts surface as TYPED exceptions (``serve.errors``): a
  request whose ``deadline_ticks`` elapsed raises ``DeadlineExceeded``, a
  fault-injected/administrative abort raises ``Cancelled`` — never a hang,
  never a silently-truncated token list.  A CLIENT-initiated
  ``handle.cancel()`` keeps the historical contract: ``result()`` returns
  the partial output.

See ``examples/serve_stream.py`` for the end-to-end streaming client,
including the cancel-on-timeout pattern.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray  # (S,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # sampling (serve.engine only; the reference engine is greedy-only):
    # temperature == 0 -> greedy argmax; seed defaults to uid at submit
    temperature: float = 0.0
    top_k: Optional[int] = None
    seed: Optional[int] = None
    # scheduling class (serve.scheduler.SloScheduler): higher admits/packs
    # first; priority >= 1 is the interactive class, 0 the batch default
    priority: int = 0
    cancelled: bool = False
    # absolute engine tick by which the request must COMPLETE (None = no
    # deadline); set by ``submit(deadline_ticks=)`` relative to the tick
    # counter at submission.  An expired request aborts with a typed
    # ``DeadlineExceeded`` recorded in ``error``.
    deadline_tick: Optional[int] = None
    # engine-side abort cause (serve.errors.DeadlineExceeded / Cancelled);
    # raised by RequestHandle.result()/tokens().  None for normal
    # completion and for client-initiated cancels.
    error: Optional[Exception] = None


class RequestHandle(int):
    """Streaming handle for one submitted request (see module docstring).

    Immutable-int identity (the uid) plus a live reference to the engine
    and its ``Request`` record; all state lives on those — the handle adds
    no bookkeeping of its own."""

    def __new__(cls, req: Request, engine) -> "RequestHandle":
        h = super().__new__(cls, req.uid)
        h._req = req
        h._engine = engine
        return h

    def __reduce__(self):
        # pickle / copy.deepcopy degrade to the plain uid int: the engine
        # reference is process-local, and pre-handle drivers that shipped
        # submit()'s return value across process or cache boundaries were
        # shipping exactly this int
        return (int, (int(self),))

    def __repr__(self) -> str:
        state = ("cancelled" if self._req.cancelled else
                 "done" if self._req.done else "live")
        return (f"RequestHandle(uid={int(self)}, {state}, "
                f"tokens={len(self._req.out_tokens)})")

    # -- state ------------------------------------------------------------
    @property
    def uid(self) -> int:
        return int(self)

    @property
    def request(self) -> Request:
        return self._req

    @property
    def done(self) -> bool:
        """True once the engine will emit no more tokens for this request
        (completed, cancelled, or drained by a truncated ``run()``)."""
        return self._req.done

    @property
    def cancelled(self) -> bool:
        return self._req.cancelled

    # -- streaming --------------------------------------------------------
    def tokens(self, max_ticks: int = 65536) -> Iterator[int]:
        """Yield this request's generated tokens as they are emitted,
        ticking the engine whenever no new token is buffered yet.

        Safe to interleave with other handles' iterators, ``tick()``, and
        ``submit()`` — every tick advances ALL live requests, and the
        iterator replays tokens emitted while it wasn't being consumed.
        Stops at ``done`` (EOS / max_tokens / cancel); ``max_ticks`` bounds
        the total engine ticks this iterator may drive.  An engine-side
        abort (deadline expiry, fault-injected cancel) raises its typed
        cause (``serve.errors``) after the partial tokens were yielded."""
        i = 0
        while True:
            while i < len(self._req.out_tokens):
                yield self._req.out_tokens[i]
                i += 1
            if self._req.done:
                if self._req.error is not None:
                    raise self._req.error
                return
            if max_ticks <= 0:
                raise TimeoutError(
                    f"request {int(self)} incomplete after the iterator's "
                    f"tick budget")
            self._engine.tick()
            max_ticks -= 1

    def result(self, max_ticks: int = 65536, *,
               timeout_ticks: Optional[int] = None) -> List[int]:
        """Drain until this request is done; returns its generated tokens
        (the partial list if it was cancelled by ``handle.cancel()``).

        ``timeout_ticks`` bounds the drain: if the engine hasn't finished
        this request within that many ticks (stalled, overloaded, or simply
        never admitting it), ``TimeoutError`` is raised instead of blocking
        indefinitely.  Engine-side aborts raise their typed cause
        (``serve.errors.DeadlineExceeded`` / ``Cancelled``), each carrying
        the partial output on ``.tokens``."""
        budget = timeout_ticks if timeout_ticks is not None else max_ticks
        for _ in self.tokens(max_ticks=budget):
            pass
        return list(self._req.out_tokens)

    def cancel(self) -> bool:
        """Stop this request now and release what it holds (module
        docstring has the refcount story).  Returns True if there was
        anything to cancel — False for an already-finished request."""
        return self._engine.cancel(self)
