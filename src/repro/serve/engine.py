"""Scalable serving engine: chunked batched prefill + paged KV slots.

The paper's serving-time analogue of the Nproc×Nthread sweep needs one
engine that stays near peak across any mix of concurrent users and prompt
lengths.  The seed engine (now ``reference.ReferenceEngine``) could not
express that: batch-1 prefills (one compile per prompt length), lock-step
positions, and per-slot ``cache_len`` KV.  This engine replaces all three:

- **Chunked, batched prefill** — every slot with outstanding prompt tokens
  advances by one fixed-size chunk per prefill tick, all slots in a single
  jit'd ``(B, chunk)`` call with per-slot positions and validity masks.
  Prompts are padded to chunk multiples; long prompts span several ticks, so
  prefill work interleaves with decode instead of stalling the whole pool.
  Exactly two programs are ever compiled — ``(B, chunk)`` prefill and
  ``(B, 1)`` decode — independent of traffic.
- **Paged KV slots** — global-attention KV lives in a page pool indexed by
  per-slot block tables (``models.layers.attention.init_paged_cache``).  A
  request pins only ``ceil((len + max_tokens) / page_size)`` pages, reserved
  at admission (no mid-flight OOM), so the engine admits ``batch_size``
  slots against a smaller physical budget and queues FIFO when the pool is
  exhausted.  Windowed layers keep per-slot circular buffers (bounded KV).
- **Host/device split** — the page allocator and block tables are host-side
  numpy (the vLLM control-plane split); the device only ever sees dense
  arrays, so the whole state remains a shardable pytree.

Greedy decode is token-identical to the reference engine on equal-length
waves, and to a solo batch-1 run on any mix (tests/test_serve.py).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.serve.reference import Request


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    fill: int = 0  # prompt tokens written so far
    pos: int = 0  # next absolute write position (== len(prompt) at decode)
    last_tok: int = 0


class ServeEngine:
    def __init__(self, params, cfg: ModelCfg, *, batch_size: int = 4,
                 cache_len: int = 256, page_size: int = 16,
                 max_pages: Optional[int] = None, prefill_chunk: int = 32,
                 greedy: bool = True, flash_decode: bool = False):
        if not greedy:
            raise NotImplementedError("sampling: greedy only for now")
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.cache_len = cache_len
        self.page_size = page_size
        self.chunk = prefill_chunk
        self.pps = -(-cache_len // page_size)  # block-table width
        self._has_paged = any(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        self.n_pages = (max_pages if max_pages is not None
                        else batch_size * self.pps)
        self._free: List[int] = list(range(self.n_pages))
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * batch_size
        self._uid = 0
        self.completion_order: List[int] = []
        self.stats = {"chunk_ticks": 0, "decode_ticks": 0, "ticks": 0,
                      "pages_in_use_peak": 0}

        # donate the state: the page pools dominate the pytree and must be
        # updated in place, not copied, on every tick of the hot loop
        step = lambda wl: (lambda p, s, t, qp, v: M.paged_step(
            p, cfg, s, t, qp, v, with_logits=wl, flash_decode=flash_decode))
        self._chunk_step = jax.jit(step(False), donate_argnums=(1,))
        self._decode_step = jax.jit(step(True), donate_argnums=(1,))
        self._reset = jax.jit(
            lambda s, s0, m, rows: M.reset_paged_slots(cfg, s, s0, m, rows),
            donate_argnums=(0,))

    def submit(self, prompt, max_tokens: int = 16, eos_id=None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_tokens > self.cache_len:
            raise ValueError(
                f"len(prompt)+max_tokens = {prompt.size + max_tokens} "
                f"exceeds cache_len={self.cache_len}")
        self._uid += 1
        req = Request(self._uid, prompt, max_tokens, eos_id)
        need = self._pages_needed(req)
        if need > self.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool has only "
                f"{self.n_pages} (raise max_pages or shrink the request)")
        self.queue.append(req)
        return self._uid

    # -- internals --------------------------------------------------------
    def _pages_needed(self, req: Request) -> int:
        if not self._has_paged:
            return 0
        return -(-(len(req.prompt) + req.max_tokens) // self.page_size)

    def _admit(self, state):
        """FIFO admission: a request enters a free slot only when its whole
        page reservation fits (no mid-flight OOM, no reordering)."""
        mask = np.zeros(self.B, bool)
        rows = np.full((self.B, self.pps), self.n_pages, np.int32)
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            need = self._pages_needed(self.queue[0])
            if need > len(self._free):
                break  # strict FIFO: head of line waits for pages
            req = self.queue.popleft()
            pages = [self._free.pop() for _ in range(need)]
            rows[b, :need] = pages
            self.slots[b] = _Slot(req, pages)
            mask[b] = True
        if mask.any():
            in_use = self.n_pages - len(self._free)
            self.stats["pages_in_use_peak"] = max(
                self.stats["pages_in_use_peak"], in_use)
            state = self._reset(state, self._template, mask, rows)
        return state

    def _prefill_tick(self, state):
        """Advance every slot with outstanding prompt tokens by one chunk —
        a single batched (B, chunk) call with per-slot positions."""
        C = self.chunk
        tokens = np.zeros((self.B, C), np.int32)
        q_pos = np.zeros((self.B, C), np.int32)
        valid = np.zeros((self.B, C), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.req.prompt)
            if s.fill >= L:
                continue
            n = min(C, L - s.fill)
            tokens[b, :n] = s.req.prompt[s.fill:s.fill + n]
            q_pos[b] = s.fill + np.arange(C)
            valid[b, :n] = True
            s.fill += n
            if s.fill >= L:
                # decode resumes from the last prompt token at position L
                # (same scheme as the reference engine, for token identity)
                s.pos = L
                s.last_tok = int(s.req.prompt[-1])
        _, state = self._chunk_step(self.params, state, tokens, q_pos, valid)
        self.stats["chunk_ticks"] += 1
        return state

    def _decode_tick(self, state):
        tokens = np.zeros((self.B, 1), np.int32)
        q_pos = np.zeros((self.B, 1), np.int32)
        valid = np.zeros((self.B, 1), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            tokens[b, 0] = s.last_tok
            q_pos[b, 0] = s.pos
            valid[b, 0] = True
        logits, state = self._decode_step(self.params, state, tokens, q_pos,
                                          valid)
        nxt = np.asarray(jax.numpy.argmax(logits[:, -1], axis=-1))
        self.stats["decode_ticks"] += 1
        results = {}
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            tok = int(nxt[b])
            req = s.req
            req.out_tokens.append(tok)
            s.pos += 1
            if (len(req.out_tokens) >= req.max_tokens
                    or (req.eos_id is not None and tok == req.eos_id)):
                results[req.uid] = req.out_tokens
                self.completion_order.append(req.uid)
                self._free.extend(s.pages)
                self.slots[b] = None
            else:
                s.last_tok = tok
        return state, results

    def run(self, max_ticks: int = 4096) -> Dict[int, List[int]]:
        """Drain the queue; returns {uid: generated tokens}."""
        state = M.init_paged_state(self.params, self.cfg, self.B,
                                   self.cache_len, page_size=self.page_size,
                                   n_pages=self.n_pages,
                                   window_extra=self.chunk - 1)
        # the reset template must not alias the (donated) live state
        self._template = jax.tree.map(jax.numpy.copy, state)
        results: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            if all(s is None for s in self.slots) and not self.queue:
                break
            state = self._admit(state)
            if any(s is not None and s.fill < len(s.req.prompt)
                   for s in self.slots):
                state = self._prefill_tick(state)
            elif any(s is not None for s in self.slots):
                state, done = self._decode_tick(state)
                results.update(done)
            self.stats["ticks"] += 1
        # drain partials on tick-budget exhaustion, releasing slots/pages so
        # the engine stays reusable (no page leak, no stale decode state);
        # never-admitted requests report their (empty) partials too, so every
        # submitted uid is present in the result
        for b, s in enumerate(self.slots):
            if s is not None:
                results[s.req.uid] = s.req.out_tokens
                self._free.extend(s.pages)
                self.slots[b] = None
        while self.queue:
            req = self.queue.popleft()
            results[req.uid] = req.out_tokens
        return results
