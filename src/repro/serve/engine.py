"""ServeEngine — the orchestration layer of a three-layer serving stack.

The paper's core result is that ONE set of system settings (KMP_AFFINITY +
taskset + all2all **cache** mode) keeps every (Nproc × Nthread)
factorization near practical peak — because the SETTINGS layer (how memory
is managed) and the WORKLOAD layer (how users factor their work) are
cleanly separable knobs, and only the former needs global tuning.  The
serving stack is now built on exactly that separation:

- **Settings layer — `serve.pool.PagePool`**: page allocation, refcounts,
  the prefix trie, copy-on-write matching, LRU eviction, and the
  byte-denominated budget (``kv_dtype``: float32 | bfloat16 | int8 — PR 4's
  memory-representation knob, the analogue of the paper's decisive memory
  mode).  Set once per engine; identical beneath every policy.
- **Workload layer — `serve.scheduler`**: admission order and pack order
  are a pluggable policy object (``scheduler=``): ``FifoScheduler``
  (default — bit-identical to the PR 1–4 engine), ``PrefixAwareScheduler``
  (bounded-window reordering so requests sharing a cached or in-flight
  prefix land in the same wave), ``SloScheduler`` (interactive-vs-batch
  classes via ``Request.priority``).  Policies return ORDERINGS only; the
  engine keeps the mechanism, so every policy inherits the
  no-mid-flight-OOM and single-trace guarantees.
- **Client layer — `serve.handle.RequestHandle`**: ``submit()`` returns an
  int-compatible streaming handle — ``handle.tokens()`` iterates tokens
  incrementally (driving ``tick()`` on starvation), ``handle.cancel()``
  releases the request's suffix pages mid-flight (refcount-safe: shared
  prefix pages survive for siblings and the cache), ``handle.done`` /
  ``handle.result()`` complete the lifecycle.  ``run()``/``tick()`` batch
  drivers are unchanged.  End-to-end client: ``examples/serve_stream.py``.

What the orchestrator itself still owns is the device contract (unchanged
from PR 1–4, and the reason any policy mix stays near peak):

- **One compiled program** (PR 2): each tick packs a fixed token budget
  ``T`` (``token_budget``) with decode tokens FIRST (a decoding slot emits
  every tick; prefill never stalls it) and prefill chunks (≤
  ``prefill_chunk`` per slot) in the leftover budget, driving a single
  jit'd ``(T,)`` ragged step (``serve_step.make_ragged_step``) with
  per-token (slot, position, validity) vectors.  The mix — and now the
  policy ordering it — is pure data, so exactly ONE program is ever traced
  (``stats["traces"]``).
- **One resident working set** (PR 3): the paged KV pool doubles as a
  refcounted, copy-on-write prefix cache — thousands of requests sharing a
  system prompt read it from resident pages instead of re-prefilling (the
  "all2all cache mode" of the engine).  Match/index/evict policy lives in
  the pool; the engine runs the control-plane programs (COW page copy,
  slot reset, and the tiered page movers below) the pool's decisions
  require.
- **A second tier under pressure** (``host_pages=`` — this PR, the paper's
  MCDRAM cache mode made literal): eviction DEMOTES refcount-0 prefix
  pages to a host-RAM tier instead of dropping them — trie entry and int8
  scale rows intact — and a prefix hit on a host-resident page PROMOTES it
  back, the scatter issued at admission so jax async dispatch overlaps the
  copy with the tick's compute.  Only a miss in BOTH tiers re-prefills.
  The pool decides which pages move (``serve.pool`` events, drained in
  chronological order before any other device mutation of the round); the
  engine owns the bytes: one jitted gather and one donated jitted scatter
  (``serve_step.make_page_gather`` / ``make_page_insert``), host storage a
  plain dict of numpy pages.  A promoted slot is packed from the NEXT tick
  (``_Slot.ready_tick``) — the overlap window — while data dependency
  through the donated state keeps any schedule correct; transcripts stay
  token-identical to the untiered engine because packing composition never
  changes sampling (packing-invariant by construction since PR 2).
- **Half-or-better bytes per resident token** (PR 4): int8 pools quantize
  at KV-write time (write-quantize → paged read-dequant → COW-with-scales),
  so the byte-denominated budget holds 2-4× the pages — more concurrent
  decoders and more resident prefix pages from the same memory.
- **More than one accepted token per page-stream** (``spec_k=`` — this PR):
  decode is memory-bound on KV bytes, so once a slot's pages stream for
  its one decode token, verifying k more tokens against that same stream
  is near-free.  The lifecycle is draft → verify → accept/rollback:
  the SpeculativeScheduler wrapper DRAFTS k continuation tokens per
  decoding slot by prompt lookup over the slot's own prompt+output
  history (no second model), the engine packs them at the slot's next
  consecutive positions in the leftover token budget (decode-first and
  prefill priority are untouched — speculation is just a packing policy)
  and VERIFIES all chains in the one forward via a (B, 1+spec_k)
  ``logit_idx`` — row j is the model's prediction given the draft prefix
  up to j.  The engine ACCEPTS the longest agreeing prefix plus the
  correction/bonus token sampled from the first disagreeing row, and for
  rejected tails ROLLS BACK kpos/slen via one more control-plane program
  (``serve_step.make_spec_rollback``) so stale rows are dead until
  overwritten.  Per-(request, position) seeded sampling keeps transcripts
  token-identical with speculation on or off at any temperature, and the
  serve-path trace count stays at exactly one.

- **Graceful degradation under pressure (``preempt=`` / ``max_queue=`` /
  ``deadline_ticks=`` / ``fault_injector=`` — this PR)**: the paper's
  headline is not one fast point but STABLE performance — every
  oversubscribed (Nproc × Nthread) mix degrades smoothly instead of
  collapsing, because the settings layer manages contention.  The serving
  analogue is a full failure-handling lifecycle over the same seams:

  * **Slot preemption** — when an admission round leaves the head
    candidate stalled on pages (or a slot) that IN-FLIGHT work holds, and
    the candidate strictly outranks a running request, the scheduler's
    ``preempt_order`` picks a victim decoding slot (default: lowest
    priority, then youngest; Slo-family policies never victimize the
    interactive class).  The victim's private pages — non-indexed,
    refcount-1: its generated tokens and prompt duplicates — PARK to the
    host tier through the same demote-gather machinery as cache demotion
    (``PagePool.park``), its shared prefix pages just drop a refcount, the
    slot frees, and the request re-queues at the head with its generated
    tokens intact.  On re-admission the parked pages promote back
    (``unpark`` — the scatter overlapping the tick like any promotion), or
    — if the park was lost or the cached prefix shrank beneath it — the
    engine RE-PREFILLS from the request's own token history (prompt, the
    position-L handoff duplicate, then every generated token but the
    last) and resumes decoding at its preempted position.  Per-(request,
    ordinal) seeded sampling makes the transcript token-identical either
    way; the movers are the PR 7 gather/scatter and the donated reset, so
    ``stats["traces"]`` stays 1.
  * **Deadlines and backpressure** — ``submit(deadline_ticks=)`` arms an
    absolute completion deadline: an expired request (queued or live)
    aborts with a typed ``DeadlineExceeded`` carrying its partial output;
    ``max_queue=`` bounds the admission queue, failing over-capacity
    submits fast with ``EngineOverloaded``; a request whose footprint can
    NEVER fit rejects at submit with ``RequestTooLarge`` (all in
    ``serve.errors``, each subclassing the builtin its untyped predecessor
    raised).
  * **Fault injection** — ``fault_injector=`` (``serve.chaos.
    FaultInjector``) draws deterministic, seed-keyed faults each tick:
    forced allocation failures (the tick admits nothing), random cancels
    (typed ``Cancelled``), host-tier eviction storms (parks survive; the
    cache tier is lost), and stalled ticks (the clock — and deadlines —
    advance; nothing runs).  Every fault degrades throughput, never
    correctness: completed requests stay token-identical and both tiers
    drain to zero leaked pages (tests/test_chaos.py holds the line).

The PR 1 two-phase path is kept behind ``ragged=False`` for A/B (admission
policy applies there too; pack ordering is a ragged-path concept).
``benchmarks/serve_sweep.py`` carries the engine and scheduler A/Bs;
``core.autotune.select_serve_defaults`` emits the tuned-once serving config
(token_budget × prefill_chunk × page_size × kv_dtype × scheduler).

**Tensor parallelism (``mesh=``)**: the single compiled ragged step shards
over the KV-head axis.  Pool layout: ``kp``/``vp`` pages and their int8
scale pools ``ks``/``vs`` split along their KV-head dim (device d holds
heads ``[d·kvH/N, (d+1)·kvH/N)`` of every page); block tables, positions,
and fill counts replicate.  The contract is strict layering: the HOST
bookkeeping (PagePool / Scheduler / slot state / byte budget) is global and
never sees the device count, while the DEVICE programs run under the serve
mesh rules and keep outputs bit-identical across device counts (the
attention output is replicated before the one head-contracting einsum, so
no device-count-dependent partial-sum order exists).  ``stats`` reports
``kv_shards`` / ``n_devices`` / ``kv_pool_bytes_per_device``.

**Statically gated invariants**: the contracts above — one serve-path
trace, donated in-place pool updates, the page lifecycle, scheduler
protocol conformance — are also PROVEN statically by ``repro.analysis``
(``python -m repro.analysis``; see ``src/repro/analysis/README.md`` for
the rules and suppression syntax), which CI runs on every change.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.serve.errors import (DeadlineExceeded, EngineOverloaded,
                                RequestTooLarge)
from repro.serve.handle import Request, RequestHandle
from repro.serve.pool import (PagePool, _PrefixNode, kv_bytes_per_token,
                              kv_page_bytes)
from repro.serve.scheduler import EngineView, Scheduler, make_scheduler
from repro.serve.serve_step import STATE_DONATE_ARGNUM, make_ragged_step

from repro.core.roofline import KV_ITEMSIZE

__all__ = ["ServeEngine", "kv_page_bytes", "kv_bytes_per_token"]


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    fill: int = 0  # prompt tokens in cache (matched prefix + prefilled)
    pos: int = 0  # next absolute write position (== len(prompt) at decode)
    last_tok: int = 0
    # prefix-cache bookkeeping: the trie node matching the indexed prefix so
    # far (None = this slot's prefix is owned elsewhere, stop indexing) and
    # how many of this slot's leading pages are on that trie chain
    node: Optional[_PrefixNode] = None
    n_indexed: int = 0
    # first tick this slot may be packed: admissions that promoted host-tier
    # pages wait one tick so the promotion copy overlaps the current tick's
    # compute instead of stalling it (correctness never depends on this —
    # the data dependency through the donated state orders the scatter)
    ready_tick: int = 0
    # what prefill actually feeds the pack: the prompt, normally — or, for
    # a preempt-resume that lost its park, the request's replayed history
    # (prompt + position-L handoff duplicate + generated tokens[:-1]),
    # whose length IS the preempted write position
    prefill_tokens: Optional[np.ndarray] = None
    # decode input to resume from when prefill completes (a re-prefilled
    # preemptee resumes from its LAST generated token, not the prompt tail)
    resume_tok: Optional[int] = None

    def __post_init__(self):
        if self.prefill_tokens is None:
            self.prefill_tokens = self.req.prompt


class ServeEngine:
    def __init__(self, params, cfg: ModelCfg, *, batch_size: int = 4,
                 cache_len: int = 256, page_size: int = 16,
                 max_pages: Optional[int] = None, prefill_chunk: int = 32,
                 token_budget: int = 128, greedy: bool = True,
                 ragged: bool = True, flash_decode: bool = False,
                 prefix_cache: bool = True, kv_dtype: Optional[str] = None,
                 scheduler=None, mesh=None, host_pages: int = 0,
                 spec_k: int = 0, preempt: bool = True,
                 max_queue: Optional[int] = None, fault_injector=None):
        self.params = params
        self.cfg = cfg
        # KV-head tensor parallelism (``mesh=`` — a jax.sharding.Mesh, e.g.
        # launch.mesh.make_mesh((N,), ("model",))).  The DEVICE side splits:
        # the paged KV pools (kp/vp and int8 ks/vs) shard along the KV-head
        # axis (serve_step.STATE_AXES), every compiled program runs under
        # ``use_mesh(mesh, make_serve_rules(mesh))``, and the Pallas flash
        # kernels enter through the shard_map wrappers in
        # serve.decode_attention — each device holds and attends over
        # 1/N of each pool page's heads.  The HOST side does NOT split:
        # PagePool, Scheduler, slot bookkeeping, and the page budget are
        # global and device-count-agnostic — page ids name whole logical
        # pages whose bytes happen to live N-ways split, so admission,
        # eviction, prefix sharing, and COW decisions are identical at any
        # device count (the invariance suite asserts token-identical output
        # across 1/2/4 devices).  Layers whose KV-head count the mesh does
        # not divide keep replicated pools (sanitize_spec drops the axis).
        self.mesh = mesh
        self._kv_shards = 1
        self._rules = None
        if mesh is not None:
            from repro.parallel.sharding import make_serve_rules

            self._rules = make_serve_rules(mesh)
            ax = self._rules["act_kv_heads"]
            for a in ((ax,) if isinstance(ax, str) else tuple(ax)):
                self._kv_shards *= mesh.shape[a]
        self.B = batch_size
        self.cache_len = cache_len
        self.page_size = page_size
        self.chunk = prefill_chunk
        self.budget = token_budget
        self.greedy = greedy
        self.ragged = ragged
        # workload-policy layer: admission + pack ordering (None/"fifo" is
        # the PR 1-4 behavior, bit-identical).  Policies that keep the
        # protocol's identity orders (fifo: all three; prefix-aware: the
        # pack pair) let the hot loop skip building per-tick EngineView
        # snapshots and the O(queue) candidate/validation/rebuild work —
        # a deep backlog costs the default policy nothing extra per tick
        self.scheduler = make_scheduler(scheduler)
        # speculative decoding rides the policy layer: ``spec_k=`` wraps the
        # resolved policy in a SpeculativeScheduler (prompt-lookup drafts of
        # depth k), or pass a SpeculativeScheduler as ``scheduler=`` directly
        # — either way the engine reads the depth off the policy object
        from repro.serve.scheduler import SpeculativeScheduler

        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k and not isinstance(self.scheduler, SpeculativeScheduler):
            if not ragged:
                raise ValueError("speculative decoding needs the ragged "
                                 "path (spec_k > 0 with ragged=False)")
            self.scheduler = SpeculativeScheduler(self.scheduler,
                                                  spec_k=spec_k)
        self.scheduler_name = getattr(self.scheduler, "name",
                                      type(self.scheduler).__name__)
        # the fast-path probes must see THROUGH the speculative wrapper: its
        # orderings delegate verbatim, so a wrapped default policy still
        # earns the no-EngineView hot loop
        probe = (self.scheduler.inner
                 if isinstance(self.scheduler, SpeculativeScheduler)
                 else self.scheduler)
        cls = type(probe)
        self._default_admit = (
            getattr(cls, "admission_order", None) is Scheduler.admission_order)
        self._default_pack = (
            getattr(cls, "decode_order", None) is Scheduler.decode_order
            and getattr(cls, "prefill_order", None) is Scheduler.prefill_order)
        # paged-pool storage representation: None follows the activation
        # dtype (the unquantized default); "int8" is the headline — half-or-
        # better bytes per resident token, quantized at KV-write time so
        # prefill, decode, prefix hits, and COW all share one representation
        self.kv_dtype = str(jnp.dtype(kv_dtype or cfg.dtype))
        if self.kv_dtype not in KV_ITEMSIZE:
            raise ValueError(f"unsupported kv_dtype {self.kv_dtype!r} "
                             f"(pick from {sorted(KV_ITEMSIZE)})")
        if ragged and token_budget < batch_size:
            raise ValueError(
                f"token_budget={token_budget} < batch_size={batch_size}: "
                "every decoding slot needs one pack entry per tick")
        self.pps = -(-cache_len // page_size)  # block-table width
        self._has_paged = any(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        # prefix sharing needs EVERY layer's state to live in shareable
        # pages: recurrent mixers and windowed circular buffers are per-slot
        # and cannot be inherited, so hybrids serve with sharing off
        all_global = self._has_paged and all(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        self.prefix_cache = bool(prefix_cache) and all_global
        # speculative decoding has the same applicability gate, for the
        # dual reason: rolling back a rejected draft tail is a kpos/slen
        # metadata edit for paged global attention, but recurrent state and
        # windowed circular buffers advance destructively — there is
        # nothing to roll back to.  Hybrids silently serve unspeculated
        # (same convention as prefix_cache; stats["spec_k"] reports 0).
        self._spec_k = (int(getattr(self.scheduler, "spec_k", 0))
                        if all_global else 0)
        self._draft = getattr(self.scheduler, "draft", None)
        if self._draft is None:
            self._spec_k = 0
        # preemption shares the applicability gate: re-prefill resume
        # replays history through the ragged pack, and a parked page only
        # captures the ENTIRE per-position state when every layer is paged
        # global attention (recurrent / windowed state has no page to park)
        self.preempt = bool(preempt) and ragged and all_global
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1, got {max_queue}")
        self.max_queue = max_queue
        self.fault_injector = fault_injector
        # uid -> park record for preempted requests awaiting re-admission:
        # "slots" (host slots holding pages [page0, page0+len) — None when
        # the park failed and resume must re-prefill), "pos"/"last_tok"
        # (the decode state to resume from)
        self._preempted: Dict[int, dict] = {}
        self._chaos_alloc_fail = False
        # the page budget is a BYTE budget: the default pool spends the same
        # bytes the unquantized (activation-dtype) pool would, so an int8
        # pool holds ~2-4× the pages — more concurrent requests and more
        # refcount-0 prefix-cache pages stay resident before eviction (the
        # serving analogue of fitting the working set into fast memory).
        # Floor: never BELOW the worst-case base_pages — a widening kv_dtype
        # (e.g. a float32 pool on a bfloat16 model) keeps every slot
        # admissible without queueing, at the cost of exceeding the
        # activation-dtype byte budget (visible in stats["kv_pool_bytes"])
        # the budget is priced on GLOBAL (unsharded) page bytes on purpose:
        # a sharded pool's per-device bytes shrink by the shard count, but
        # pricing pages per-device would let n_pages drift with the device
        # count and break the cross-device-count token-identity contract —
        # per-device footprint is reported in stats instead
        base_pages = batch_size * self.pps
        if max_pages is not None:
            self.n_pages = max_pages
        elif self._has_paged:
            ref = kv_page_bytes(cfg, page_size, str(jnp.dtype(cfg.dtype)))
            act = kv_page_bytes(cfg, page_size, self.kv_dtype)
            self.n_pages = max(base_pages, base_pages * ref // max(act, 1))
        else:
            self.n_pages = base_pages
        # memory-settings layer: one pool object owns every page policy.
        # ``host_pages`` > 0 adds the host-RAM tier (the paper's cache
        # mode): eviction demotes instead of dropping, prefix hits promote
        # back; the tier only matters with the prefix cache on — without an
        # index there is nothing matchable to keep warm one tier down
        self.host_pages = host_pages if self.prefix_cache else 0
        self.pool = PagePool(self.n_pages, page_size,
                             index_enabled=self.prefix_cache,
                             host_pages=self.host_pages)
        self._host_store: Dict[int, Dict] = {}  # host tier page bytes
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * batch_size
        self._uid = 0
        self.completion_order: List[int] = []
        self._state = None  # persistent: the pool doubles as the prefix cache
        self._stats = {"chunk_ticks": 0, "decode_ticks": 0, "ragged_ticks": 0,
                       "ticks": 0, "packed_tokens": 0, "traces": 0,
                       "pages_in_use_peak": 0, "admissions": 0,
                       "prefix_hits": 0, "prefix_tokens_reused": 0,
                       "cow_copies": 0, "cancelled": 0,
                       # tiered-cache accounting: admissions that hit the
                       # HOST tier (between warm and cold), and how many
                       # pages those hits promoted back to the device tier
                       "host_hits": 0, "host_pages_promoted": 0,
                       "host_pool_pages": self.host_pages,
                       "scheduler": self.scheduler_name,
                       # speculative-decoding accounting: draft depth in
                       # effect (0 = off/gated-off), draft tokens packed
                       # into verify rows, accepted vs rejected split,
                       # rollback dispatches, and how many (slot, tick)
                       # sampling opportunities there were — emitted tokens
                       # divided by sampled_slot_ticks is the accepted-
                       # tokens-per-tick headline (> 1 only via drafts)
                       "spec_k": self._spec_k, "spec_drafted": 0,
                       "spec_accepted": 0, "spec_rejected": 0,
                       "spec_rollbacks": 0, "sampled_slot_ticks": 0,
                       # robustness accounting: slot preemptions and how
                       # their resumes went (park promoted back vs history
                       # re-prefilled), deadline aborts, backpressure
                       # rejections, and injected-fault counts
                       "preemptions": 0, "resumes": 0,
                       "resume_park_hits": 0, "resume_reprefills": 0,
                       "preempt_pages_parked": 0, "deadline_expired": 0,
                       "overload_rejections": 0, "chaos_alloc_fails": 0,
                       "chaos_cancels": 0, "chaos_evict_storms": 0,
                       "chaos_stalled_ticks": 0,
                       # memory-representation accounting: bytes of paged KV
                       # one token occupies (streams per context token at
                       # decode) and the pool's byte footprint at this dtype
                       "kv_dtype": self.kv_dtype,
                       "kv_bytes_per_token": kv_bytes_per_token(
                           cfg, self.kv_dtype),
                       "kv_pool_bytes": self.n_pages * kv_page_bytes(
                           cfg, page_size, self.kv_dtype),
                       # tensor-parallel accounting: shard count of the
                       # paged pools' KV-head axis and one device's share
                       # of the pool bytes (== kv_pool_bytes at 1 device)
                       "kv_shards": self._kv_shards,
                       "n_devices": (mesh.devices.size
                                     if mesh is not None else 1),
                       "kv_pool_bytes_per_device":
                           self.n_pages * kv_page_bytes(
                               cfg, page_size, self.kv_dtype,
                               self._kv_shards)}
        # per-token / per-tick logs for the latency benchmark:
        # token_log rows are (uid, tick index, wall time); tick_log rows are
        # (had outstanding prefill at tick start, wall time at tick end)
        self.token_log: List[tuple] = []
        self.tick_log: List[tuple] = []

        def _count_traces(fn):
            def wrapper(*a):
                self._stats["traces"] += 1  # python body runs at trace time
                return fn(*a)
            return wrapper

        # donate the state (serve_step.STATE_DONATE_ARGNUM): the KV page
        # pools, int8 scale pools, and recurrent-state carries dominate the
        # pytree and must be updated in place, not copied, on every tick of
        # the hot loop (no-copy contract asserted by pointer identity in
        # tests/test_kv_quant.py)
        donate = (STATE_DONATE_ARGNUM,)
        # width = most tokens one slot contributes to a pack: a prefill
        # chunk plus its handoff decode token, or a decode token plus its
        # spec_k draft chain — whichever is wider (compile-time constant)
        self._ragged_step = jax.jit(
            _count_traces(make_ragged_step(
                cfg, width=max(prefill_chunk + 1, 1 + self._spec_k),
                flash_decode=flash_decode)),
            donate_argnums=donate)
        step = lambda wl: (lambda p, s, t, qp, v: M.paged_step(
            p, cfg, s, t, qp, v, with_logits=wl, flash_decode=flash_decode))
        self._chunk_step = jax.jit(step(False), donate_argnums=donate)
        self._decode_step = jax.jit(step(True), donate_argnums=donate)
        # control-plane programs (admission reset, COW page copy) — separate
        # from the serve path, each traced at most once
        self._reset = jax.jit(
            lambda s, s0, m, rows, plen: M.reset_paged_slots(
                cfg, s, s0, m, rows, plen),
            donate_argnums=(0,))
        self._copy = jax.jit(
            lambda s, src, dst: M.copy_kv_pages(cfg, s, src, dst),
            donate_argnums=(0,))
        # tiered page movers: demotion gather (state stays live) and
        # promotion scatter (state donated, pools update in place); page id
        # is data, so each traces at most once for the engine's lifetime
        from repro.serve.serve_step import (make_page_gather,
                                            make_page_insert,
                                            make_spec_rollback)

        self._gather_page = jax.jit(make_page_gather(cfg))
        self._insert_page = jax.jit(make_page_insert(cfg),
                                    donate_argnums=(0,))
        # speculative rejection: invalidate kpos/slen for rolled-back draft
        # tails (pools/scales untouched); dispatched only on ticks that
        # rejected drafts, traced at most once like the other movers
        self._spec_rollback = jax.jit(make_spec_rollback(cfg),
                                      donate_argnums=(0,))

    # -- public surface ---------------------------------------------------
    def submit(self, prompt, max_tokens: int = 16, eos_id=None, *,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None,
               priority: int = 0,
               deadline_ticks: Optional[int] = None) -> RequestHandle:
        """Queue one request; returns a streaming ``RequestHandle`` (an
        ``int`` subclass carrying the uid, so legacy id-keyed drivers are
        unchanged).  ``priority`` is the scheduling class read by
        ``SloScheduler`` (>= 1 interactive, 0 batch; FIFO ignores it).

        ``deadline_ticks`` arms a completion deadline that many engine
        ticks from now: a request still unfinished when it expires aborts
        with a typed ``DeadlineExceeded`` (partial output attached) raised
        from its handle.  A request whose footprint can NEVER fit rejects
        immediately with ``RequestTooLarge``; with ``max_queue=`` set, an
        over-capacity submit rejects with ``EngineOverloaded`` instead of
        growing the backlog unboundedly (both in ``serve.errors``)."""
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {max_tokens}")
        if prompt.size + max_tokens > self.cache_len:
            raise RequestTooLarge(
                f"len(prompt)+max_tokens = {prompt.size + max_tokens} "
                f"exceeds cache_len={self.cache_len}")
        if temperature is None:
            temperature = 0.0 if self.greedy else 1.0
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        if deadline_ticks is not None and deadline_ticks < 1:
            raise ValueError(
                f"deadline_ticks must be >= 1, got {deadline_ticks}")
        if (self.max_queue is not None
                and len(self.queue) >= self.max_queue):
            self._stats["overload_rejections"] += 1
            raise EngineOverloaded(
                f"admission queue full ({len(self.queue)} >= "
                f"max_queue={self.max_queue}): shed load or retry later")
        self._uid += 1
        req = Request(self._uid, prompt, max_tokens, eos_id,
                      temperature=temperature, top_k=top_k, seed=seed,
                      priority=priority)
        if deadline_ticks is not None:
            req.deadline_tick = self._stats["ticks"] + deadline_ticks
        # admission reserves only the unmatched suffix on a prefix hit, but
        # cache contents churn before this request reaches the head of the
        # queue — validate against the cold-start worst case
        need = self._pages_needed(req)
        if need > self.n_pages:
            raise RequestTooLarge(
                f"request needs {need} pages but the pool has only "
                f"{self.n_pages} (raise max_pages or shrink the request)")
        self.queue.append(req)
        return RequestHandle(req, self)

    def cancel(self, handle_or_uid, *,
               error: Optional[Exception] = None) -> bool:
        """Stop a request and release what it holds.  Queued: dequeued
        before it ever takes pages (a preempted request's parked host pages
        are dropped with it).  Admitted: its slot is freed and its page
        references dropped — shared prefix pages survive for siblings and
        for the cache (refcounted), its own indexed prompt pages stay
        resident as cache, and everything else returns to the free list.
        Returns False (no-op) for finished or unknown requests.

        ``error`` marks an ENGINE-initiated abort (fault injection, an
        administrative kill): it lands on the request record and is raised
        by ``result()``/``tokens()``.  A client ``handle.cancel()`` passes
        no error and keeps the historical partial-return contract."""
        uid = int(handle_or_uid)
        for i, req in enumerate(self.queue):
            if req.uid == uid:
                del self.queue[i]
                self._drop_park_record(uid)
                self._finish_cancel(req, error)
                return True
        for b, s in enumerate(self.slots):
            if s is not None and s.req.uid == uid:
                self._finish_cancel(s.req, error)
                self._release_slot(b)
                return True
        return False

    def _finish_cancel(self, req: Request,
                       error: Optional[Exception]) -> None:
        req.cancelled = req.done = True
        if error is not None:
            if hasattr(error, "tokens") and not error.tokens:
                error.tokens = list(req.out_tokens)
            req.error = error
        self._stats["cancelled"] += 1

    def _drop_park_record(self, uid: int) -> None:
        """Forget a preempted request's park (cancel / deadline / drain):
        free its host slots and, since hevicts need no device state, drain
        them right away when nothing else is pending."""
        rec = self._preempted.pop(uid, None)
        if rec is None or rec["slots"] is None:
            return
        self.pool.drop_parked(rec["slots"])
        if self.pool.events and all(
                ev[0] == "hevict" for ev in self.pool.events):
            for ev in self.pool.drain_events():
                self._host_store.pop(ev[1], None)

    @property
    def stats(self) -> Dict:
        """Engine counters merged with the pool's (read-only snapshot)."""
        return {**self._stats, **self.pool.stats}

    # -- pool passthroughs (PR 1-4 surface; tests and drivers use these) --
    @property
    def _ref(self) -> np.ndarray:
        return self.pool._ref

    @property
    def _free(self) -> List[int]:
        return self.pool._free

    @property
    def cached_pages(self) -> int:
        """Pages currently held by the prefix index."""
        return self.pool.cached_pages

    @property
    def reclaimable_pages(self) -> int:
        """Free pages plus refcount-0 cached pages — the allocator can hand
        all of these out; equals ``n_pages`` whenever no request is live."""
        return self.pool.reclaimable_pages

    def drop_prefix_cache(self) -> int:
        """Discard every refcount-0 cached page in BOTH tiers (A/B runs,
        tests).  Returns the number of device pages returned to the free
        list."""
        n = self.pool.drop_cache()
        for ev in self.pool.drain_events():  # hevicts only: free host bytes
            self._host_store.pop(ev[1], None)
        return n

    def _apply_pool_events(self, state):
        """Apply the pool's tier-traffic log to device state IN ORDER,
        before any other device mutation of the admission round: a demoted
        page's bytes are gathered out BEFORE its freed device page can be
        reused (the free-then-realloc chain inside one round is resolved by
        chronology), a promoted page's bytes scatter into its newly
        allocated device page, an hevicted slot's host bytes are dropped.
        The gather is materialized to numpy (host RAM IS the tier); the
        scatter donates the state, so pools update in place and jax's async
        dispatch overlaps the copy with the tick that follows."""
        for ev in self.pool.drain_events():
            if ev[0] == "demote":
                _, page, slot = ev
                rows = self._gather_page(state, np.int32(page))
                self._host_store[slot] = jax.tree.map(np.asarray, rows)
            elif ev[0] == "promote":
                _, slot, page = ev
                state = self._insert_page(state, self._host_store.pop(slot),
                                          np.int32(page))
            else:  # ("hevict", slot)
                self._host_store.pop(ev[1], None)
        return state

    # -- admission --------------------------------------------------------
    def _pages_needed(self, req: Request, matched_pages: int = 0) -> int:
        """Pages the request must RESERVE: its full footprint minus the
        ``matched_pages`` shared prefix pages it maps instead of allocating."""
        if not self._has_paged:
            return 0
        total = -(-(len(req.prompt) + req.max_tokens) // self.page_size)
        return total - matched_pages

    def _view(self, include_queue: bool = True) -> EngineView:
        # pack-order consultations get an empty queue (documented on
        # EngineView): packing is a slots concern, and copying a deep
        # backlog every tick would tax the hot loop for nothing
        return EngineView(
            queue=tuple(self.queue) if include_queue else (),
            slot_requests=tuple(s.req if s is not None else None
                                for s in self.slots),
            slot_fill=tuple(s.fill if s is not None else 0
                            for s in self.slots),
            budget=self.budget, chunk=self.chunk, page_size=self.page_size,
            match_len=self.pool.probe_prefix_len,
            match_split=self.pool.probe_prefix_split)

    def _pack_order(self, order, slots_in: List[int],
                    fn_name: str) -> List[int]:
        """A pack order must PERMUTE the engine-computed slot list: a
        duplicate would pack (and sample) a slot twice, an omission would
        stall a decoding slot — both break invariants every policy
        inherits, so they fail loudly here instead of corrupting output."""
        order = list(order)
        if sorted(order) != sorted(slots_in):
            raise ValueError(
                f"{self.scheduler_name}: {fn_name} must permute "
                f"{slots_in}, got {order}")
        return order

    def _admission_candidates(self) -> List[Request]:
        """Consult the scheduler for this round's candidate order (indices
        into the queue snapshot), validated to a duplicate-free in-range
        sequence.  The policy proposes; admission still disposes: the
        engine walks candidates in the returned order and STOPS at the
        first whose page demand exceeds supply, so no policy can overcommit
        the pool or bypass the reservation discipline."""
        view = self._view()
        order = list(self.scheduler.admission_order(view))
        n = len(view.queue)
        if len(set(order)) != len(order) or any(
                not (0 <= i < n) for i in order):
            raise ValueError(
                f"{self.scheduler_name}: admission_order returned "
                f"{order!r} for a {n}-deep queue")
        return [view.queue[i] for i in order]

    def _admit_round(self, state):
        """Admit scheduler-ordered queue candidates into free slots while
        the pages each actually needs — its unmatched suffix, after the
        longest-cached-prefix match — fit in free + evictable pages (no
        mid-flight OOM, no starving the admission round on pages a prefix
        hit would never use).  FIFO order reproduces the PR 1-4 strict
        head-of-line behavior bit for bit.

        A PREEMPTED candidate (a ``_preempted`` record exists) re-admits
        one of two ways: its park promotes back — trie pages cover the
        front, unparked pages the middle, fresh pages the tail, and decode
        resumes at the recorded position on the next tick — or, when the
        park was lost or the cached prefix shrank beneath it, it
        re-prefills its replayed history (``_Slot.prefill_tokens``) and
        resumes from its last generated token.  Either way the transcript
        continues token-identically (per-(request, ordinal) seeded
        sampling)."""
        if not self.queue or all(s is not None for s in self.slots):
            return state  # nothing to admit: the policy is not consulted
        mask = np.zeros(self.B, bool)
        rows = np.full((self.B, self.pps), self.n_pages, np.int32)
        plen = np.zeros(self.B, np.int32)
        # unused COW pairs keep the n_pages sentinel: kernels.ops.copy_pages
        # turns them into self-copy no-ops, so the op is one fixed-width trace
        cow_src = np.full(self.B, self.n_pages, np.int32)
        cow_dst = np.full(self.B, self.n_pages, np.int32)
        cow_pins: List[int] = []
        n_cow = 0
        # default (FIFO) admission peeks the queue head and poplefts in
        # O(1) — the PR 1-4 loop verbatim; only a reordering policy pays
        # for the candidate snapshot, validation, and queue rebuild
        cands = None if self._default_admit else self._admission_candidates()
        admitted: set = set()
        ci = 0
        for b in range(self.B):
            if self.slots[b] is not None:
                continue
            if cands is None:
                if not self.queue:
                    continue
                req = self.queue[0]
            else:
                if ci >= len(cands):
                    continue
                req = cands[ci]
            rec = self._preempted.get(req.uid)
            node, mpages, matched, cow = self.pool.match_prefix(req.prompt)
            if rec is not None:
                cow = None  # a resumed request never COWs: its coverage is
                # already decided by the park or its own replayed history
            # a HOST-tier hit is the third candidate class between warm and
            # cold: the pages are matchable but each costs one device page
            # to promote, so they count as demand, not as supply
            n_host = sum(1 for p in mpages if self.pool.is_host(p))
            parked = rec["slots"] if rec is not None else None
            resume_hit = (parked is not None
                          and len(mpages) >= rec["page0"])
            if resume_hit:
                # promote-resume: trie pages cover [0, mp), the park covers
                # [page0, page0+len(parked)) — drop the overlap (the trie's
                # copy wins: no promotion copy for pages both tiers hold),
                # unpark the rest, allocate out to the full footprint
                mp = len(mpages)
                keep = parked[mp - rec["page0"]:]
                ncover = rec["page0"] + len(parked)
                need = -(-(len(req.prompt) + req.max_tokens)
                         // self.page_size) - ncover
                demand = need + len(keep) + n_host
            else:
                need = self._pages_needed(req, matched_pages=len(mpages))
                demand = need + n_host
            if cow is not None and need + n_host > self.pool.available(
                    mpages + [cow[0]]):
                cow = None  # pinning the COW source would leave the pool
                # short one page: forgo the partial-page reuse (it is an
                # optimization; the full-page match alone always fits)
            if demand > self.pool.available(mpages):
                break  # stop at the first infeasible candidate: the pool's
                # reservation discipline outranks any policy's ordering
            if cands is None:
                self.queue.popleft()
            else:
                ci += 1
                admitted.add(req.uid)
            mpages = self.pool.acquire(mpages)  # +1 ref each; promotes
            # host hits (events drained into device state in the epilogue)
            if n_host:
                self._stats["host_hits"] += 1
                self._stats["host_pages_promoted"] += n_host
            if rec is not None:
                self._preempted.pop(req.uid)
                self._stats["resumes"] += 1
            if resume_hit:
                if len(keep) < len(parked):
                    self.pool.drop_parked(parked[:len(parked) - len(keep)])
                pages = mpages + self.pool.unpark(keep) \
                    + self.pool.alloc(need)
                rows[b, :len(pages)] = pages
                plen[b] = rec["pos"]
                self.slots[b] = _Slot(
                    req, pages, fill=len(req.prompt), pos=rec["pos"],
                    last_tok=rec["last_tok"], node=node,
                    n_indexed=len(mpages),
                    # the unpark scatters overlap this tick's compute,
                    # exactly like a host-tier prefix promotion
                    ready_tick=self._stats["ticks"] + 1)
                mask[b] = True
                self._stats["admissions"] += 1
                self._stats["resume_park_hits"] += 1
                if matched:
                    self._stats["prefix_hits"] += 1
                    self._stats["prefix_tokens_reused"] += matched
                continue
            ptoks, rtok = req.prompt, None
            if rec is not None:
                # park lost (or a hole opened between the trie match and
                # the park): abandon what is left and RE-PREFILL the
                # request's replayed history — prompt, the position-L
                # handoff duplicate, then every generated token but the
                # last, whose turn as decode input comes at resume.  Its
                # length IS the preempted write position, so the handoff
                # below lands exactly where the uninterrupted run was.
                if parked is not None:
                    self.pool.drop_parked(parked)
                ptoks = np.concatenate(
                    [req.prompt, req.prompt[-1:],
                     np.asarray(req.out_tokens[:-1], np.int32)])
                rtok = int(rec["last_tok"])
                self._stats["resume_reprefills"] += 1
            if cow is not None:
                self.pool.share([cow[0]])  # pin the COW source vs eviction
                cow_pins.append(cow[0])
            alloc = self.pool.alloc(need)  # arrives refcounted
            if cow is not None:
                cow_src[b], cow_dst[b] = cow[0], alloc[0]
                matched += cow[1]
                n_cow += 1
            pages = mpages + alloc
            rows[b, :len(pages)] = pages
            plen[b] = matched
            s = _Slot(req, pages, fill=matched, node=node,
                      n_indexed=len(mpages),
                      prefill_tokens=ptoks, resume_tok=rtok,
                      # a promotion's scatter overlaps this tick's compute:
                      # hold the slot out of the pack until the next tick
                      ready_tick=(self._stats["ticks"] + 1 if n_host
                                  else self._stats["ticks"]))
            if matched >= len(ptoks):
                # whole prompt cached: straight to decode, same resume
                # scheme as a completed prefill (last token, position L)
                s.pos = len(ptoks)
                s.last_tok = int(ptoks[-1])
            self.slots[b] = s
            mask[b] = True
            self._stats["admissions"] += 1
            if matched:
                self._stats["prefix_hits"] += 1
                self._stats["prefix_tokens_reused"] += matched
        if mask.any():
            if admitted:
                self.queue = deque(r for r in self.queue
                                   if r.uid not in admitted)
            self._stats["pages_in_use_peak"] = max(
                self._stats["pages_in_use_peak"], self.pool.pages_in_use)
            # tier traffic first: demote gathers must read pages before the
            # COW copy / reset / tick can overwrite them, promote scatters
            # must land in the state the tick consumes
            state = self._apply_pool_events(state)
            if n_cow:
                # device-side ordering is by data dependency (copy feeds the
                # reset feeds the tick), so the host may unpin right away
                state = self._copy(state, cow_src, cow_dst)
                self._stats["cow_copies"] += n_cow
            self.pool.release(cow_pins)
            state = self._reset(state, self._template, mask, rows, plen)
        return state

    # -- preemption -------------------------------------------------------
    def _admit(self, state):
        """Admission with a preemption backstop: when a round leaves the
        head candidate stalled on pages (or a slot) that IN-FLIGHT work
        holds, and the candidate STRICTLY outranks a running victim, the
        victim is preempted and the round re-runs.  Strict priority is the
        anti-thrash rule — equal classes never preempt each other, so two
        starved peers cannot swap one slot forever; it also means the
        default priority-0 world never preempts at all, keeping the PR 1-8
        behavior bit-identical unless the workload opts into classes."""
        if self._chaos_alloc_fail:
            return state  # injected allocation failure: the tick admits
            # nothing (and preempts nothing — a fault starves progress,
            # never correctness)
        state = self._admit_round(state)
        if not self.preempt:
            return state
        for _ in range(self.B):  # each pass frees one slot at most
            cand = self._stalled_candidate()
            if cand is None:
                break
            b = self._pick_victim(cand)
            if b is None:
                break
            state = self._preempt_slot(b, state)
            state = self._admit_round(state)
        return state

    def _stalled_candidate(self) -> Optional[Request]:
        """The first admission candidate left in the queue after a round —
        the request a preemption would be FOR.  None when the queue is
        empty (a non-empty queue after a round means the round could not
        place its head: no free slot, or infeasible page demand)."""
        if not self.queue:
            return None
        if self._default_admit:
            return self.queue[0]
        cands = self._admission_candidates()
        return cands[0] if cands else None

    def _pick_victim(self, cand: Request) -> Optional[int]:
        """A decoding slot whose preemption would let ``cand`` admit.

        Eligible victims decode (mid-prefill work is all still prompt —
        nothing worth parking) and strictly UNDERRANK the candidate; the
        policy's ``preempt_order`` ranks them (and may exempt slots — Slo
        policies drop the interactive class entirely); the first ranked
        victim whose freed pages close the candidate's gap wins.  The
        priority filter runs before any EngineView is built, so workloads
        that never use classes pay O(batch) per stalled tick, not
        O(queue)."""
        tick = self._stats["ticks"]
        victims = [b for b, s in enumerate(self.slots)
                   if s is not None and s.ready_tick <= tick
                   and s.fill >= len(s.prefill_tokens)
                   and s.req.priority < cand.priority]
        if not victims:
            return None
        po = getattr(self.scheduler, "preempt_order", None)
        view = self._view()
        order = list(po(view, victims) if po is not None
                     else Scheduler.preempt_order(self.scheduler, view,
                                                  victims))
        if len(set(order)) != len(order) or any(
                b not in victims for b in order):
            raise ValueError(
                f"{self.scheduler_name}: preempt_order returned {order!r} "
                f"for victims {victims}")
        for b in order:
            if self._admits_after(cand, self.slots[b]):
                return b
        return None

    def _admits_after(self, req: Request, s: _Slot) -> bool:
        """Would preempting ``s`` make ``req`` admissible?  Counts only
        the pages the victim holds as SOLE owner (shared prefix pages
        survive its release) against the candidate's demand, probed
        without touching LRU state.  Slightly conservative — never
        optimistic enough to preempt a victim for nothing."""
        _, mpages, _ = self.pool._walk_full_pages(req.prompt, touch=False)
        gain = sum(1 for p in s.pages if self.pool.ref(p) == 1)
        n_host = sum(1 for p in mpages if self.pool.is_host(p))
        rec = self._preempted.get(req.uid)
        if (rec is not None and rec["slots"] is not None
                and len(mpages) >= rec["page0"]):
            keep = len(rec["slots"]) - (len(mpages) - rec["page0"])
            ncover = rec["page0"] + len(rec["slots"])
            demand = (-(-(len(req.prompt) + req.max_tokens)
                        // self.page_size) - ncover) + keep + n_host
        else:
            demand = self._pages_needed(
                req, matched_pages=len(mpages)) + n_host
        return demand <= self.pool.available(mpages) + gain

    def _preempt_slot(self, b: int, state):
        """Preempt decoding slot ``b``: park its private pages (the
        coverage of positions [0, pos) beyond its indexed prefix) to the
        host tier, release the rest, and requeue the request AT THE HEAD
        with its generated tokens intact.  The park's demote gathers apply
        immediately — the freed device pages may be reallocated by the
        very next admission round."""
        s = self.slots[b]
        req = s.req
        ncover = -(-s.pos // self.page_size)
        ps = s.n_indexed
        if req.out_tokens:
            parked = self.pool.park(s.pages[ps:ncover])
            self._preempted[req.uid] = {
                "slots": parked, "page0": ps, "pos": s.pos,
                "last_tok": s.last_tok}
            if parked is not None:
                self._stats["preempt_pages_parked"] += len(parked)
                self.pool.release(s.pages[:ps] + s.pages[ncover:])
            else:
                self.pool.release(s.pages)  # host tier absent or full:
                # the record alone still resumes via re-prefill
        else:
            # nothing generated yet: a plain requeue re-admits through the
            # normal path (its prompt pages stay cached for the re-prefill)
            self.pool.release(s.pages)
        self.slots[b] = None
        self.queue.appendleft(req)
        self._stats["preemptions"] += 1
        return self._apply_pool_events(state)

    # -- deadlines / fault injection --------------------------------------
    def _expire_deadlines(self) -> None:
        """Abort every queued or live request whose deadline tick has
        passed: a typed ``DeadlineExceeded`` (partial output attached)
        lands on the request record, raised by its handle's
        ``result()``/``tokens()``.  Parked state is dropped — an expired
        request never resumes."""
        tick = self._stats["ticks"]

        def expire(req: Request) -> None:
            req.error = DeadlineExceeded(
                f"request {req.uid} missed its deadline "
                f"(tick {tick} >= {req.deadline_tick})",
                tokens=req.out_tokens)
            req.done = True
            self._stats["deadline_expired"] += 1

        for req in [r for r in self.queue
                    if r.deadline_tick is not None
                    and tick >= r.deadline_tick]:
            self.queue.remove(req)
            self._drop_park_record(req.uid)
            expire(req)
        for b, s in enumerate(self.slots):
            if (s is not None and s.req.deadline_tick is not None
                    and tick >= s.req.deadline_tick):
                self._release_slot(b)
                expire(s.req)

    def _chaos_tick(self) -> bool:
        """Draw and apply this tick's injected faults (deterministic in
        (seed, tick) — see ``serve.chaos.FaultInjector``).  Returns True
        for a STALLED tick: the engine does nothing but let the clock —
        and with it every deadline — advance."""
        live = ([s.req.uid for s in self.slots if s is not None]
                + [r.uid for r in self.queue])
        f = self.fault_injector.faults(self._stats["ticks"], live)
        if f.get("cancel") is not None:
            from repro.serve.errors import Cancelled

            if self.cancel(f["cancel"], error=Cancelled(
                    f"request {f['cancel']} cancelled by fault injection")):
                self._stats["chaos_cancels"] += 1
        if f.get("evict_storm"):
            self.pool.storm_host_cache()
            self._state = self._apply_pool_events(self._state)
            self._stats["chaos_evict_storms"] += 1
        if f.get("alloc_fail"):
            self._chaos_alloc_fail = True
            self._stats["chaos_alloc_fails"] += 1
        if f.get("stall"):
            self._stats["chaos_stalled_ticks"] += 1
            return True
        return False

    # -- slot lifecycle ---------------------------------------------------
    def _release_slot(self, b: int) -> None:
        s = self.slots[b]
        self.pool.release(s.pages)
        self.slots[b] = None

    def _index_filled_pages(self, s: _Slot) -> None:
        """Insert this slot's freshly completed PROMPT pages into the trie.

        Called whenever ``fill`` advances: every full page now covered by
        prefilled (or inherited) tokens extends the slot's chain, unless an
        equivalent page already exists — then the existing page keeps
        ownership of the prefix and this slot's private duplicate simply
        never enters the index (freed at completion).  Decode tokens never
        advance ``fill``, so generated pages are never indexed — and a
        preempt-resume re-prefill, whose ``prefill_tokens`` replay history
        PAST the prompt, caps indexing at the pure-prompt pages."""
        if s.node is None or not self.prefix_cache:
            return
        P = self.page_size
        limit = min(s.fill, len(s.req.prompt))
        while (s.n_indexed + 1) * P <= limit:
            j = s.n_indexed
            key = tuple(int(t) for t in s.req.prompt[j * P:(j + 1) * P])
            s.node = self.pool.index_page(s.node, key, s.pages[j])
            if s.node is None:
                return
            s.n_indexed += 1

    # -- sampling / bookkeeping -------------------------------------------
    def _sample(self, req: Request, logits_row: np.ndarray,
                ordinal: int) -> int:
        """One token from a (V,) logits row: greedy argmax at temperature 0,
        seeded temperature/top-k sampling otherwise.

        ``ordinal`` is the emission index within the request (==
        ``len(req.out_tokens)`` at draw time), and the RNG is keyed
        per-(request seed, ordinal) — NOT a per-request sequential stream.
        A sequential generator is packing-invariant only while every slot
        emits exactly one token per tick; speculative acceptance emits a
        whole chain in one tick, and keying each draw by its position in
        the output keeps emission m's randomness identical whether it was
        sampled alone, as a verify row, or re-drawn as the correction after
        a rejected draft.  Consequence: transcripts are token-identical
        with speculation on or off at ANY temperature, not just greedy."""
        if req.temperature == 0.0:
            return int(np.argmax(logits_row))
        logit = logits_row.astype(np.float64) / req.temperature
        if req.top_k is not None and req.top_k < logit.size:
            kth = np.partition(logit, -req.top_k)[-req.top_k]
            logit = np.where(logit >= kth, logit, -np.inf)
        logit = logit - logit.max()
        p = np.exp(logit)
        p /= p.sum()
        base = req.seed if req.seed is not None else req.uid
        rng = np.random.default_rng((base, ordinal))
        return int(rng.choice(logit.size, p=p))

    def _finish_token(self, b: int, tok: int, results: Dict) -> None:
        """Book one sampled token for slot ``b``: emit, advance, retire the
        request (releasing its page refs) on EOS / max_tokens."""
        s = self.slots[b]
        req = s.req
        req.out_tokens.append(tok)
        s.pos += 1
        self.token_log.append((req.uid, self._stats["ticks"],
                               # servelint: ignore[hot-nondeterminism] — measurement-only: the wall time lands in token_log for the latency benchmark and never feeds control flow
                               time.perf_counter()))
        if (len(req.out_tokens) >= req.max_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            req.done = True
            results[req.uid] = req.out_tokens
            self.completion_order.append(req.uid)
            self._release_slot(b)
        else:
            s.last_tok = tok

    # -- ragged path ------------------------------------------------------
    def _ragged_tick(self, state):
        """Pack one token budget and run the single ragged program.

        Decode first (no decoding slot ever stalls), then prefill chunks
        until the budget runs out; WITHIN each section the scheduler's
        pack order decides which slot's tokens take the budget first (FIFO:
        slot-index order, bit-identical to PR 2-4).  A slot whose prompt
        completes in this pack appends its first decode token right behind
        it.  Slots admitted on a full prefix hit enter the decode section
        on their very first tick — the whole prefill phase is skipped.

        With speculation on (``spec_k`` > 0) a THIRD section follows:
        leftover budget takes each decoding slot's prompt-lookup draft
        chain at its next consecutive positions, and ``logit_idx`` widens
        to (B, 1+spec_k) so the one forward returns a verify row per draft.
        Decode-first and prefill keep strict priority over drafts — drafts
        are speculative work and only ever consume budget nothing else
        claimed, so non-speculative packing is bit-identical with the
        feature on.  After the step the engine accepts the longest
        agreeing draft prefix (plus the correction/bonus token) and rolls
        back kpos/slen for rejected tails."""
        T, W = self.budget, max(self.chunk + 1, 1 + self._spec_k)
        R = 1 + self._spec_k
        tokens = np.zeros(T, np.int32)
        slot = np.zeros(T, np.int32)
        q_pos = np.zeros(T, np.int32)
        seq_idx = np.full(T, W, np.int32)
        valid = np.zeros(T, bool)
        logit_idx = np.full((self.B, R) if self._spec_k else (self.B,),
                            T, np.int32)
        n = 0
        sampling: List[int] = []
        tick = self._stats["ticks"]
        ready = [b for b, s in enumerate(self.slots)
                 if s is not None and s.ready_tick <= tick
                 and s.fill >= len(s.prefill_tokens)]
        filling = [b for b, s in enumerate(self.slots)
                   if s is not None and s.ready_tick <= tick
                   and s.fill < len(s.prefill_tokens)]
        if self._default_pack:
            decode_order, prefill_order = ready, filling
        else:
            view = self._view(include_queue=False)
            decode_order = self._pack_order(
                self.scheduler.decode_order(view, ready), ready,
                "decode_order")
            prefill_order = self._pack_order(
                self.scheduler.prefill_order(view, filling), filling,
                "prefill_order")
        for b in decode_order:
            s = self.slots[b]
            tokens[n] = s.last_tok
            slot[n] = b
            q_pos[n] = s.pos
            seq_idx[n] = 0
            valid[n] = True
            if self._spec_k:
                logit_idx[b, 0] = n
            else:
                logit_idx[b] = n
            sampling.append(b)
            n += 1
        for b in prefill_order:
            if n >= T:
                break
            s = self.slots[b]
            L = len(s.prefill_tokens)
            c = min(self.chunk, L - s.fill, T - n)
            tokens[n:n + c] = s.prefill_tokens[s.fill:s.fill + c]
            slot[n:n + c] = b
            q_pos[n:n + c] = s.fill + np.arange(c)
            seq_idx[n:n + c] = np.arange(c)
            valid[n:n + c] = True
            n += c
            s.fill += c
            self._index_filled_pages(s)
            if s.fill >= L:
                # decode resumes from the last prompt token at position L
                # (same scheme as the reference engine, for token identity)
                # — or, for a re-prefilled preemptee, from its last
                # generated token at its preempted position (L here is the
                # replayed-history length, which IS that position)
                s.pos = L
                s.last_tok = (s.resume_tok if s.resume_tok is not None
                              else int(s.prefill_tokens[-1]))
                if n < T:
                    tokens[n] = s.last_tok
                    slot[n] = b
                    q_pos[n] = s.pos
                    seq_idx[n] = c
                    valid[n] = True
                    if self._spec_k:
                        logit_idx[b, 0] = n
                    else:
                        logit_idx[b] = n
                    sampling.append(b)
                    n += 1
        # draft section: leftover budget takes each decoding slot's prompt-
        # lookup chain at its next consecutive positions.  The drafted dict
        # is the tick's DRAFT LEDGER: slot -> proposed tokens, with the
        # verify rows at logit_idx[b, 1:1+k].  Drafts never displace decode
        # or prefill tokens and a lookup miss packs nothing, so this
        # section is free for non-repetitive traffic.
        drafted: Dict[int, List[int]] = {}
        if self._spec_k:
            for b in decode_order:
                if n >= T:
                    break
                s = self.slots[b]
                req = s.req
                # cap by remaining output (drafting past max_tokens-1 can
                # never be accepted) and by leftover budget
                room = min(self._spec_k,
                           req.max_tokens - len(req.out_tokens) - 1, T - n)
                if room < 1:
                    continue
                hist = (np.concatenate([req.prompt, np.asarray(
                            req.out_tokens, np.int32)])
                        if req.out_tokens else req.prompt)
                d = self._draft(hist, room)
                if not d:
                    continue
                k = len(d)
                if __debug__:
                    # the rejected-tail contract (see PagePool.is_indexed):
                    # draft rows land beyond the prompt, in pages the slot
                    # privately owns — never in indexed prefix pages
                    for pi in range((s.pos + 1) // self.page_size,
                                    (s.pos + k) // self.page_size + 1):
                        assert not self.pool.is_indexed(s.pages[pi]), \
                            (b, pi, s.pages[pi])
                tokens[n:n + k] = d
                slot[n:n + k] = b
                q_pos[n:n + k] = s.pos + 1 + np.arange(k)
                seq_idx[n:n + k] = 1 + np.arange(k)
                valid[n:n + k] = True
                logit_idx[b, 1:1 + k] = n + np.arange(k)
                drafted[b] = d
                self._stats["spec_drafted"] += k
                n += k
        results: Dict[int, List[int]] = {}
        if n == 0:
            return state, results
        logits, state = self._ragged_step(self.params, state, tokens, slot,
                                          q_pos, seq_idx, valid, logit_idx)
        self._stats["ragged_ticks"] += 1
        self._stats["packed_tokens"] += n
        if sampling:
            rows = np.asarray(logits)  # (B, V) — or (B, R, V) with spec on
            self._stats["sampled_slot_ticks"] += len(sampling)
            accepted: Dict[int, int] = {}
            for b in sampling:
                req = self.slots[b].req
                drafts = drafted.get(b, ())
                # verify in one pass: row j holds the model's prediction
                # given the draft prefix d_1..d_j, so sampling row j both
                # CHECKS draft j+1 and, on mismatch or exhaustion, IS the
                # correction/bonus token — the chain always emits >= 1
                j = 0
                tok = self._sample(req, rows[b, 0] if self._spec_k
                                   else rows[b], len(req.out_tokens))
                while True:
                    self._finish_token(b, tok, results)
                    if (self.slots[b] is None or j >= len(drafts)
                            or tok != drafts[j]):
                        break
                    j += 1
                    self._stats["spec_accepted"] += 1
                    tok = self._sample(req, rows[b, j], len(req.out_tokens))
                accepted[b] = j
            if drafted:
                # roll back rejected tails: drop kpos/slen for positions at
                # and beyond the slot's new write position.  Released slots
                # skip it — admission's reset wipes the whole row anyway.
                mask = np.zeros(self.B, bool)
                new_len = np.zeros(self.B, np.int32)
                for b, d in drafted.items():
                    j = accepted.get(b, 0)
                    if j < len(d):
                        self._stats["spec_rejected"] += len(d) - j
                        s = self.slots[b]
                        if s is not None:
                            mask[b] = True
                            new_len[b] = s.pos
                if mask.any():
                    state = self._spec_rollback(state, mask, new_len)
                    self._stats["spec_rollbacks"] += int(mask.sum())
        return state, results

    # -- legacy two-phase path (PR 1, kept behind ragged=False) -----------
    def _prefill_tick(self, state):
        """Advance every slot with outstanding prompt tokens by one chunk —
        a single batched (B, chunk) call with per-slot positions."""
        C = self.chunk
        tokens = np.zeros((self.B, C), np.int32)
        q_pos = np.zeros((self.B, C), np.int32)
        valid = np.zeros((self.B, C), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.prefill_tokens)
            if s.fill >= L:
                continue
            n = min(C, L - s.fill)
            tokens[b, :n] = s.prefill_tokens[s.fill:s.fill + n]
            q_pos[b] = s.fill + np.arange(C)
            valid[b, :n] = True
            s.fill += n
            self._index_filled_pages(s)
            if s.fill >= L:
                s.pos = L
                s.last_tok = (s.resume_tok if s.resume_tok is not None
                              else int(s.prefill_tokens[-1]))
        _, state = self._chunk_step(self.params, state, tokens, q_pos, valid)
        self._stats["chunk_ticks"] += 1
        return state

    def _decode_tick(self, state):
        tokens = np.zeros((self.B, 1), np.int32)
        q_pos = np.zeros((self.B, 1), np.int32)
        valid = np.zeros((self.B, 1), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            tokens[b, 0] = s.last_tok
            q_pos[b, 0] = s.pos
            valid[b, 0] = True
        logits, state = self._decode_step(self.params, state, tokens, q_pos,
                                          valid)
        rows = np.asarray(logits[:, -1])
        self._stats["decode_ticks"] += 1
        results: Dict[int, List[int]] = {}
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            self._finish_token(b, self._sample(s.req, rows[b],
                                               len(s.req.out_tokens)),
                               results)
        return state, results

    # -- driving ----------------------------------------------------------
    @property
    def idle(self) -> bool:
        """No live slot and nothing queued."""
        return all(s is None for s in self.slots) and not self.queue

    def _ctx(self):
        """Ambient mesh + serve rules for every trace/execute of the
        compiled programs (no-op without ``mesh=``).  All four device
        programs — the serve step, COW copy, slot reset, and the two-phase
        legacy steps — must trace under the SAME context so the lshard
        constraints in the model and the shard_map kernel wrappers see the
        KV-head rule; the sharded state then keeps every program's layout
        consistent via input propagation."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.parallel.sharding import use_mesh

        return use_mesh(self.mesh, self._rules)

    def _ensure_state(self):
        """Decode state is created once and persists for the engine's whole
        life: freeing it between runs would throw away the prefix cache (the
        pool's pages ARE the cached data).

        Under ``mesh=`` the freshly built state is committed to its
        NamedShardings (serve_step.decode_state_specs: pools split on the
        KV-head axis, per-slot bookkeeping replicated) and the params are
        committed replicated; every jit'd program then inherits the layout
        from its committed operands — no per-call in_shardings needed, and
        donation keeps the sharded pools updating in place."""
        if self._state is None:
            self._state = M.init_paged_state(
                self.params, self.cfg, self.B, self.cache_len,
                page_size=self.page_size, n_pages=self.n_pages,
                window_extra=self.chunk, kv_dtype=self.kv_dtype)
            if self.mesh is not None:
                from repro.serve.serve_step import decode_state_specs

                with self._ctx():
                    specs = decode_state_specs(jax.tree.map(
                        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                        self._state))
                ns = jax.tree.map(
                    lambda s: NamedSharding(self.mesh, s), specs,
                    is_leaf=lambda x: isinstance(x, P))
                self._state = jax.device_put(self._state, ns)
                self.params = jax.device_put(
                    self.params, NamedSharding(self.mesh, P()))
            # the reset template must not alias the (donated) live state
            self._template = jax.tree.map(jax.numpy.copy, self._state)

    def tick(self) -> Dict[int, List[int]]:
        """One scheduling tick: admit from the queue, pack, run one program
        step.  Returns the requests that finished this tick ({uid: tokens}).
        Public so continuous-arrival drivers (benchmarks/serve_sweep.py) and
        ``RequestHandle.tokens()`` iterators can interleave ``submit`` with
        serving instead of draining a batch."""
        with self._ctx():
            self._ensure_state()
            self._expire_deadlines()
            self._chaos_alloc_fail = False
            if self.fault_injector is not None and self._chaos_tick():
                # stalled tick: the clock advanced, nothing ran
                self._stats["ticks"] += 1
                # servelint: ignore[hot-nondeterminism] — measurement-only: tick_log wall time, never control flow
                self.tick_log.append((False, time.perf_counter()))
                return {}
            if self.pool.events:
                # expiry / cancellation may have dropped parked pages with
                # no admission round behind them to drain the hevicts
                self._state = self._apply_pool_events(self._state)
            self._state = self._admit(self._state)
            had_prefill = any(s is not None
                              and s.fill < len(s.prefill_tokens)
                              for s in self.slots)
            results: Dict[int, List[int]] = {}
            if self.ragged:
                self._state, results = self._ragged_tick(self._state)
            elif had_prefill:
                self._state = self._prefill_tick(self._state)
            elif any(s is not None for s in self.slots):
                self._state, results = self._decode_tick(self._state)
        self._stats["ticks"] += 1
        # servelint: ignore[hot-nondeterminism] — measurement-only: tick_log wall time, never control flow
        self.tick_log.append((had_prefill, time.perf_counter()))
        return results

    def run(self, max_ticks: int = 4096) -> Dict[int, List[int]]:
        """Drain the queue; returns {uid: generated tokens}."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            if self.idle:
                break
            results.update(self.tick())
        # drain partials on tick-budget exhaustion, releasing slots/pages so
        # the engine stays reusable (no page leak, no stale decode state);
        # never-admitted requests report their (empty) partials too, so every
        # submitted uid is present in the result
        for b, s in enumerate(self.slots):
            if s is not None:
                s.req.done = True
                results[s.req.uid] = s.req.out_tokens
                self._release_slot(b)
        while self.queue:
            req = self.queue.popleft()
            self._drop_park_record(req.uid)
            req.done = True
            results[req.uid] = req.out_tokens
        return results
