"""Ragged token-budget serving engine: one compiled program for any traffic.

The paper's core result is that ONE set of system settings keeps every
(Nproc × Nthread) factorization near practical peak.  The serving analogue:
one compiled program that stays near the roofline for any mix of prefilling
and decoding requests.  PR 1 got to two programs — a ``(B, chunk)`` prefill
and a ``(B, 1)`` decode — but a tick was either one or the other, so every
prefill chunk stalled every decoding slot (head-of-line interference, the
exact failure mode the paper's single-configuration discipline eliminates).

This engine collapses the two-phase tick into a single jit'd **ragged
step** (``serve_step.make_ragged_step`` / ``models.model.ragged_step``):

- **Token-budget packs** — each tick, a host-side scheduler packs a fixed
  token budget ``T`` (``token_budget``, default 128) with a mix of prefill
  chunks and decode tokens from whichever slots have work.  Decode tokens
  pack first — a decoding slot emits one token EVERY tick, regardless of
  concurrent prefill — and prefill chunks (≤ ``prefill_chunk`` tokens per
  slot) fill the leftover budget.  A slot that finishes its prompt inside a
  pack appends its first decode token to the same pack (one fewer tick to
  first token).
- **Per-token (slot, position, validity) vectors** drive the one
  ``(T,)``-shaped program: attention scatters KV into the same page pools /
  circular buffers as before, recurrent mixers repack into per-slot dense
  order, and logits are gathered only at each slot's last packed token.
  ``prefill_chunk`` and ``token_budget`` are compile-time shapes; the
  prefill/decode mix is pure data, so exactly ONE program is ever traced
  (``stats["traces"]``; the admission reset is a separate control-plane
  program, not part of the serve path).
- **Paged KV slots** — unchanged from PR 1: global-attention KV lives in
  page pools behind per-slot block tables, pages are reserved FIFO at
  admission and freed at completion; windowed layers keep per-slot circular
  buffers; the allocator and block tables are host-side numpy.
- **Seeded sampling** — per-request ``temperature`` / ``top_k`` / ``seed``
  (greedy argmax remains the default and is token-identical to
  ``reference.ReferenceEngine``).  Sampling runs host-side from the per-slot
  logits row with one RNG draw per token, so sampled outputs are identical
  across (budget, chunk, page) packings too.

The PR 1 two-phase path is kept behind ``ragged=False`` for A/B — the
``benchmarks/serve_sweep.py`` ragged-vs-chunked column and the p50
decode-latency-under-prefill comparison run both.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.serve.reference import Request
from repro.serve.serve_step import make_ragged_step


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    fill: int = 0  # prompt tokens written so far
    pos: int = 0  # next absolute write position (== len(prompt) at decode)
    last_tok: int = 0


class ServeEngine:
    def __init__(self, params, cfg: ModelCfg, *, batch_size: int = 4,
                 cache_len: int = 256, page_size: int = 16,
                 max_pages: Optional[int] = None, prefill_chunk: int = 32,
                 token_budget: int = 128, greedy: bool = True,
                 ragged: bool = True, flash_decode: bool = False):
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.cache_len = cache_len
        self.page_size = page_size
        self.chunk = prefill_chunk
        self.budget = token_budget
        self.greedy = greedy
        self.ragged = ragged
        if ragged and token_budget < batch_size:
            raise ValueError(
                f"token_budget={token_budget} < batch_size={batch_size}: "
                "every decoding slot needs one pack entry per tick")
        self.pps = -(-cache_len // page_size)  # block-table width
        self._has_paged = any(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        self.n_pages = (max_pages if max_pages is not None
                        else batch_size * self.pps)
        self._free: List[int] = list(range(self.n_pages))
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * batch_size
        self._uid = 0
        self._rngs: Dict[int, np.random.Generator] = {}
        self.completion_order: List[int] = []
        self.stats = {"chunk_ticks": 0, "decode_ticks": 0, "ragged_ticks": 0,
                      "ticks": 0, "packed_tokens": 0, "traces": 0,
                      "pages_in_use_peak": 0}
        # per-token / per-tick logs for the latency benchmark:
        # token_log rows are (uid, tick index, wall time); tick_log rows are
        # (had outstanding prefill at tick start, wall time at tick end)
        self.token_log: List[tuple] = []
        self.tick_log: List[tuple] = []

        def _count_traces(fn):
            def wrapper(*a):
                self.stats["traces"] += 1  # python body runs at trace time
                return fn(*a)
            return wrapper

        # donate the state: the page pools dominate the pytree and must be
        # updated in place, not copied, on every tick of the hot loop
        self._ragged_step = jax.jit(
            _count_traces(make_ragged_step(
                cfg, width=prefill_chunk + 1, flash_decode=flash_decode)),
            donate_argnums=(1,))
        step = lambda wl: (lambda p, s, t, qp, v: M.paged_step(
            p, cfg, s, t, qp, v, with_logits=wl, flash_decode=flash_decode))
        self._chunk_step = jax.jit(step(False), donate_argnums=(1,))
        self._decode_step = jax.jit(step(True), donate_argnums=(1,))
        self._reset = jax.jit(
            lambda s, s0, m, rows: M.reset_paged_slots(cfg, s, s0, m, rows),
            donate_argnums=(0,))

    def submit(self, prompt, max_tokens: int = 16, eos_id=None, *,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if prompt.size + max_tokens > self.cache_len:
            raise ValueError(
                f"len(prompt)+max_tokens = {prompt.size + max_tokens} "
                f"exceeds cache_len={self.cache_len}")
        if temperature is None:
            temperature = 0.0 if self.greedy else 1.0
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self._uid += 1
        req = Request(self._uid, prompt, max_tokens, eos_id,
                      temperature=temperature, top_k=top_k, seed=seed)
        need = self._pages_needed(req)
        if need > self.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool has only "
                f"{self.n_pages} (raise max_pages or shrink the request)")
        if temperature > 0.0:
            self._rngs[self._uid] = np.random.default_rng(
                seed if seed is not None else self._uid)
        self.queue.append(req)
        return self._uid

    # -- internals --------------------------------------------------------
    def _pages_needed(self, req: Request) -> int:
        if not self._has_paged:
            return 0
        return -(-(len(req.prompt) + req.max_tokens) // self.page_size)

    def _admit(self, state):
        """FIFO admission: a request enters a free slot only when its whole
        page reservation fits (no mid-flight OOM, no reordering)."""
        mask = np.zeros(self.B, bool)
        rows = np.full((self.B, self.pps), self.n_pages, np.int32)
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            need = self._pages_needed(self.queue[0])
            if need > len(self._free):
                break  # strict FIFO: head of line waits for pages
            req = self.queue.popleft()
            pages = [self._free.pop() for _ in range(need)]
            rows[b, :need] = pages
            self.slots[b] = _Slot(req, pages)
            mask[b] = True
        if mask.any():
            in_use = self.n_pages - len(self._free)
            self.stats["pages_in_use_peak"] = max(
                self.stats["pages_in_use_peak"], in_use)
            state = self._reset(state, self._template, mask, rows)
        return state

    def _sample(self, req: Request, logits_row: np.ndarray) -> int:
        """One token from a (V,) logits row: greedy argmax at temperature 0,
        seeded temperature/top-k sampling otherwise (one RNG draw per token,
        so output is independent of how ticks were packed)."""
        if req.temperature == 0.0:
            return int(np.argmax(logits_row))
        logit = logits_row.astype(np.float64) / req.temperature
        if req.top_k is not None and req.top_k < logit.size:
            kth = np.partition(logit, -req.top_k)[-req.top_k]
            logit = np.where(logit >= kth, logit, -np.inf)
        logit = logit - logit.max()
        p = np.exp(logit)
        p /= p.sum()
        return int(self._rngs[req.uid].choice(logit.size, p=p))

    def _finish_token(self, b: int, tok: int, results: Dict) -> None:
        """Book one sampled token for slot ``b``: emit, advance, retire the
        request (freeing its pages) on EOS / max_tokens."""
        s = self.slots[b]
        req = s.req
        req.out_tokens.append(tok)
        s.pos += 1
        self.token_log.append((req.uid, self.stats["ticks"],
                               time.perf_counter()))
        if (len(req.out_tokens) >= req.max_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            results[req.uid] = req.out_tokens
            self.completion_order.append(req.uid)
            self._free.extend(s.pages)
            self._rngs.pop(req.uid, None)
            self.slots[b] = None
        else:
            s.last_tok = tok

    # -- ragged path ------------------------------------------------------
    def _ragged_tick(self, state):
        """Pack one token budget and run the single ragged program.

        Decode first (no decoding slot ever stalls), then prefill chunks in
        slot order until the budget runs out; a slot whose prompt completes
        in this pack appends its first decode token right behind it."""
        T, W = self.budget, self.chunk + 1
        tokens = np.zeros(T, np.int32)
        slot = np.zeros(T, np.int32)
        q_pos = np.zeros(T, np.int32)
        seq_idx = np.full(T, W, np.int32)
        valid = np.zeros(T, bool)
        logit_idx = np.full(self.B, T, np.int32)
        n = 0
        sampling: List[int] = []
        for b, s in enumerate(self.slots):
            if s is None or s.fill < len(s.req.prompt):
                continue
            tokens[n] = s.last_tok
            slot[n] = b
            q_pos[n] = s.pos
            seq_idx[n] = 0
            valid[n] = True
            logit_idx[b] = n
            sampling.append(b)
            n += 1
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.req.prompt)
            if s.fill >= L or n >= T:
                continue
            c = min(self.chunk, L - s.fill, T - n)
            tokens[n:n + c] = s.req.prompt[s.fill:s.fill + c]
            slot[n:n + c] = b
            q_pos[n:n + c] = s.fill + np.arange(c)
            seq_idx[n:n + c] = np.arange(c)
            valid[n:n + c] = True
            n += c
            s.fill += c
            if s.fill >= L:
                # decode resumes from the last prompt token at position L
                # (same scheme as the reference engine, for token identity)
                s.pos = L
                s.last_tok = int(s.req.prompt[-1])
                if n < T:
                    tokens[n] = s.last_tok
                    slot[n] = b
                    q_pos[n] = s.pos
                    seq_idx[n] = c
                    valid[n] = True
                    logit_idx[b] = n
                    sampling.append(b)
                    n += 1
        results: Dict[int, List[int]] = {}
        if n == 0:
            return state, results
        logits, state = self._ragged_step(self.params, state, tokens, slot,
                                          q_pos, seq_idx, valid, logit_idx)
        self.stats["ragged_ticks"] += 1
        self.stats["packed_tokens"] += n
        if sampling:
            rows = np.asarray(logits)  # (B, V)
            for b in sampling:
                self._finish_token(b, self._sample(self.slots[b].req,
                                                   rows[b]), results)
        return state, results

    # -- legacy two-phase path (PR 1, kept behind ragged=False) -----------
    def _prefill_tick(self, state):
        """Advance every slot with outstanding prompt tokens by one chunk —
        a single batched (B, chunk) call with per-slot positions."""
        C = self.chunk
        tokens = np.zeros((self.B, C), np.int32)
        q_pos = np.zeros((self.B, C), np.int32)
        valid = np.zeros((self.B, C), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.req.prompt)
            if s.fill >= L:
                continue
            n = min(C, L - s.fill)
            tokens[b, :n] = s.req.prompt[s.fill:s.fill + n]
            q_pos[b] = s.fill + np.arange(C)
            valid[b, :n] = True
            s.fill += n
            if s.fill >= L:
                s.pos = L
                s.last_tok = int(s.req.prompt[-1])
        _, state = self._chunk_step(self.params, state, tokens, q_pos, valid)
        self.stats["chunk_ticks"] += 1
        return state

    def _decode_tick(self, state):
        tokens = np.zeros((self.B, 1), np.int32)
        q_pos = np.zeros((self.B, 1), np.int32)
        valid = np.zeros((self.B, 1), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            tokens[b, 0] = s.last_tok
            q_pos[b, 0] = s.pos
            valid[b, 0] = True
        logits, state = self._decode_step(self.params, state, tokens, q_pos,
                                          valid)
        rows = np.asarray(logits[:, -1])
        self.stats["decode_ticks"] += 1
        results: Dict[int, List[int]] = {}
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            self._finish_token(b, self._sample(s.req, rows[b]), results)
        return state, results

    def run(self, max_ticks: int = 4096) -> Dict[int, List[int]]:
        """Drain the queue; returns {uid: generated tokens}."""
        state = M.init_paged_state(self.params, self.cfg, self.B,
                                   self.cache_len, page_size=self.page_size,
                                   n_pages=self.n_pages,
                                   window_extra=self.chunk)
        # the reset template must not alias the (donated) live state
        self._template = jax.tree.map(jax.numpy.copy, state)
        results: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            if all(s is None for s in self.slots) and not self.queue:
                break
            state = self._admit(state)
            had_prefill = any(s is not None and s.fill < len(s.req.prompt)
                              for s in self.slots)
            if self.ragged:
                state, done = self._ragged_tick(state)
                results.update(done)
            elif had_prefill:
                state = self._prefill_tick(state)
            elif any(s is not None for s in self.slots):
                state, done = self._decode_tick(state)
                results.update(done)
            self.stats["ticks"] += 1
            self.tick_log.append((had_prefill, time.perf_counter()))
        # drain partials on tick-budget exhaustion, releasing slots/pages so
        # the engine stays reusable (no page leak, no stale decode state);
        # never-admitted requests report their (empty) partials too, so every
        # submitted uid is present in the result
        for b, s in enumerate(self.slots):
            if s is not None:
                results[s.req.uid] = s.req.out_tokens
                self._free.extend(s.pages)
                self._rngs.pop(s.req.uid, None)
                self.slots[b] = None
        while self.queue:
            req = self.queue.popleft()
            results[req.uid] = req.out_tokens
            self._rngs.pop(req.uid, None)
        return results
