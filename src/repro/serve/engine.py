"""Ragged token-budget serving engine with a refcounted, copy-on-write,
prefix-cached page pool: one compiled program AND one resident working set
for any traffic.

The paper's core result is that ONE set of system settings (KMP_AFFINITY +
taskset + all2all **cache** mode) keeps every (Nproc × Nthread)
factorization near practical peak — and the decisive setting is the cache
mode: the shared working set is served from cache instead of being
recomputed or refetched per process.  This engine applies both halves of
that lesson to serving:

- **One compiled program** (PR 2): each tick a host-side scheduler packs a
  fixed token budget ``T`` (``token_budget``) with decode tokens FIRST (a
  decoding slot emits every tick; prefill never stalls it) and prefill
  chunks (≤ ``prefill_chunk`` per slot) in the leftover budget, driving a
  single jit'd ``(T,)`` ragged step (``serve_step.make_ragged_step``) with
  per-token (slot, position, validity) vectors.  The mix is pure data, so
  exactly ONE program is ever traced (``stats["traces"]``).
- **One resident working set** (PR 3): thousands of requests sharing a
  system-prompt prefix are the serving analogue of the paper's "millions of
  users" hitting the same data — so the paged KV pool is a shared,
  refcounted cache rather than scratch space.  The "all2all cache mode" of
  the engine: the shared prefix stays resident and every request reads it
  from the pool instead of re-prefilling it.
- **Half-or-better bytes per resident token** (this PR): the pool's memory
  REPRESENTATION is a knob (``kv_dtype``: float32 | bfloat16 | int8; the
  default follows the activation dtype).  An int8 pool stores symmetric
  int8 K/V plus one f32 scale per pool entry per KV head, and its lifecycle
  is **write-quantize → paged read-dequant → COW-with-scales**: rows are
  quantized exactly once, as the serve step scatters them into the pool
  (``kernels.ops.kv_scatter_quantized``); every reader — prefill chunks,
  decode ticks, prefix hits, the fused-dequant Pallas kernels — dequantizes
  the same stored bytes; and copy-on-write copies a page's scale row with
  its values (``kernels.ops.copy_pages``).  Because the page budget is
  really a BYTE budget, int8 holds 2-4× the pages in the same bytes: more
  concurrent decoders admitted and more refcount-0 prefix pages resident
  before eviction.  This is the memory-mode half of the paper's result
  applied twice over — the decode path streams ~¼ the KV bytes per token
  (the bandwidth-bound term of `core.roofline.mixed_bound`), AND the
  working set that must stay resident shrinks to match.

Prefix-cache lifecycle (host-side; the device only ever sees block tables):

- **Index** — a trie over FULL pages of prompt tokens maps token prefixes to
  pool pages.  As a slot's prefill passes each page boundary, that page is
  inserted (pages whose prefix is already owned by another page are left
  private).  Only prompt pages are indexed — decode output is per-request.
- **Match** — at admission the queue head's prompt walks the trie: every
  matched full page is mapped into the slot's block table (refcount++) and
  prefill starts at the first unmatched token, so a warm system prompt
  skips almost all prefill compute.  ``reset_paged_slots`` presets
  kpos/slen for the inherited positions.  Admission reserves ONLY the
  unmatched-suffix pages — the strict-FIFO no-mid-flight-OOM guarantee now
  counts what the hit actually needs, not the cold-start worst case.
- **Copy-on-write** — if the prompt diverges from a cached page mid-page
  (longest-common-prefix ≥ 1 token), the page is duplicated into a freshly
  allocated private page with a jit'd page-copy op
  (``models.model.copy_kv_pages`` → ``kernels.ops.copy_pages``) and the
  block-table entry points at the copy; stale tail offsets stay masked via
  kpos until prefill overwrites them.  Writes therefore NEVER target a page
  with refcount > 1 — asserted by construction: a slot's first unmatched
  position always falls in a page it owns.
- **Release / evict** — completion decrements refcounts; refcount-0 pages
  that are indexed STAY in the pool as cache (LRU-ordered) instead of being
  freed eagerly, and are evicted leaf-first on allocation pressure.  Pages
  never indexed return to the free list immediately.  The pool is always
  fully reclaimable: free + refcount-0-cached == n_pages when idle.

Sharing is enabled automatically only for models whose mixers are all
global (non-windowed) attention — recurrent states and windowed circular
buffers are per-slot and cannot be inherited from a page, so hybrid models
run with ``prefix_len = 0`` and behave exactly as before.

The KV pages shared between slots need no kernel support: the ragged Pallas
kernel (``kernels.flash_attention.ragged_paged_flash``) already resolves
token → slot → page per grid step, so aliased block-table rows just DMA the
same tile.

The PR 1 two-phase path is kept behind ``ragged=False`` for A/B, and the
seeded-sampling / paged-slot machinery is unchanged from PR 2
(``benchmarks/serve_sweep.py`` carries the comparisons).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.serve.reference import Request
from repro.serve.serve_step import STATE_DONATE_ARGNUM, make_ragged_step

from repro.core.roofline import KV_ITEMSIZE, KV_SCALE_BYTES


def kv_page_bytes(cfg: ModelCfg, page_size: int, kv_dtype: str) -> int:
    """Bytes one pool page costs across ALL paged (global-attention) layers
    for a given storage dtype — K and V values plus, for int8, their scale
    rows.  The engine sizes its page budget with this: a pool budget is a
    BYTE budget, and int8 fits ~``4·hd/(hd+4)``× the pages of float32 in
    the same bytes (≈3.8× at hd=64, ≥2× for hd ≥ 4; 3.2× on the smoke
    model's hd=16)."""
    isize = KV_ITEMSIZE[kv_dtype]
    sbytes = KV_SCALE_BYTES[kv_dtype]
    total = 0
    for st in cfg.stages:
        for blk in st.pattern:
            if blk.mixer == "attn" and blk.attn.window is None:
                kvH, hd = blk.attn.num_kv_heads, blk.attn.head_dim
                total += st.repeats * 2 * page_size * kvH * (hd * isize
                                                             + sbytes)
    return total


def kv_bytes_per_token(cfg: ModelCfg, kv_dtype: str) -> int:
    """Bytes of paged-pool KV one token occupies (and one decode step must
    stream per context token) across all global-attention layers — the
    quantity the int8 pool halves-or-better vs float32."""
    return kv_page_bytes(cfg, 1, kv_dtype)


@dataclasses.dataclass
class _Slot:
    req: Request
    pages: List[int]
    fill: int = 0  # prompt tokens in cache (matched prefix + prefilled)
    pos: int = 0  # next absolute write position (== len(prompt) at decode)
    last_tok: int = 0
    # prefix-cache bookkeeping: the trie node matching the indexed prefix so
    # far (None = this slot's prefix is owned elsewhere, stop indexing) and
    # how many of this slot's leading pages are on that trie chain
    node: Optional["_PrefixNode"] = None
    n_indexed: int = 0


class _PrefixNode:
    """One full page of prompt tokens in the prefix trie.

    ``children`` maps the NEXT page's token tuple to its node, so a cached
    prefix is a root-to-node chain of full pages.  Refcounts live in the
    engine's per-page array; a node is evictable when its page's refcount is
    0 and it has no children (leaf-first eviction keeps every cached chain
    reachable from the root — an active request holds refs on its whole
    matched path, so refcounts are monotone non-increasing down the trie)."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Optional[Tuple[int, ...]], page: int,
                 parent: Optional["_PrefixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.last_used = 0


class ServeEngine:
    def __init__(self, params, cfg: ModelCfg, *, batch_size: int = 4,
                 cache_len: int = 256, page_size: int = 16,
                 max_pages: Optional[int] = None, prefill_chunk: int = 32,
                 token_budget: int = 128, greedy: bool = True,
                 ragged: bool = True, flash_decode: bool = False,
                 prefix_cache: bool = True, kv_dtype: Optional[str] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.cache_len = cache_len
        self.page_size = page_size
        self.chunk = prefill_chunk
        self.budget = token_budget
        self.greedy = greedy
        self.ragged = ragged
        # paged-pool storage representation: None follows the activation
        # dtype (the unquantized default); "int8" is the headline — half-or-
        # better bytes per resident token, quantized at KV-write time so
        # prefill, decode, prefix hits, and COW all share one representation
        self.kv_dtype = str(jnp.dtype(kv_dtype or cfg.dtype))
        if self.kv_dtype not in KV_ITEMSIZE:
            raise ValueError(f"unsupported kv_dtype {self.kv_dtype!r} "
                             f"(pick from {sorted(KV_ITEMSIZE)})")
        if ragged and token_budget < batch_size:
            raise ValueError(
                f"token_budget={token_budget} < batch_size={batch_size}: "
                "every decoding slot needs one pack entry per tick")
        self.pps = -(-cache_len // page_size)  # block-table width
        self._has_paged = any(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        # prefix sharing needs EVERY layer's state to live in shareable
        # pages: recurrent mixers and windowed circular buffers are per-slot
        # and cannot be inherited, so hybrids serve with sharing off
        self.prefix_cache = bool(prefix_cache) and self._has_paged and all(
            blk.mixer == "attn" and blk.attn.window is None
            for st in cfg.stages for blk in st.pattern)
        # the page budget is a BYTE budget: the default pool spends the same
        # bytes the unquantized (activation-dtype) pool would, so an int8
        # pool holds ~2-4× the pages — more concurrent requests and more
        # refcount-0 prefix-cache pages stay resident before eviction (the
        # serving analogue of fitting the working set into fast memory).
        # Floor: never BELOW the worst-case base_pages — a widening kv_dtype
        # (e.g. a float32 pool on a bfloat16 model) keeps every slot
        # admissible without queueing, at the cost of exceeding the
        # activation-dtype byte budget (visible in stats["kv_pool_bytes"])
        base_pages = batch_size * self.pps
        if max_pages is not None:
            self.n_pages = max_pages
        elif self._has_paged:
            ref = kv_page_bytes(cfg, page_size, str(jnp.dtype(cfg.dtype)))
            act = kv_page_bytes(cfg, page_size, self.kv_dtype)
            self.n_pages = max(base_pages, base_pages * ref // max(act, 1))
        else:
            self.n_pages = base_pages
        self._free: List[int] = list(range(self.n_pages))
        self._ref = np.zeros(self.n_pages, np.int64)  # per-page refcounts
        self._root = _PrefixNode(None, -1, None)  # trie of cached prefixes
        self._page_node: Dict[int, _PrefixNode] = {}  # page -> trie node
        self._clock = 0  # LRU counter (bumped per touch)
        self.queue: deque = deque()
        self.slots: List[Optional[_Slot]] = [None] * batch_size
        self._uid = 0
        self._rngs: Dict[int, np.random.Generator] = {}
        self.completion_order: List[int] = []
        self._state = None  # persistent: the pool doubles as the prefix cache
        self.stats = {"chunk_ticks": 0, "decode_ticks": 0, "ragged_ticks": 0,
                      "ticks": 0, "packed_tokens": 0, "traces": 0,
                      "pages_in_use_peak": 0, "admissions": 0,
                      "prefix_hits": 0, "prefix_tokens_reused": 0,
                      "cow_copies": 0, "evictions": 0,
                      # memory-representation accounting: bytes of paged KV
                      # one token occupies (streams per context token at
                      # decode) and the pool's byte footprint at this dtype
                      "kv_dtype": self.kv_dtype,
                      "kv_bytes_per_token": kv_bytes_per_token(
                          cfg, self.kv_dtype),
                      "kv_pool_bytes": self.n_pages * kv_page_bytes(
                          cfg, page_size, self.kv_dtype)}
        # per-token / per-tick logs for the latency benchmark:
        # token_log rows are (uid, tick index, wall time); tick_log rows are
        # (had outstanding prefill at tick start, wall time at tick end)
        self.token_log: List[tuple] = []
        self.tick_log: List[tuple] = []

        def _count_traces(fn):
            def wrapper(*a):
                self.stats["traces"] += 1  # python body runs at trace time
                return fn(*a)
            return wrapper

        # donate the state (serve_step.STATE_DONATE_ARGNUM): the KV page
        # pools, int8 scale pools, and recurrent-state carries dominate the
        # pytree and must be updated in place, not copied, on every tick of
        # the hot loop (no-copy contract asserted by pointer identity in
        # tests/test_kv_quant.py)
        donate = (STATE_DONATE_ARGNUM,)
        self._ragged_step = jax.jit(
            _count_traces(make_ragged_step(
                cfg, width=prefill_chunk + 1, flash_decode=flash_decode)),
            donate_argnums=donate)
        step = lambda wl: (lambda p, s, t, qp, v: M.paged_step(
            p, cfg, s, t, qp, v, with_logits=wl, flash_decode=flash_decode))
        self._chunk_step = jax.jit(step(False), donate_argnums=donate)
        self._decode_step = jax.jit(step(True), donate_argnums=donate)
        # control-plane programs (admission reset, COW page copy) — separate
        # from the serve path, each traced at most once
        self._reset = jax.jit(
            lambda s, s0, m, rows, plen: M.reset_paged_slots(
                cfg, s, s0, m, rows, plen),
            donate_argnums=(0,))
        self._copy = jax.jit(
            lambda s, src, dst: M.copy_kv_pages(cfg, s, src, dst),
            donate_argnums=(0,))

    def submit(self, prompt, max_tokens: int = 16, eos_id=None, *,
               temperature: Optional[float] = None,
               top_k: Optional[int] = None,
               seed: Optional[int] = None) -> int:
        prompt = np.asarray(prompt, np.int32)
        if prompt.size == 0:
            raise ValueError("empty prompt")
        if max_tokens < 1:
            raise ValueError(f"max_tokens must be >= 1, got {max_tokens}")
        if prompt.size + max_tokens > self.cache_len:
            raise ValueError(
                f"len(prompt)+max_tokens = {prompt.size + max_tokens} "
                f"exceeds cache_len={self.cache_len}")
        if temperature is None:
            temperature = 0.0 if self.greedy else 1.0
        if temperature < 0.0:
            raise ValueError(f"temperature must be >= 0, got {temperature}")
        if top_k is not None and top_k < 1:
            raise ValueError(f"top_k must be >= 1, got {top_k}")
        self._uid += 1
        req = Request(self._uid, prompt, max_tokens, eos_id,
                      temperature=temperature, top_k=top_k, seed=seed)
        # admission reserves only the unmatched suffix on a prefix hit, but
        # cache contents churn before this request reaches the head of the
        # queue — validate against the cold-start worst case
        need = self._pages_needed(req)
        if need > self.n_pages:
            raise ValueError(
                f"request needs {need} pages but the pool has only "
                f"{self.n_pages} (raise max_pages or shrink the request)")
        if temperature > 0.0:
            self._rngs[self._uid] = np.random.default_rng(
                seed if seed is not None else self._uid)
        self.queue.append(req)
        return self._uid

    # -- page allocator / prefix cache ------------------------------------
    def _pages_needed(self, req: Request, matched_pages: int = 0) -> int:
        """Pages the request must RESERVE: its full footprint minus the
        ``matched_pages`` shared prefix pages it maps instead of allocating."""
        if not self._has_paged:
            return 0
        total = -(-(len(req.prompt) + req.max_tokens) // self.page_size)
        return total - matched_pages

    def _match_prefix(self, prompt: np.ndarray):
        """Longest cached prefix of ``prompt``: walk the trie a full page at
        a time, then probe the children of the last matched node for a
        partial-page hit (longest common prefix ≥ 1 token → COW candidate).

        Returns (node, pages, matched_tokens, cow) with ``pages`` the full
        shared pages and ``cow`` either None or (src_page, extra_tokens)."""
        if not self.prefix_cache:
            return self._root, [], 0, None
        P = self.page_size
        node, pages, matched = self._root, [], 0
        self._clock += 1
        while matched + P <= len(prompt):
            child = node.children.get(
                tuple(int(t) for t in prompt[matched:matched + P]))
            if child is None:
                break
            child.last_used = self._clock
            node = child
            pages.append(child.page)
            matched += P
        cow = None
        rem = prompt[matched:]
        if rem.size and node.children:
            best_len, best = 0, None
            for key, child in node.children.items():
                k = np.asarray(key[:rem.size], np.int32)
                lcp = int((np.cumprod(k == rem[:k.size]) if k.size else
                           np.zeros(0)).sum())
                if lcp > best_len:
                    best_len, best = lcp, child
            if best is not None:
                best.last_used = self._clock
                cow = (best.page, best_len)
        return node, pages, matched, cow

    def _evictable(self) -> int:
        """Cached pages reclaimable under pressure (refcount 0)."""
        return sum(1 for p in self._page_node if self._ref[p] == 0)

    def _evict_one(self) -> bool:
        """Drop the least-recently-used refcount-0 LEAF from the trie and
        return its page to the free list.  Leaf-first keeps every cached
        chain reachable; a ref-0 node's descendants are all ref-0 (active
        requests hold their whole matched path), so repetition drains any
        evictable subtree."""
        best = None
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd.children or self._ref[nd.page] != 0:
                continue
            if best is None or nd.last_used < best.last_used:
                best = nd
        if best is None:
            return False
        del best.parent.children[best.key]
        del self._page_node[best.page]
        self._free.append(best.page)
        self.stats["evictions"] += 1
        return True

    def _alloc(self, n: int) -> List[int]:
        while len(self._free) < n:
            if not self._evict_one():
                raise RuntimeError(  # unreachable: _admit checks availability
                    "page pool exhausted with nothing evictable")
        return [self._free.pop() for _ in range(n)]

    def _release_pages(self, pages: List[int]) -> None:
        """Drop one reference per page.  Refcount-0 pages stay resident if
        the prefix trie indexes them (the pool IS the cache; LRU eviction
        reclaims them under pressure) and are freed immediately otherwise."""
        for p in pages:
            self._ref[p] -= 1
            assert self._ref[p] >= 0, f"page {p} over-released"
            if self._ref[p] == 0 and p not in self._page_node:
                self._free.append(p)

    def _release_slot(self, b: int) -> None:
        s = self.slots[b]
        self._release_pages(s.pages)
        self._rngs.pop(s.req.uid, None)
        self.slots[b] = None

    def _index_filled_pages(self, s: _Slot) -> None:
        """Insert this slot's freshly completed PROMPT pages into the trie.

        Called whenever ``fill`` advances: every full page now covered by
        prefilled (or inherited) tokens extends the slot's chain, unless an
        equivalent page already exists — then the existing page keeps
        ownership of the prefix and this slot's private duplicate simply
        never enters the index (freed at completion).  Decode tokens never
        advance ``fill``, so generated pages are never indexed."""
        if s.node is None or not self.prefix_cache:
            return
        P = self.page_size
        while (s.n_indexed + 1) * P <= s.fill:
            j = s.n_indexed
            key = tuple(int(t) for t in s.req.prompt[j * P:(j + 1) * P])
            child = s.node.children.get(key)
            if child is None:
                child = _PrefixNode(key, s.pages[j], s.node)
                s.node.children[key] = child
                self._page_node[s.pages[j]] = child
            elif child.page != s.pages[j]:
                s.node = None  # prefix owned elsewhere: stop indexing
                return
            self._clock += 1
            child.last_used = self._clock
            s.node = child
            s.n_indexed += 1

    @property
    def cached_pages(self) -> int:
        """Pages currently held by the prefix index."""
        return len(self._page_node)

    @property
    def reclaimable_pages(self) -> int:
        """Free pages plus refcount-0 cached pages — the allocator can hand
        all of these out; equals ``n_pages`` whenever no request is live."""
        return len(self._free) + self._evictable()

    def drop_prefix_cache(self) -> int:
        """Evict every refcount-0 cached page (A/B runs, tests).  Returns
        the number of pages returned to the free list."""
        n = 0
        while self._evict_one():
            n += 1
        return n

    # -- admission --------------------------------------------------------
    def _admit(self, state):
        """FIFO admission: a request enters a free slot only when the pages
        it actually needs — its unmatched suffix, after the longest-cached-
        prefix match — fit in free + evictable pages (no mid-flight OOM, no
        reordering, and no starving the head of line on pages a prefix hit
        would never use)."""
        mask = np.zeros(self.B, bool)
        rows = np.full((self.B, self.pps), self.n_pages, np.int32)
        plen = np.zeros(self.B, np.int32)
        # unused COW pairs keep the n_pages sentinel: kernels.ops.copy_pages
        # turns them into self-copy no-ops, so the op is one fixed-width trace
        cow_src = np.full(self.B, self.n_pages, np.int32)
        cow_dst = np.full(self.B, self.n_pages, np.int32)
        cow_pins: List[int] = []
        n_cow = 0
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue[0]
            node, mpages, matched, cow = self._match_prefix(req.prompt)
            need = self._pages_needed(req, matched_pages=len(mpages))

            def supply(pins):
                # free + evictable AFTER this admission pins its matched /
                # COW-source pages: a currently refcount-0 cached page the
                # request itself is about to hold must not be counted as
                # reclaimable supply for its own allocation
                held = sum(1 for p in set(pins) if self._ref[p] == 0)
                return len(self._free) + self._evictable() - held

            if cow is not None and need > supply(mpages + [cow[0]]):
                cow = None  # pinning the COW source would leave the pool
                # short one page: forgo the partial-page reuse (it is an
                # optimization; the full-page match alone always fits)
            if need > supply(mpages):
                break  # strict FIFO: head of line waits for pages
            self.queue.popleft()
            for p in mpages:
                self._ref[p] += 1
            if cow is not None:
                self._ref[cow[0]] += 1  # pin the COW source vs eviction
                cow_pins.append(cow[0])
            alloc = self._alloc(need)
            for p in alloc:
                self._ref[p] += 1
            if cow is not None:
                cow_src[b], cow_dst[b] = cow[0], alloc[0]
                matched += cow[1]
                n_cow += 1
            pages = mpages + alloc
            rows[b, :len(pages)] = pages
            plen[b] = matched
            s = _Slot(req, pages, fill=matched, node=node,
                      n_indexed=len(mpages))
            if matched >= len(req.prompt):
                # whole prompt cached: straight to decode, same resume
                # scheme as a completed prefill (last token, position L)
                s.pos = len(req.prompt)
                s.last_tok = int(req.prompt[-1])
            self.slots[b] = s
            mask[b] = True
            self.stats["admissions"] += 1
            if matched:
                self.stats["prefix_hits"] += 1
                self.stats["prefix_tokens_reused"] += matched
        if mask.any():
            self.stats["pages_in_use_peak"] = max(
                self.stats["pages_in_use_peak"], int((self._ref > 0).sum()))
            if n_cow:
                # device-side ordering is by data dependency (copy feeds the
                # reset feeds the tick), so the host may unpin right away
                state = self._copy(state, cow_src, cow_dst)
                self.stats["cow_copies"] += n_cow
            self._release_pages(cow_pins)
            state = self._reset(state, self._template, mask, rows, plen)
        return state

    # -- sampling / bookkeeping -------------------------------------------
    def _sample(self, req: Request, logits_row: np.ndarray) -> int:
        """One token from a (V,) logits row: greedy argmax at temperature 0,
        seeded temperature/top-k sampling otherwise (one RNG draw per token,
        so output is independent of how ticks were packed)."""
        if req.temperature == 0.0:
            return int(np.argmax(logits_row))
        logit = logits_row.astype(np.float64) / req.temperature
        if req.top_k is not None and req.top_k < logit.size:
            kth = np.partition(logit, -req.top_k)[-req.top_k]
            logit = np.where(logit >= kth, logit, -np.inf)
        logit = logit - logit.max()
        p = np.exp(logit)
        p /= p.sum()
        return int(self._rngs[req.uid].choice(logit.size, p=p))

    def _finish_token(self, b: int, tok: int, results: Dict) -> None:
        """Book one sampled token for slot ``b``: emit, advance, retire the
        request (releasing its page refs) on EOS / max_tokens."""
        s = self.slots[b]
        req = s.req
        req.out_tokens.append(tok)
        s.pos += 1
        self.token_log.append((req.uid, self.stats["ticks"],
                               time.perf_counter()))
        if (len(req.out_tokens) >= req.max_tokens
                or (req.eos_id is not None and tok == req.eos_id)):
            results[req.uid] = req.out_tokens
            self.completion_order.append(req.uid)
            self._release_slot(b)
        else:
            s.last_tok = tok

    # -- ragged path ------------------------------------------------------
    def _ragged_tick(self, state):
        """Pack one token budget and run the single ragged program.

        Decode first (no decoding slot ever stalls), then prefill chunks in
        slot order until the budget runs out; a slot whose prompt completes
        in this pack appends its first decode token right behind it.  Slots
        admitted on a full prefix hit enter the decode section on their very
        first tick — the whole prefill phase is skipped."""
        T, W = self.budget, self.chunk + 1
        tokens = np.zeros(T, np.int32)
        slot = np.zeros(T, np.int32)
        q_pos = np.zeros(T, np.int32)
        seq_idx = np.full(T, W, np.int32)
        valid = np.zeros(T, bool)
        logit_idx = np.full(self.B, T, np.int32)
        n = 0
        sampling: List[int] = []
        for b, s in enumerate(self.slots):
            if s is None or s.fill < len(s.req.prompt):
                continue
            tokens[n] = s.last_tok
            slot[n] = b
            q_pos[n] = s.pos
            seq_idx[n] = 0
            valid[n] = True
            logit_idx[b] = n
            sampling.append(b)
            n += 1
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.req.prompt)
            if s.fill >= L or n >= T:
                continue
            c = min(self.chunk, L - s.fill, T - n)
            tokens[n:n + c] = s.req.prompt[s.fill:s.fill + c]
            slot[n:n + c] = b
            q_pos[n:n + c] = s.fill + np.arange(c)
            seq_idx[n:n + c] = np.arange(c)
            valid[n:n + c] = True
            n += c
            s.fill += c
            self._index_filled_pages(s)
            if s.fill >= L:
                # decode resumes from the last prompt token at position L
                # (same scheme as the reference engine, for token identity)
                s.pos = L
                s.last_tok = int(s.req.prompt[-1])
                if n < T:
                    tokens[n] = s.last_tok
                    slot[n] = b
                    q_pos[n] = s.pos
                    seq_idx[n] = c
                    valid[n] = True
                    logit_idx[b] = n
                    sampling.append(b)
                    n += 1
        results: Dict[int, List[int]] = {}
        if n == 0:
            return state, results
        logits, state = self._ragged_step(self.params, state, tokens, slot,
                                          q_pos, seq_idx, valid, logit_idx)
        self.stats["ragged_ticks"] += 1
        self.stats["packed_tokens"] += n
        if sampling:
            rows = np.asarray(logits)  # (B, V)
            for b in sampling:
                self._finish_token(b, self._sample(self.slots[b].req,
                                                   rows[b]), results)
        return state, results

    # -- legacy two-phase path (PR 1, kept behind ragged=False) -----------
    def _prefill_tick(self, state):
        """Advance every slot with outstanding prompt tokens by one chunk —
        a single batched (B, chunk) call with per-slot positions."""
        C = self.chunk
        tokens = np.zeros((self.B, C), np.int32)
        q_pos = np.zeros((self.B, C), np.int32)
        valid = np.zeros((self.B, C), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            L = len(s.req.prompt)
            if s.fill >= L:
                continue
            n = min(C, L - s.fill)
            tokens[b, :n] = s.req.prompt[s.fill:s.fill + n]
            q_pos[b] = s.fill + np.arange(C)
            valid[b, :n] = True
            s.fill += n
            self._index_filled_pages(s)
            if s.fill >= L:
                s.pos = L
                s.last_tok = int(s.req.prompt[-1])
        _, state = self._chunk_step(self.params, state, tokens, q_pos, valid)
        self.stats["chunk_ticks"] += 1
        return state

    def _decode_tick(self, state):
        tokens = np.zeros((self.B, 1), np.int32)
        q_pos = np.zeros((self.B, 1), np.int32)
        valid = np.zeros((self.B, 1), bool)
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            tokens[b, 0] = s.last_tok
            q_pos[b, 0] = s.pos
            valid[b, 0] = True
        logits, state = self._decode_step(self.params, state, tokens, q_pos,
                                          valid)
        rows = np.asarray(logits[:, -1])
        self.stats["decode_ticks"] += 1
        results: Dict[int, List[int]] = {}
        for b, s in enumerate(self.slots):
            if s is None:
                continue
            self._finish_token(b, self._sample(s.req, rows[b]), results)
        return state, results

    # -- driving ----------------------------------------------------------
    @property
    def idle(self) -> bool:
        """No live slot and nothing queued."""
        return all(s is None for s in self.slots) and not self.queue

    def _ensure_state(self):
        """Decode state is created once and persists for the engine's whole
        life: freeing it between runs would throw away the prefix cache (the
        pool's pages ARE the cached data)."""
        if self._state is None:
            self._state = M.init_paged_state(
                self.params, self.cfg, self.B, self.cache_len,
                page_size=self.page_size, n_pages=self.n_pages,
                window_extra=self.chunk, kv_dtype=self.kv_dtype)
            # the reset template must not alias the (donated) live state
            self._template = jax.tree.map(jax.numpy.copy, self._state)

    def tick(self) -> Dict[int, List[int]]:
        """One scheduling tick: admit from the queue, pack, run one program
        step.  Returns the requests that finished this tick ({uid: tokens}).
        Public so continuous-arrival drivers (benchmarks/serve_sweep.py) can
        interleave ``submit`` with serving instead of draining a batch."""
        self._ensure_state()
        self._state = self._admit(self._state)
        had_prefill = any(s is not None and s.fill < len(s.req.prompt)
                          for s in self.slots)
        results: Dict[int, List[int]] = {}
        if self.ragged:
            self._state, results = self._ragged_tick(self._state)
        elif had_prefill:
            self._state = self._prefill_tick(self._state)
        elif any(s is not None for s in self.slots):
            self._state, results = self._decode_tick(self._state)
        self.stats["ticks"] += 1
        self.tick_log.append((had_prefill, time.perf_counter()))
        return results

    def run(self, max_ticks: int = 4096) -> Dict[int, List[int]]:
        """Drain the queue; returns {uid: generated tokens}."""
        results: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            if self.idle:
                break
            results.update(self.tick())
        # drain partials on tick-budget exhaustion, releasing slots/pages so
        # the engine stays reusable (no page leak, no stale decode state);
        # never-admitted requests report their (empty) partials too, so every
        # submitted uid is present in the result
        for b, s in enumerate(self.slots):
            if s is not None:
                results[s.req.uid] = s.req.out_tokens
                self._release_slot(b)
        while self.queue:
            req = self.queue.popleft()
            results[req.uid] = req.out_tokens
            self._rngs.pop(req.uid, None)
        return results
