"""Distributed flash-decode: single-token attention over a sharded KV cache.

One of the two decode-attention paths under ``serve/``:

- **This module** — the *sharded lock-step* path used by ``launch.serve``
  dry-runs and the distributed decode shapes: the KV cache's *sequence* dim
  is sharded (normal decode: over 'model'; long-context batch=1: over
  ('data','model')).  Each shard produces the partial online-softmax terms
  (local max, local sum, local weighted values); a pmax + two psums over the
  sequence axes combine them.  The communicated payload per layer is
  O(B·kvH·G·hd) — independent of context length — which is what makes
  32k–512k contexts serveable at all (an all-gathered KV would be GBs per
  layer per step).
- **The paged per-slot path** — ``models.layers.attention
  .paged_attention_step`` (jnp gather over block tables) and
  ``kernels.flash_attention.paged_flash_decode`` (Pallas, block table as
  scalar prefetch), driven by ``serve.engine.ServeEngine``.  Use that for
  mixed-length continuous batching; use this one when the KV of a single
  sequence outgrows one device.

This module also owns the tensor-parallel entry points of the paged flash
kernels (``tp_ragged_paged_flash`` / ``tp_paged_flash_decode``): under an
engine mesh the paged KV pools are sharded over the KV-head axis
(serve_step.STATE_AXES "act_kv_heads"), and since GSPMD cannot partition a
``pallas_call``, the kernels run inside an explicit ``shard_map`` over that
axis — each shard dequantizes and attends over ONLY its head slice of the
pools (block tables, slots, and lengths are replicated control data).
Per-KV-head attention has no cross-shard reduction (softmax normalizes over
the unsharded context axis), so no collective appears here; the single
cross-head contraction lives downstream in the out-projection.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import current_mesh, current_rules, shard_map

NEG_INF = -1e30


def _partial_terms(q, k, v, k_pos, pos, window):
    """q: (B,1,kvH,G,hd); k,v: (B,T,kvH,hd); k_pos: (T,).
    Returns (m (B,kvH,G), l (B,kvH,G), o (B,kvH,G,hd))."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgd,btkd->bkgt", q, k).astype(jnp.float32) * scale
    ok = (k_pos >= 0) & (k_pos <= pos)
    if window is not None:
        ok &= (pos - k_pos) < window
    s = jnp.where(ok[None, None, None, :], s, NEG_INF)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(ok[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgt,btkd->bkgd", p.astype(v.dtype), v).astype(jnp.float32)
    return m, l, o


def sp_flash_decode(q, k, v, k_pos, pos, window: Optional[int] = None):
    """Returns (B, 1, kvH, G, hd).  Falls back to the local computation when
    no mesh / no KV-seq sharding is active (unit tests, single host)."""
    mesh = current_mesh()
    rules = current_rules()
    seq_ax = rules.get("act_kv_seq")
    if mesh is None or not seq_ax:
        m, l, o = _partial_terms(q, k, v, k_pos, pos, window)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out[:, None].astype(q.dtype)  # (B,1,kvH,G,hd)

    seq_ax = (seq_ax,) if isinstance(seq_ax, str) else tuple(seq_ax)
    batch_ax = rules.get("act_kv_batch") or ()
    batch_ax = (batch_ax,) if isinstance(batch_ax, str) else tuple(batch_ax)
    bspec = batch_ax if batch_ax else None

    def local_fn(q, k, v, k_pos, pos):
        m, l, o = _partial_terms(q, k, v, k_pos, pos, window)
        m_g = jax.lax.pmax(m, seq_ax)
        corr = jnp.exp(m - m_g)
        l = jax.lax.psum(l * corr, seq_ax)
        o = jax.lax.psum(o * corr[..., None], seq_ax)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.astype(q.dtype).reshape(q.shape)

    return shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(P(bspec), P(bspec, seq_ax), P(bspec, seq_ax), P(seq_ax), P()),
        out_specs=P(bspec),
    )(q, k, v, k_pos, pos)


# ---------------------------------------------------------------------------
# KV-head tensor-parallel paged flash (serving engine mesh= path)


def _head_tp(kvH: int):
    """Resolve the active KV-head shard setup: (mesh, head_axis) when a mesh
    is ambient, the rules map "act_kv_heads" to a mesh axis, and the axis
    size divides ``kvH`` — else (None, None) (run the kernel unsharded)."""
    mesh = current_mesh()
    if mesh is None:
        return None, None
    head_ax = current_rules().get("act_kv_heads")
    if not head_ax:
        return None, None
    axes = (head_ax,) if isinstance(head_ax, str) else tuple(head_ax)
    tp = 1
    for a in axes:
        tp *= mesh.shape[a]
    if tp == 1 or kvH % tp != 0:
        return None, None
    return mesh, head_ax


def tp_ragged_paged_flash(q, kp, vp, ptab, slot, lens, ks=None, vs=None):
    """``kernels.ops.ragged_paged_flash`` under the engine mesh: shard_map
    over the KV-head axis of q and the paged pools (values + int8 scales);
    ptab/slot/lens replicate.  Falls back to the plain kernel call with no
    mesh, no "act_kv_heads" rule, or an indivisible head count."""
    from repro.kernels import ops as kops

    mesh, h = _head_tp(q.shape[1])
    if mesh is None:
        return kops.ragged_paged_flash(q, kp, vp, ptab, slot, lens,
                                       ks=ks, vs=vs)
    qspec, pspec, sspec = P(None, h, None, None), P(None, None, h, None), \
        P(None, None, h)
    if ks is None:
        return shard_map(
            lambda q, kp, vp, ptab, slot, lens: kops.ragged_paged_flash(
                q, kp, vp, ptab, slot, lens),
            mesh=mesh, in_specs=(qspec, pspec, pspec, P(), P(), P()),
            out_specs=qspec)(q, kp, vp, ptab, slot, lens)
    return shard_map(
        lambda q, kp, vp, ptab, slot, lens, ks, vs: kops.ragged_paged_flash(
            q, kp, vp, ptab, slot, lens, ks=ks, vs=vs),
        mesh=mesh,
        in_specs=(qspec, pspec, pspec, P(), P(), P(), sspec, sspec),
        out_specs=qspec)(q, kp, vp, ptab, slot, lens, ks, vs)


def tp_paged_flash_decode(q, kp, vp, ptab, lens, ks=None, vs=None):
    """``kernels.ops.paged_flash_decode`` under the engine mesh (lock-step
    C==1 decode shape, q: (B,kvH,G,hd)); same sharding contract as
    ``tp_ragged_paged_flash``."""
    from repro.kernels import ops as kops

    mesh, h = _head_tp(q.shape[1])
    if mesh is None:
        return kops.paged_flash_decode(q, kp, vp, ptab, lens, ks=ks, vs=vs)
    qspec, pspec, sspec = P(None, h, None, None), P(None, None, h, None), \
        P(None, None, h)
    if ks is None:
        return shard_map(
            lambda q, kp, vp, ptab, lens: kops.paged_flash_decode(
                q, kp, vp, ptab, lens),
            mesh=mesh, in_specs=(qspec, pspec, pspec, P(), P()),
            out_specs=qspec)(q, kp, vp, ptab, lens)
    return shard_map(
        lambda q, kp, vp, ptab, lens, ks, vs: kops.paged_flash_decode(
            q, kp, vp, ptab, lens, ks=ks, vs=vs),
        mesh=mesh, in_specs=(qspec, pspec, pspec, P(), P(), sspec, sspec),
        out_specs=qspec)(q, kp, vp, ptab, lens, ks, vs)
