"""The jit-compiled serving steps + state sharding rules.

Two step builders live here:

- ``make_serve_step`` — the legacy lock-step decode step (one token per
  slot, shared positions; kept for the reference engine path).
- ``make_ragged_step`` — the serving engine's ONE compiled program: a flat
  (T,) token pack in which every entry carries its own (slot, position,
  validity), so any mix of prefill-chunk tokens and decode tokens runs
  through a single trace.  ``width`` (max tokens any slot contributes to a
  pack) and ``flash_decode`` are compile-time constants; everything else is
  data, which is what keeps the program count at exactly one regardless of
  traffic.

Neither the engine's prefix cache nor its pluggable scheduler adds a step
builder: sharing is an allocator concern (``serve.pool.PagePool``) and
scheduling is a host-side ORDERING concern (``serve.scheduler``) — a policy
only permutes which (slot, position) pairs fill the pack vectors.  Block-
table rows of several slots may alias one pool page; the ragged step reads
KV through ptab either way, admission presets kpos/slen for inherited
positions via ``models.model.reset_paged_slots`` (a separate control-plane
program, like the COW page copy ``models.model.copy_kv_pages``), and the
serve-path trace count stays at exactly one for every policy.

The TIERED pool (``host_pages=``) adds two more control-plane programs, the
page movers built by ``make_page_gather`` / ``make_page_insert``: demotion
gathers one page's rows (kp/vp values and int8 ks/vs scale rows together)
out to host RAM, promotion scatters them back into a freshly allocated
device page.  Both take the page id as DATA (one trace each for the
engine's lifetime), and the insert is jitted with the state donated — same
contracts as the COW copy, so tiering never perturbs the serve-path trace
count or the no-copy hot loop.  PREEMPTION rides the same two movers: a
victim's private pages PARK via the demotion gather and resume UNPARKS
them via the promotion insert — swap-to-host adds zero new programs, only
pool bookkeeping (``PagePool.park`` / ``unpark``), so ``stats["traces"]``
stays 1 through preempt/resume cycles too.

SPECULATIVE decoding is, by the same argument, just a packing policy: the
drafter proposes k continuation tokens for a decoding slot and the engine
packs them at the slot's next k positions inside the SAME (T,) budget —
to the compiled program they are indistinguishable from any other valid
(slot, position) entries, and ``logit_idx`` widening from (B,) to (B, R)
merely asks the LM head for R rows per slot instead of one.  Verification
is the forward itself (row j's logits are the model's prediction given
the draft prefix up to j); accept/rollback is host-side bookkeeping plus
ONE more control-plane program, ``make_spec_rollback``, which drops the
kpos/slen metadata of rejected draft rows (``models.model
.rollback_paged_slots``).  No draft, accept, or reject path ever adds a
serve-path trace: ``stats["traces"]`` stays 1 with speculation on.

``STATE_AXES`` names the logical axes of every decode-state leaf — the
lock-step cache (k/v/k_pos/pos) and the ragged/paged engine's leaves (kp/vp
page pools, ptab block tables, kpos per-slot positions, slen fill counts) —
so ``decode_state_specs`` can lay either state out on a mesh.  The ragged
pack's own vectors (tokens/slot/q_pos/seq_idx/valid) are replicated: they
are (T,)-sized control data, not state.

Under the serving engine's ``mesh=`` (rules from ``parallel.sharding
.make_serve_rules``) exactly one logical axis maps to hardware:
"act_kv_heads" — so the page pools and int8 scale pools split along their
KV-head dim while ptab/kpos/slen and the pack vectors replicate.  That
shard-split pool layout is the whole device-side story of serving TP: a
logical page id (what PagePool allocates, refcounts, and evicts) names the
SAME page on every device, each device merely storing its slice of the
page's heads — which is why the host bookkeeping needs no knowledge of the
device count and one traced program serves any mesh size.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.parallel.sharding import logical_spec


# argnum of the decode-state pytree in every step builder's signature: jit
# with ``donate_argnums=(STATE_DONATE_ARGNUM,)`` so the pools update in place
STATE_DONATE_ARGNUM = 1


def make_serve_step(cfg: ModelCfg, *, sp_decode: bool = False):
    def serve_step(params, state, tokens_t):
        return M.decode_step(params, cfg, state, tokens_t, sp_decode=sp_decode)

    return serve_step


def make_ragged_step(cfg: ModelCfg, *, width: int, flash_decode: bool = False):
    """Build the single ragged serving program (see ``models.model.ragged_step``).

    Returns ``f(params, state, tokens, slot, q_pos, seq_idx, valid,
    logit_idx) -> (logits (B, V), new_state)`` with all pack vectors (T,)
    and ``logit_idx`` (B,).  Jit it with ``donate_argnums=(1,)``
    (``STATE_DONATE_ARGNUM``) — the KV page pools, their int8 scale pools,
    and the recurrent-state carries dominate the state pytree, and donation
    lets XLA scatter the tick's new entries into the existing buffers
    instead of copying the whole pool every tick (the hot-loop no-copy
    contract; asserted by buffer-pointer identity in tests/test_kv_quant.py
    on backends that support donation).
    """

    def ragged_step(params, state, tokens, slot, q_pos, seq_idx, valid,
                    logit_idx):
        return M.ragged_step(params, cfg, state, tokens, slot, q_pos,
                             seq_idx, valid, logit_idx, width=width,
                             flash_decode=flash_decode)

    return ragged_step


def make_page_gather(cfg: ModelCfg):
    """Demotion mover: ``f(state, page) -> {path: rows}`` pulling one pool
    page's K/V values and int8 scale rows out of every paged leaf (see
    ``models.model.gather_kv_page``).  Jit WITHOUT donation — the state
    stays live; the engine materializes the result into host RAM."""
    def page_gather(state, page):
        return M.gather_kv_page(cfg, state, page)

    return page_gather


def make_page_insert(cfg: ModelCfg):
    """Promotion mover: ``f(state, page_data, page) -> state`` scattering a
    demoted page's rows back into the pools at device page ``page``.  Jit
    with ``donate_argnums=(0,)`` so the pools update in place; the engine
    issues it at admission and lets async dispatch overlap the copy with
    the tick's compute (see ``models.model.insert_kv_page``)."""
    def page_insert(state, page_data, page):
        return M.insert_kv_page(cfg, state, page_data, page)

    return page_insert


def make_spec_rollback(cfg: ModelCfg):
    """Speculative-rejection mover: ``f(state, mask, new_len) -> state``
    invalidating every masked slot's KV rows at positions >= new_len
    (kpos -> -1, slen clamped; pools/scales/ptab untouched — see
    ``models.model.rollback_paged_slots``).  Jit with
    ``donate_argnums=(0,)``; the engine dispatches it only on ticks that
    rejected a draft tail, and it traces once for the engine's lifetime
    like every other control-plane program."""
    def spec_rollback(state, mask, new_len):
        return M.rollback_paged_slots(cfg, state, mask, new_len)

    return spec_rollback


# leaf name -> logical axes for decode-state leaves (unstacked; a scanned
# stage adds a leading "layer" dim)
STATE_AXES: Dict[str, tuple] = {
    # attention KV cache (lock-step engine)
    "k": ("act_kv_batch", "act_kv_seq", "act_kv_heads", None),
    "v": ("act_kv_batch", "act_kv_seq", "act_kv_heads", None),
    "k_pos": ("act_kv_seq",),
    "pos": (),
    # paged KV (per-slot engine): page pools shard over KV heads; block
    # tables / positions are per-slot and follow the batch axis.  int8
    # pools add per-entry scale pools (ks/vs) that shard with their pages.
    "kp": (None, None, "act_kv_heads", None),
    "vp": (None, None, "act_kv_heads", None),
    "ks": (None, None, "act_kv_heads"),
    "vs": (None, None, "act_kv_heads"),
    "ptab": ("act_kv_batch", None),
    "kpos": ("act_kv_batch", None),
    "slen": ("act_kv_batch",),
    # mamba
    "h": ("act_kv_batch", "tensor", None),
    "conv": ("act_kv_batch", None, "tensor"),
    # mlstm (matrix memory replicated over 'model'; it is small)
    "C": ("act_kv_batch", None, None, None),
    "n": ("act_kv_batch", None, None),
    "m": ("act_kv_batch", None),
    # slstm
    "sh": ("act_kv_batch", None),
    "sc": ("act_kv_batch", None),
    "sn": ("act_kv_batch", None),
    "sm": ("act_kv_batch", None),
}


def _state_leaf_spec(path, leaf, rules, mesh=None):
    name = None
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            name = p.key
            break
    if name not in STATE_AXES:
        raise ValueError(f"no sharding rule for decode-state leaf {path}")
    axes = STATE_AXES[name]
    if len(leaf.shape) == len(axes) + 1:
        axes = ("layer",) + axes
    elif len(leaf.shape) != len(axes):
        raise ValueError(f"state leaf {name}: ndim {len(leaf.shape)} vs rule {len(axes)}")
    spec = logical_spec(axes, rules)
    if mesh is not None:
        from repro.parallel.sharding import sanitize_spec

        spec = sanitize_spec(spec, leaf.shape, mesh)
    return spec


def decode_state_specs(state_shapes, rules=None):
    """PartitionSpec tree for an init_decode_state() pytree."""
    from repro.parallel.sharding import current_mesh, current_rules

    rules = rules if rules is not None else current_rules()
    mesh = current_mesh()
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: _state_leaf_spec(path, leaf, rules, mesh), state_shapes)
