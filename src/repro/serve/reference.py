"""The seed (pre-paging) serving engine, kept verbatim as the correctness
and throughput baseline.

Limitations that motivated the rebuild in ``engine.py``: prompts are
prefilled one slot at a time with batch-1 forwards (one compile per distinct
prompt length, sequential host round-trips), every slot pays ``cache_len``
KV regardless of sequence length, and positions are lock-step across slots
(shared ``k_pos``/``pos``) so only equal-length prompt waves decode
correctly.  Tests pin the paged engine token-for-token against this engine
on equal-length traffic; ``benchmarks/serve_sweep.py`` scores the speedup.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg
from repro.models import model as M
from repro.serve.handle import Request  # noqa: F401  (moved; re-exported)


class ReferenceEngine:
    def __init__(self, params, cfg: ModelCfg, *, batch_size: int = 4,
                 cache_len: int = 256, greedy: bool = True):
        self.params = params
        self.cfg = cfg
        self.B = batch_size
        self.cache_len = cache_len
        self._decode = jax.jit(
            lambda p, s, t: M.decode_step(p, cfg, s, t))
        self.queue: deque = deque()
        self.slots: List[Optional[Request]] = [None] * batch_size
        self._uid = 0

    def submit(self, prompt, max_tokens: int = 16, eos_id=None) -> int:
        self._uid += 1
        self.queue.append(Request(self._uid, np.asarray(prompt, np.int32),
                                  max_tokens, eos_id))
        return self._uid

    # -- internals --------------------------------------------------------
    def _fill_slots(self, state, last_tok):
        """Prefill queued requests into free slots (one at a time: per-slot
        prefill uses a batch-1 forward and writes that slot's cache rows)."""
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            self.slots[b] = req
            one = M.init_decode_state(self.params, self.cfg, 1, self.cache_len)
            one = M.prefill(self.params, self.cfg, one, req.prompt[None, :])
            state = _write_slot(state, one, b)
            last_tok = last_tok.at[b, 0].set(int(req.prompt[-1]))
        return state, last_tok

    def run(self, max_ticks: int = 256) -> Dict[int, List[int]]:
        """Drain the queue; returns {uid: generated tokens}."""
        state = M.init_decode_state(self.params, self.cfg, self.B,
                                    self.cache_len)
        last_tok = jnp.zeros((self.B, 1), jnp.int32)
        results: Dict[int, List[int]] = {}
        for _ in range(max_ticks):
            if all(s is None for s in self.slots) and not self.queue:
                break
            state, last_tok = self._fill_slots(state, last_tok)
            logits, state = self._decode(self.params, state, last_tok)
            nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            nxt_host = np.asarray(nxt)
            for b, req in enumerate(self.slots):
                if req is None:
                    continue
                tok = int(nxt_host[b])
                req.out_tokens.append(tok)
                if (len(req.out_tokens) >= req.max_tokens
                        or (req.eos_id is not None and tok == req.eos_id)):
                    results[req.uid] = req.out_tokens
                    self.slots[b] = None
                else:
                    last_tok = last_tok.at[b, 0].set(tok)
        for req in self.slots:  # drain partials on tick budget exhaustion
            if req is not None:
                results[req.uid] = req.out_tokens
        return results


def _write_slot(state, one, b: int):
    """Copy a batch-1 decode state into slot ``b`` of the pooled state.

    Positions are lock-step across slots (k_pos is shared per layer), so the
    engine admits equal-length prompt waves; per-slot position tracking
    lives in the paged engine (serve/engine.py).  Recurrent states are
    per-batch-row and copy cleanly.
    """
    flat_p, treedef = jax.tree_util.tree_flatten_with_path(state)
    flat_o = [l for _, l in jax.tree_util.tree_flatten_with_path(one)[0]]
    out = []
    for (path, pl), sl in zip(flat_p, flat_o):
        name = None
        for p in reversed(path):
            if isinstance(p, jax.tree_util.DictKey):
                name = p.key
                break
        if pl.ndim == sl.ndim and pl.shape == sl.shape and pl.ndim == 0:
            out.append(jnp.maximum(pl, sl))  # scalar pos: lock-step max
        elif name == "k_pos":
            out.append(sl)  # shared slot positions (lock-step)
        else:
            # batch dim is the first dim whose size differs (pool B vs 1)
            axis = next((i for i, (a, c) in enumerate(zip(pl.shape, sl.shape))
                         if a != c), None)
            if axis is None:
                out.append(sl)
            else:
                out.append(jax.lax.dynamic_update_slice_in_dim(
                    pl, sl.astype(pl.dtype), b, axis))
    return jax.tree_util.tree_unflatten(treedef, out)
