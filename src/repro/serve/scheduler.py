"""Scheduler — the serving stack's pluggable workload-policy layer.

The paper's grid result (every Nproc × Nthread mix stays near peak once the
system settings are fixed) holds because the resource-management layer is
UNIFORM beneath diverse workloads.  The serving analogue: `serve.pool.
PagePool` (the settings layer) and the engine's single compiled program are
fixed, and everything workload-shaped — WHICH queued request is admitted
next, and in WHAT ORDER slots contribute tokens to a tick's pack — is a
policy object behind this module's ``Scheduler`` protocol.  Swapping the
policy never touches memory management or the compiled step, so every
policy inherits the no-mid-flight-OOM and one-trace guarantees.

A scheduler sees a read-only ``EngineView`` snapshot and returns ORDERINGS;
the engine keeps all mechanism (feasibility checks, page reservation,
chunking, budget accounting).  Since the preemption PR the protocol has a
fourth consultation, ``preempt_order``: when admission cannot make progress
because IN-FLIGHT requests exhaust the pool (not merely a deep queue), the
engine asks the policy to rank candidate victim slots; the engine then
preempts the first victim whose release actually makes the stalled head
admissible (pages park to the host tier, the request re-queues and later
resumes token-identically — all mechanism, all engine-side).  The default
ranking is lowest priority first, then youngest; ``SloScheduler`` and
``ClassThenFamilyScheduler`` additionally refuse to victimize the
interactive class (priority >= 1) entirely — batch work is what soaks up
preemption.  Two invariants the engine enforces no matter the policy:

- **Admission stops at the first infeasible candidate** — a request is
  admitted only when the pages it actually needs (its unmatched suffix
  after the prefix match) fit in free + evictable supply, so no policy can
  cause a mid-flight OOM or strand the pool.
- **Every decoding slot packs one token per tick** (``token_budget >=
  batch_size``) — reordering decides priority within the pack, never
  whether a decoder stalls.

Policies:

- ``FifoScheduler`` — strict arrival order, slot-index pack order.  This is
  bit-identical to the pre-refactor engine (PR 1–4): same queue walk, same
  page-allocator call sequence, same pack layout, token-for-token.
- ``PrefixAwareScheduler`` — reorders a bounded window at the head of the
  queue (``depth``) so requests sharing a cached or in-flight prefix land
  in the same admission wave: the window is grouped by prefix family (first
  full page of the prompt — exactly the trie's first key), warm families
  (longest indexed match, probed without touching LRU state) first so a
  resident prefix is reused before eviction pressure can reclaim it, cold
  families kept contiguous so the family head indexes pages its siblings
  hit in-flight a tick later.  Fairness degrades gracefully: order beyond
  the window is untouched, and a head of line displaced ``max_bypass``
  times — actually overtaken, OR stuck behind a never-admitting proposal —
  pins the next round to strict FIFO, so no request waits more than a
  bounded number of admission rounds beyond its FIFO turn.
- ``SloScheduler`` — interactive-vs-batch classes from ``Request.priority``
  (>= 1 = interactive): interactive requests admit first within a bounded
  window, and interactive slots' prefill chunks take the leftover budget
  ahead of batch documents', so an interactive arrival's
  time-to-first-token never queues behind a batch prefill.  (Decode needs
  no ordering: the engine invariant ``token_budget >= batch_size`` packs
  every ready slot's token every tick regardless.)  Within a class, FIFO.
  Under a saturating interactive stream a batch head of line is bypassed
  at most ``max_bypass`` times before a strict-FIFO round admits it —
  priority inverts latency, never liveness.
- ``ClassThenFamilyScheduler`` — the composite: SLO class first, then
  prefix-family grouping within each class, sharing SloScheduler's prefill
  packing.  Tier-aware via ``EngineView.match_split``: within a class,
  device-warm families admit before host-warm before cold (a host hit pays
  a promotion copy; a miss pays re-prefill).
- ``SpeculativeScheduler`` — a WRAPPER, not a peer policy: it delegates
  all three orderings to an inner policy (any of the above) untouched and
  adds the one thing speculation needs from the policy layer, a
  ``draft(history, k)`` method proposing up to k continuation tokens by
  prompt lookup (``prompt_lookup_draft``: match the tail n-gram of the
  slot's own prompt+output history against an earlier occurrence — no
  second model).  The engine packs the proposed chain into the leftover
  token budget after decode-first packing and verifies it in the same
  forward; accept/rollback is the engine's concern.  Speculation is thus
  literally a packing policy — it composes with every admission/ordering
  policy and inherits the one-trace and no-OOM guarantees unchanged.

``benchmarks/serve_sweep.py:scheduler_ab_scenario`` A/Bs the policies on mixed
shared-prefix Poisson traffic; ``core.autotune.select_serve_defaults``
carries a ``scheduler`` axis so the tuned-once serving config names its
policy alongside token_budget / page_size / kv_dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serve.handle import Request


@dataclasses.dataclass(frozen=True)
class EngineView:
    """Read-only snapshot the engine hands a scheduler each consultation.

    ``queue``/``slot_requests`` reference live ``Request`` objects —
    schedulers must treat them as immutable.  ``match_len`` is
    ``PagePool.probe_prefix_len``: tokens of a prompt covered by indexed
    full pages, probed WITHOUT mutating LRU state.  ``match_split`` is the
    tier-aware refinement (``PagePool.probe_prefix_split``): the same
    tokens split (device, host) — a device hit is free, a host hit costs a
    promotion copy, a miss costs re-prefill — so policies can rank the
    three candidate classes warm > host-warm > cold.  ``None`` when the
    engine predates tiering (policies fall back to ``match_len``).

    For ``decode_order``/``prefill_order`` consultations ``queue`` is
    EMPTY: pack ordering is a slots concern, and snapshotting a deep
    backlog every tick would tax the hot loop for nothing.  The full queue
    is present for ``admission_order``."""

    queue: Tuple[Request, ...]
    slot_requests: Tuple[Optional[Request], ...]  # None = free slot
    slot_fill: Tuple[int, ...]  # prompt tokens already in cache, per slot
    budget: int
    chunk: int
    page_size: int
    match_len: Callable[[np.ndarray], int]
    match_split: Optional[Callable[[np.ndarray], Tuple[int, int]]] = None


class Scheduler:
    """Protocol + neutral defaults (identity orderings == FIFO).

    Subclass and override any subset; returned orderings may be lazy
    sequences.  ``admission_order`` returns indices into ``view.queue``
    (a permutation prefix is fine — omitted indices just wait);
    ``decode_order``/``prefill_order`` reorder the slot-id lists the engine
    computed (return them unchanged for slot-index order)."""

    name = "scheduler"

    def admission_order(self, view: EngineView) -> Sequence[int]:
        return range(len(view.queue))

    def decode_order(self, view: EngineView,
                     ready: Sequence[int]) -> Sequence[int]:
        return ready

    def prefill_order(self, view: EngineView,
                      filling: Sequence[int]) -> Sequence[int]:
        return filling

    def preempt_order(self, view: EngineView,
                      victims: Sequence[int]) -> Sequence[int]:
        """Rank candidate victim slots for preemption (best victim first);
        return a subsequence to EXEMPT slots (an omitted slot is never
        victimized).  Default: lowest ``Request.priority`` first, youngest
        (highest uid) within a class — cheap work lost, old work kept."""
        return sorted(victims,
                      key=lambda b: (view.slot_requests[b].priority,
                                     -view.slot_requests[b].uid))


class FifoScheduler(Scheduler):
    """Strict arrival-order admission, slot-index pack order — the PR 1–4
    behavior, bit-identical (the identity policy)."""

    name = "fifo"


class _BoundedReorderScheduler(Scheduler):
    """Shared fairness bookkeeping for window-reordering policies.

    Subclasses implement ``_reorder(view)`` (any permutation of the queue
    indices that leaves order beyond ``depth`` untouched); this base
    guarantees the head of line waits at most ``max_bypass`` rounds of
    EITHER kind of displacement before strict-FIFO rounds pin it to the
    front:

    - **overtakes** — some request the proposal ranked ahead of the head
      left the queue by the next consultation (admitted past it; a
      cancellation is miscounted — conservative and rare);
    - **stalls** — consecutive proposal rounds in which nobody was
      admitted at all.  Counting these is what makes the bound a LIVENESS
      guarantee: admission stops at the first infeasible candidate, so a
      reorder that ranks an infeasible request ahead of a feasible head
      would otherwise block the head indefinitely on an identical,
      never-progressing proposal.  An overtake (real progress) resets the
      stall count, so interleaved progress keeps the policy reordering.

    Both budgets refresh when the head is admitted (the head changes), so
    the backstop degrades a round to FIFO, never the policy."""

    def __init__(self, depth: int, max_bypass: int):
        if depth < 1 or max_bypass < 1:
            raise ValueError(f"bad bounds ({depth=}, {max_bypass=})")
        self.depth = depth
        self.max_bypass = max_bypass
        self._head_uid = None  # current head of line...
        self._overtakes = 0  # ...how often it was actually bypassed...
        self._stalls = 0  # ...and consecutive no-progress proposals
        self._proposed: Optional[frozenset] = None  # other uids at proposal

    def _reorder(self, view: EngineView) -> List[int]:
        raise NotImplementedError

    def admission_order(self, view: EngineView) -> Sequence[int]:
        q = view.queue
        if not q:
            return ()
        if q[0].uid != self._head_uid:
            # head admitted (or cancelled): fresh budget for the new head
            self._head_uid = q[0].uid
            self._overtakes = self._stalls = 0
            self._proposed = None
        elif self._proposed is not None:
            live = {r.uid for r in q}
            if any(u not in live for u in self._proposed):
                self._overtakes += 1
                self._stalls = 0
            else:
                self._stalls += 1
            self._proposed = None
        if max(self._overtakes, self._stalls) >= self.max_bypass:
            return range(len(q))  # fairness backstop: strict FIFO rounds
            # until this head finally admits (then the head change resets)
        order = self._reorder(view)
        if order and order[0] != 0:
            self._proposed = frozenset(r.uid for r in q[1:])
        return order


def _family_order(view: EngineView, idxs: Sequence[int]) -> List[int]:
    """Order queue indices ``idxs`` by shared-prefix family — the policy
    core the prefix-aware and class-then-family schedulers share.

    Family key = the trie's first key (first FULL prompt page; sub-page
    prompts can never share pages -> singleton families).  Families rank
    warmest-first so a resident prefix is reused before eviction pressure
    reclaims it, and with a tiered pool (``view.match_split``) DEVICE
    residency outranks HOST residency: a device hit is free, a host hit
    pays one promotion copy — warm > host-warm > cold, the three candidate
    classes of tiered admission.  Ties break FIFO by earliest member, and
    members stay in FIFO order within their family."""
    q, P = view.queue, view.page_size

    def family(r: Request):
        return (tuple(int(t) for t in r.prompt[:P])
                if len(r.prompt) >= P else ("solo", r.uid))

    def warmth(i: int) -> Tuple[int, int]:
        if view.match_split is not None:
            return view.match_split(q[i].prompt)
        return view.match_len(q[i].prompt), 0

    groups: Dict[tuple, List[int]] = {}
    for i in idxs:
        groups.setdefault(family(q[i]), []).append(i)
    ranked = sorted(groups.values(),
                    key=lambda g: (-max(warmth(i)[0] for i in g),
                                   -max(warmth(i)[1] for i in g), g[0]))
    return [i for g in ranked for i in g]


class PrefixAwareScheduler(_BoundedReorderScheduler):
    """Group the admission window by shared-prefix family (see module
    docstring and ``_family_order``).  ``depth`` bounds reordering;
    ``max_bypass`` bounds how many times the head of line can actually be
    overtaken."""

    name = "prefix-aware"

    def __init__(self, depth: int = 8, max_bypass: int = 4):
        super().__init__(depth, max_bypass)

    def _reorder(self, view: EngineView) -> List[int]:
        q = view.queue
        D = min(self.depth, len(q))
        return _family_order(view, range(D)) + list(range(D, len(q)))


class SloScheduler(_BoundedReorderScheduler):
    """Interactive-first admission and prefill packing by
    ``Request.priority`` (stable within a class, so each class is FIFO).
    ``depth`` bounds how far an interactive arrival may jump the admission
    queue; ``max_bypass`` bounds how many times a batch head of line can
    actually be jumped (the shared backstop — a saturating interactive
    stream may otherwise keep refilling the window).  ``decode_order`` is
    deliberately NOT overridden: every ready slot packs one decode token
    per tick whatever the order (engine invariant), so reordering there
    would change nothing but cost the hot loop a per-tick view."""

    name = "slo"

    def __init__(self, depth: int = 16, max_bypass: int = 4):
        super().__init__(depth, max_bypass)

    def _reorder(self, view: EngineView) -> List[int]:
        q = view.queue
        D = min(self.depth, len(q))
        window = sorted(range(D), key=lambda i: (-q[i].priority, i))
        return window + list(range(D, len(q)))

    def prefill_order(self, view: EngineView,
                      filling: Sequence[int]) -> Sequence[int]:
        return sorted(filling,
                      key=lambda b: (-view.slot_requests[b].priority, b))

    def preempt_order(self, view: EngineView,
                      victims: Sequence[int]) -> Sequence[int]:
        """Batch slots only, youngest first — the interactive class
        (priority >= 1) is NEVER victimized: preempting it would trade the
        latency SLO this policy exists to protect for batch throughput."""
        batch = [b for b in victims if view.slot_requests[b].priority < 1]
        return sorted(batch, key=lambda b: (view.slot_requests[b].priority,
                                            -view.slot_requests[b].uid))


class ClassThenFamilyScheduler(_BoundedReorderScheduler):
    """Composite policy: SLO class FIRST, prefix-family grouping WITHIN a
    class — the ROADMAP's ``slo × prefix-aware``.

    Admission partitions the window by ``Request.priority`` (higher class
    first, exactly SloScheduler's axis), then orders each class by
    ``_family_order`` — so an interactive arrival still never queues behind
    a batch prefill, while siblings of one shared prompt land in the same
    admission wave and a warm family admits before pressure reclaims its
    pages.  Tier-aware for free: ``_family_order`` reads
    ``EngineView.match_split``, so within a class device-resident families
    outrank host-resident ones outrank cold — the promotion-cost ordering
    of tiered admission.  Prefill packing is SloScheduler's
    (interactive chunks take leftover budget first); the fairness backstop
    is the shared ``_BoundedReorderScheduler`` bound."""

    name = "class-then-family"

    def __init__(self, depth: int = 16, max_bypass: int = 4):
        super().__init__(depth, max_bypass)

    def _reorder(self, view: EngineView) -> List[int]:
        q = view.queue
        D = min(self.depth, len(q))
        classes: Dict[int, List[int]] = {}
        for i in range(D):
            classes.setdefault(-q[i].priority, []).append(i)
        out: List[int] = []
        for c in sorted(classes):
            out.extend(_family_order(view, classes[c]))
        return out + list(range(D, len(q)))

    def prefill_order(self, view: EngineView,
                      filling: Sequence[int]) -> Sequence[int]:
        return sorted(filling,
                      key=lambda b: (-view.slot_requests[b].priority, b))

    def preempt_order(self, view: EngineView,
                      victims: Sequence[int]) -> Sequence[int]:
        """SloScheduler's rule: batch only, never the interactive class."""
        batch = [b for b in victims if view.slot_requests[b].priority < 1]
        return sorted(batch, key=lambda b: (view.slot_requests[b].priority,
                                            -view.slot_requests[b].uid))


def prompt_lookup_draft(history, k: int, *, ngram_max: int = 3,
                        ngram_min: int = 1) -> List[int]:
    """Propose up to ``k`` continuation tokens for ``history`` (the slot's
    prompt + emitted output, a 1-D int sequence) by prompt lookup: find the
    longest tail n-gram (``ngram_max`` down to ``ngram_min`` tokens) that
    also occurs earlier in the history, and return the tokens that followed
    its LATEST earlier occurrence.  Longer n-grams are tried first (more
    context -> higher acceptance), and among equal-length matches the most
    recent wins (recent continuations track the current phrase).  Returns
    [] when nothing repeats — the engine simply packs no drafts for the
    slot that tick, so lookup misses cost zero model work."""
    h = np.asarray(history, dtype=np.int64).ravel()
    n = h.size
    if k < 1 or n < ngram_min + 1:
        return []
    for g in range(min(ngram_max, n - 1), ngram_min - 1, -1):
        tail = h[n - g:]
        win = np.lib.stride_tricks.sliding_window_view(h[:-1], g)
        hits = np.flatnonzero((win == tail).all(axis=1))
        # scan latest-first; skip matches whose continuation is empty
        for i in hits[::-1]:
            cont = h[i + g:i + g + k]
            if cont.size:
                return [int(t) for t in cont]
    return []


class SpeculativeScheduler(Scheduler):
    """Compose speculative drafting onto any policy: orderings delegate to
    ``inner`` verbatim (so pack composition, admission fairness, and SLO
    behavior are bit-identical to the wrapped policy), and ``draft``
    supplies per-slot prompt-lookup chains of depth <= ``spec_k`` that the
    engine appends to the pack's leftover budget.  ``inner`` accepts
    anything ``make_scheduler`` does (None -> FIFO, a name, an object)."""

    def __init__(self, inner=None, *, spec_k: int = 4, ngram_max: int = 3,
                 ngram_min: int = 1):
        if spec_k < 1:
            raise ValueError(f"spec_k must be >= 1, got {spec_k}")
        if not (1 <= ngram_min <= ngram_max):
            raise ValueError(f"bad n-gram bounds ({ngram_min=}, {ngram_max=})")
        self.inner = make_scheduler(inner)
        self.spec_k = int(spec_k)
        self.ngram_max = int(ngram_max)
        self.ngram_min = int(ngram_min)
        self.name = f"speculative({self.inner.name},k={self.spec_k})"

    def admission_order(self, view: EngineView) -> Sequence[int]:
        return self.inner.admission_order(view)

    def decode_order(self, view: EngineView,
                     ready: Sequence[int]) -> Sequence[int]:
        return self.inner.decode_order(view, ready)

    def prefill_order(self, view: EngineView,
                      filling: Sequence[int]) -> Sequence[int]:
        return self.inner.prefill_order(view, filling)

    def preempt_order(self, view: EngineView,
                      victims: Sequence[int]) -> Sequence[int]:
        return self.inner.preempt_order(view, victims)

    def draft(self, history, k: int) -> List[int]:
        """Draft chain for one slot: at most min(k, spec_k) tokens."""
        return prompt_lookup_draft(history, min(int(k), self.spec_k),
                                   ngram_max=self.ngram_max,
                                   ngram_min=self.ngram_min)


SCHEDULERS = {
    "fifo": FifoScheduler,
    "prefix-aware": PrefixAwareScheduler,
    "slo": SloScheduler,
    "class-then-family": ClassThenFamilyScheduler,
    "speculative": SpeculativeScheduler,
}


def make_scheduler(spec) -> Scheduler:
    """Resolve the engine's ``scheduler=`` argument: None -> FIFO, a name
    from ``SCHEDULERS``, or a ready policy object (duck-typed — anything
    with the three ordering methods)."""
    if spec is None:
        return FifoScheduler()
    if isinstance(spec, str):
        try:
            return SCHEDULERS[spec]()
        except KeyError:
            raise ValueError(f"unknown scheduler {spec!r} "
                             f"(pick from {sorted(SCHEDULERS)})") from None
    for method in ("admission_order", "decode_order", "prefill_order"):
        if not callable(getattr(spec, method, None)):
            raise TypeError(f"scheduler {spec!r} lacks {method}()")
    return spec
