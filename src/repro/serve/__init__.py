from repro.serve.serve_step import (make_ragged_step, make_serve_step,  # noqa: F401
                                    decode_state_specs)
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.pool import PagePool, kv_bytes_per_token, kv_page_bytes  # noqa: F401
from repro.serve.scheduler import (SCHEDULERS, EngineView,  # noqa: F401
                                   FifoScheduler, PrefixAwareScheduler,
                                   Scheduler, SloScheduler, make_scheduler)
from repro.serve.handle import Request, RequestHandle  # noqa: F401
from repro.serve.reference import ReferenceEngine  # noqa: F401
from repro.serve.errors import (Cancelled, DeadlineExceeded,  # noqa: F401
                                EngineOverloaded, RequestTooLarge,
                                ServeError)
from repro.serve.chaos import FaultInjector  # noqa: F401
