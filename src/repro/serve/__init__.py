from repro.serve.serve_step import make_serve_step, decode_state_specs  # noqa: F401
