from repro.serve.serve_step import (make_ragged_step, make_serve_step,  # noqa: F401
                                    decode_state_specs)
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.reference import ReferenceEngine, Request  # noqa: F401
