from repro.serve.serve_step import make_serve_step, decode_state_specs  # noqa: F401
from repro.serve.engine import ServeEngine  # noqa: F401
from repro.serve.reference import ReferenceEngine, Request  # noqa: F401
