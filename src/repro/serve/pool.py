"""PagePool — the serving stack's memory-management layer.

The paper separates the SETTINGS layer (memory mode, affinity — set once,
system-wide) from the WORKLOAD layer (each user's Nproc × Nthread choice),
and shows that keeping the former uniform is what lets every choice of the
latter stay near peak.  ``PagePool`` is the settings layer of the serving
stack: one object owns every page-level policy — allocation, refcounts,
the prefix trie, copy-on-write matching, LRU eviction, byte-denominated
budgeting — behind a narrow interface, so the workload layer (the
``Scheduler`` policies in ``serve.scheduler``) and the orchestration layer
(``serve.engine.ServeEngine``) can change freely without touching it.

The pool is pure host-side bookkeeping over integer page ids: it never sees
a model, an array of KV data, or a device — which is what makes it
unit-testable in microseconds (tests/test_pool.py) and reusable by any
engine.  Device-side effects (the COW page copy, the slot reset) remain the
engine's job; the pool only decides WHICH pages.

Interface (all O(pages) or better, no jax imports):

- ``alloc(n)`` — pop ``n`` free pages (refcount 1 each), LRU-evicting
  refcount-0 cached pages under pressure; raises if the demand can never be
  met (callers gate on ``available()`` first).
- ``share(pages)`` / ``release(pages)`` — refcount ++/--.  A released page
  stays RESIDENT if the prefix trie indexes it (the pool IS the cache) and
  returns to the free list otherwise.
- ``match_prefix(prompt)`` — longest cached prefix: full trie pages to map
  (refcounts untouched; callers ``share`` what they keep) plus an optional
  mid-page copy-on-write candidate ``(src_page, extra_tokens)``.
- ``index_page(node, key, page)`` — extend a cached chain by one full page
  as prefill passes each page boundary; returns the chain node, or ``None``
  when an equivalent page already owns the prefix.
- ``probe_prefix_len(prompt)`` — non-mutating trie walk (no LRU touch) for
  schedulers ranking queued requests by expected reuse.
- ``evict_one()`` / ``drop_cache()`` / ``available(pinned)`` — eviction and
  admission-supply accounting.

Byte budgeting: ``kv_page_bytes`` / ``kv_bytes_per_token`` price a page (or
token) of paged KV across every global-attention layer for a storage dtype,
so budgets are BYTES, not page counts — an int8 pool holds ~``4·hd/(hd+4)``×
the float32 pages in the same bytes (PR 4's memory-representation knob).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.roofline import KV_ITEMSIZE, KV_SCALE_BYTES


def kv_page_bytes(cfg, page_size: int, kv_dtype: str,
                  kv_shards: int = 1) -> int:
    """Bytes one pool page costs across ALL paged (global-attention) layers
    for a given storage dtype — K and V values plus, for int8, their scale
    rows.  The engine sizes its page budget with this: a pool budget is a
    BYTE budget, and int8 fits ~``4·hd/(hd+4)``× the pages of float32 in
    the same bytes (≈3.8× at hd=64, ≥2× for hd ≥ 4; 3.2× on the smoke
    model's hd=16).

    ``kv_shards`` prices a page PER DEVICE under KV-head tensor parallelism
    (serve.engine ``mesh=``): each device holds ``kvH // kv_shards`` of a
    layer's KV heads, so a page's per-device footprint shrinks by the shard
    count (layers whose head count does not divide stay replicated and cost
    their full bytes on every device)."""
    isize = KV_ITEMSIZE[kv_dtype]
    sbytes = KV_SCALE_BYTES[kv_dtype]
    total = 0
    for st in cfg.stages:
        for blk in st.pattern:
            if blk.mixer == "attn" and blk.attn.window is None:
                kvH, hd = blk.attn.num_kv_heads, blk.attn.head_dim
                if kvH % kv_shards == 0:
                    kvH //= kv_shards
                total += st.repeats * 2 * page_size * kvH * (hd * isize
                                                             + sbytes)
    return total


def kv_bytes_per_token(cfg, kv_dtype: str, kv_shards: int = 1) -> int:
    """Bytes of paged-pool KV one token occupies (and one decode step must
    stream per context token) across all global-attention layers — the
    quantity the int8 pool halves-or-better vs float32.  Per device when
    ``kv_shards > 1`` (see ``kv_page_bytes``)."""
    return kv_page_bytes(cfg, 1, kv_dtype, kv_shards)


class _PrefixNode:
    """One full page of prompt tokens in the prefix trie.

    ``children`` maps the NEXT page's token tuple to its node, so a cached
    prefix is a root-to-node chain of full pages.  Refcounts live in the
    pool's per-page array; a node is evictable when its page's refcount is
    0 and it has no children (leaf-first eviction keeps every cached chain
    reachable from the root — an active request holds refs on its whole
    matched path, so refcounts are monotone non-increasing down the trie)."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Optional[Tuple[int, ...]], page: int,
                 parent: Optional["_PrefixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.last_used = 0


class PagePool:
    """Refcounted page allocator doubling as a prefix cache (see module
    docstring).  ``index_enabled=False`` degrades it to a plain FIFO page
    allocator: every match misses and released pages free immediately."""

    def __init__(self, n_pages: int, page_size: int, *,
                 index_enabled: bool = True):
        if n_pages < 0 or page_size < 1:
            raise ValueError(f"bad pool shape ({n_pages=}, {page_size=})")
        self.n_pages = n_pages
        self.page_size = page_size
        self.index_enabled = bool(index_enabled)
        self._free: List[int] = list(range(n_pages))
        self._ref = np.zeros(n_pages, np.int64)  # per-page refcounts
        self._root = _PrefixNode(None, -1, None)  # trie of cached prefixes
        self._page_node: Dict[int, _PrefixNode] = {}  # page -> trie node
        self._clock = 0  # LRU counter (bumped per touch)
        self.stats = {"evictions": 0}

    # -- introspection ----------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Pages currently held by the prefix index."""
        return len(self._page_node)

    @property
    def pages_in_use(self) -> int:
        """Pages some live request currently holds (refcount > 0)."""
        return int((self._ref > 0).sum())

    @property
    def reclaimable_pages(self) -> int:
        """Free pages plus refcount-0 cached pages — the allocator can hand
        all of these out; equals ``n_pages`` whenever no page is pinned."""
        return len(self._free) + self.evictable()

    def ref(self, page: int) -> int:
        return int(self._ref[page])

    def evictable(self) -> int:
        """Cached pages reclaimable under pressure (refcount 0)."""
        return sum(1 for p in self._page_node if self._ref[p] == 0)

    def available(self, pinned: Sequence[int] = ()) -> int:
        """Pages an admission could obtain AFTER it pins ``pinned``: free +
        evictable, minus currently-refcount-0 cached pages the caller is
        about to hold — a page the request itself pins must not be counted
        as reclaimable supply for its own allocation."""
        held = sum(1 for p in set(pinned) if self._ref[p] == 0)
        return len(self._free) + self.evictable() - held

    # -- refcounts / allocation -------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` pages, LRU-evicting cached refcount-0 pages as needed.
        Returned pages carry refcount 1 (the caller owns them)."""
        while len(self._free) < n:
            if not self.evict_one():
                raise RuntimeError(  # unreachable when callers gate on
                    "page pool exhausted with nothing evictable")  # available()
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] += 1
        return out

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference per page (mapping cached pages into a slot)."""
        for p in pages:
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page.  Refcount-0 pages stay resident if
        the prefix trie indexes them (the pool IS the cache; LRU eviction
        reclaims them under pressure) and are freed immediately otherwise."""
        for p in pages:
            self._ref[p] -= 1
            assert self._ref[p] >= 0, f"page {p} over-released"
            if self._ref[p] == 0 and p not in self._page_node:
                self._free.append(p)

    # -- prefix index -----------------------------------------------------
    @property
    def root(self) -> _PrefixNode:
        return self._root

    def _walk_full_pages(self, prompt: np.ndarray, touch: bool):
        """Walk the trie one full page of ``prompt`` at a time; returns
        (last node, matched pages, matched tokens).  ``touch`` refreshes
        LRU recency — the one difference between a real match and the
        schedulers' non-mutating probe, which must share this walk so their
        notions of "cached prefix" can never drift apart."""
        P = self.page_size
        node, pages, matched = self._root, [], 0
        while matched + P <= len(prompt):
            child = node.children.get(
                tuple(int(t) for t in prompt[matched:matched + P]))
            if child is None:
                break
            if touch:
                child.last_used = self._clock
            node = child
            pages.append(child.page)
            matched += P
        return node, pages, matched

    def match_prefix(self, prompt: np.ndarray):
        """Longest cached prefix of ``prompt``: walk the trie a full page at
        a time, then probe the children of the last matched node for a
        partial-page hit (longest common prefix ≥ 1 token → COW candidate).

        Returns (node, pages, matched_tokens, cow) with ``pages`` the full
        shared pages and ``cow`` either None or (src_page, extra_tokens).
        Refcounts are NOT touched — the caller ``share``s what it keeps."""
        if not self.index_enabled:
            return self._root, [], 0, None
        self._clock += 1
        node, pages, matched = self._walk_full_pages(prompt, touch=True)
        cow = None
        rem = prompt[matched:]
        if rem.size and node.children:
            best_len, best = 0, None
            for key, child in node.children.items():
                k = np.asarray(key[:rem.size], np.int32)
                lcp = int((np.cumprod(k == rem[:k.size]) if k.size else
                           np.zeros(0)).sum())
                if lcp > best_len:
                    best_len, best = lcp, child
            if best is not None:
                best.last_used = self._clock
                cow = (best.page, best_len)
        return node, pages, matched, cow

    def probe_prefix_len(self, prompt: np.ndarray) -> int:
        """Tokens of ``prompt`` covered by cached FULL pages — a
        non-mutating ``match_prefix`` (no LRU touch) for schedulers ranking
        queued requests by expected reuse."""
        if not self.index_enabled:
            return 0
        return self._walk_full_pages(prompt, touch=False)[2]

    def index_page(self, node: _PrefixNode, key: Tuple[int, ...],
                   page: int) -> Optional[_PrefixNode]:
        """Extend the cached chain at ``node`` with one full page.

        Returns the chain's new tip, or ``None`` when an EQUIVALENT page
        already owns this prefix (the caller's private duplicate stays out
        of the index and is freed at its release)."""
        if not self.index_enabled:
            return None
        child = node.children.get(key)
        if child is None:
            child = _PrefixNode(key, page, node)
            node.children[key] = child
            self._page_node[page] = child
        elif child.page != page:
            return None  # prefix owned elsewhere: stop indexing
        self._clock += 1
        child.last_used = self._clock
        return child

    # -- eviction ---------------------------------------------------------
    def evict_one(self) -> bool:
        """Drop the least-recently-used refcount-0 LEAF from the trie and
        return its page to the free list.  Leaf-first keeps every cached
        chain reachable; a ref-0 node's descendants are all ref-0 (active
        requests hold their whole matched path), so repetition drains any
        evictable subtree."""
        best = None
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if nd.children or self._ref[nd.page] != 0:
                continue
            if best is None or nd.last_used < best.last_used:
                best = nd
        if best is None:
            return False
        del best.parent.children[best.key]
        del self._page_node[best.page]
        self._free.append(best.page)
        self.stats["evictions"] += 1
        return True

    def drop_cache(self) -> int:
        """Evict every refcount-0 cached page (A/B runs, tests).  Returns
        the number of pages returned to the free list."""
        n = 0
        while self.evict_one():
            n += 1
        return n
