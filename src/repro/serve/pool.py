"""PagePool — the serving stack's TWO-TIER memory-management layer.

The paper separates the SETTINGS layer (memory mode, affinity — set once,
system-wide) from the WORKLOAD layer (each user's Nproc × Nthread choice),
and shows that keeping the former uniform is what lets every choice of the
latter stay near peak.  ``PagePool`` is the settings layer of the serving
stack: one object owns every page-level policy — allocation, refcounts,
the prefix trie, copy-on-write matching, tiered eviction, byte-denominated
budgeting — behind a narrow interface, so the workload layer (the
``Scheduler`` policies in ``serve.scheduler``) and the orchestration layer
(``serve.engine.ServeEngine``) can change freely without touching it.

**Tiers (the paper's MCDRAM cache mode, applied to serving).**  The paper's
central result is that *cache* mode beats flat mode because the hot working
set stays resident in the fast tier while the cold set lives one tier down.
The pool reproduces that hierarchy for the KV prefix cache: the DEVICE tier
is ``n_pages`` of fast pool memory, and an optional HOST tier
(``host_pages`` slots of host RAM) catches what pressure pushes out.  The
full page lifecycle is alloc → (release) → demote → promote → **preempt
(park) → resume (unpark)** → free:

- **Demotion** — under allocation pressure, the LRU refcount-0 device node
  with no device children moves its page to a host slot instead of being
  discarded.  Its trie entry survives (annotated with the new tier by its
  encoded page id), so the prefix stays matchable; a ("demote", page, slot)
  event tells the engine to gather the page's bytes — values AND int8 scale
  rows — into host storage before the device page is reused.
- **Promotion** — a ``match_prefix`` hit on a host-resident page is
  ``acquire``d back: a device page is allocated (possibly demoting someone
  else), the trie entry returns to the device tier, and a
  ("promote", slot, page) event tells the engine to scatter the host bytes
  back into the pool — issued at admission so jax's async dispatch overlaps
  the copy with the current tick's compute.
- **Host eviction** — the host tier is itself finite: making room for a
  demotion drops the LRU childless host node (("hevict", slot) event).
  Only when BOTH tiers miss does a request pay full re-prefill.
- **Preemption (park / unpark)** — when the engine preempts a decoding
  slot under pressure, the victim's PRIVATE pages (non-indexed,
  refcount-1: generated-token pages and prompt duplicates — pages the trie
  would never cache) are PARKED: their bytes move to host slots via the
  same ("demote", page, slot) event machinery, but the slots are pinned in
  ``_parked`` rather than entering the trie — cache traffic can never
  evict a live request's swapped-out state.  ``unpark`` is the resume
  mirror: one device page per parked slot, ("promote", slot, page) events,
  and the host slots return to the cache's free list.  ``drop_parked``
  abandons a park (cancel, deadline expiry, chaos storm) with ("hevict",
  slot) events.  All-or-nothing: ``park`` returns None rather than a
  partial park — a resume needs contiguous coverage or none.

Host-tier CACHE pages carry no refcounts (the cache tier holds only
refcount-0 trie pages; live requests only ever hold device pages) and are
named by ENCODED ids ``n_pages + slot`` wherever they appear in match
results, so the device region of the trie stays prefix-closed: every
ancestor of a device page is a device page, which is what lets a matched
chain promote root-first.  PARKED slots are the one host-tier occupant
outside the trie: invisible to matching and host eviction, owned by
exactly one preempted request until unparked or dropped.

The pool is pure host-side bookkeeping over integer page ids: it never sees
a model, an array of KV data, or a device — which is what makes it
unit-testable in microseconds (tests/test_pool.py) and reusable by any
engine.  Device-side effects (the COW page copy, the slot reset, the
demote gather / promote scatter ordered by ``drain_events()``) remain the
engine's job; the pool only decides WHICH pages move WHERE.

Interface (all O(pages) or better, no jax imports):

- ``alloc(n)`` — pop ``n`` free pages (refcount 1 each), demoting (or,
  untiered, dropping) refcount-0 cached pages under pressure; raises if the
  demand can never be met (callers gate on ``available()`` first).
- ``share(pages)`` / ``release(pages)`` — refcount ++/--.  A released page
  stays RESIDENT if the prefix trie indexes it (the pool IS the cache) and
  returns to the free list otherwise.
- ``match_prefix(prompt)`` — longest cached prefix ACROSS BOTH TIERS: full
  trie pages to map (host hits appear as encoded ids; refcounts untouched)
  plus an optional mid-page copy-on-write candidate (device tier only).
- ``acquire(pages)`` — take one reference per matched page, promoting any
  host-tier hits; returns the resolved all-device page list.
- ``index_page(node, key, page)`` — extend a cached chain by one full page
  as prefill passes each page boundary; returns the chain node, or ``None``
  when an equivalent page already owns the prefix.
- ``probe_prefix_len(prompt)`` / ``probe_prefix_split(prompt)`` —
  non-mutating trie walks (no LRU touch) for schedulers ranking queued
  requests by expected reuse, totalled or split (device, host).
- ``park(pages)`` / ``unpark(slots)`` / ``drop_parked(slots)`` — the
  preemption swap: move a victim slot's private pages to pinned host
  slots, bring them back on resume, or abandon them.
- ``evict_one()`` / ``drop_cache()`` / ``available(pinned)`` — reclamation
  and admission-supply accounting; ``drain_events()`` hands the engine the
  chronological demote/promote/hevict log to apply to device state.

Byte budgeting: ``kv_page_bytes`` / ``kv_bytes_per_token`` price a page (or
token) of paged KV across every global-attention layer for a storage dtype,
so budgets are BYTES, not page counts — an int8 pool holds ~``4·hd/(hd+4)``×
the float32 pages in the same bytes (PR 4's memory-representation knob).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.roofline import KV_ITEMSIZE, KV_SCALE_BYTES


def kv_page_bytes(cfg, page_size: int, kv_dtype: str,
                  kv_shards: int = 1) -> int:
    """Bytes one pool page costs across ALL paged (global-attention) layers
    for a given storage dtype — K and V values plus, for int8, their scale
    rows.  The engine sizes its page budget with this: a pool budget is a
    BYTE budget, and int8 fits ~``4·hd/(hd+4)``× the pages of float32 in
    the same bytes (≈3.8× at hd=64, ≥2× for hd ≥ 4; 3.2× on the smoke
    model's hd=16).

    ``kv_shards`` prices a page PER DEVICE under KV-head tensor parallelism
    (serve.engine ``mesh=``): each device holds ``kvH // kv_shards`` of a
    layer's KV heads, so a page's per-device footprint shrinks by the shard
    count (layers whose head count does not divide stay replicated and cost
    their full bytes on every device)."""
    isize = KV_ITEMSIZE[kv_dtype]
    sbytes = KV_SCALE_BYTES[kv_dtype]
    total = 0
    for st in cfg.stages:
        for blk in st.pattern:
            if blk.mixer == "attn" and blk.attn.window is None:
                kvH, hd = blk.attn.num_kv_heads, blk.attn.head_dim
                if kvH % kv_shards == 0:
                    kvH //= kv_shards
                total += st.repeats * 2 * page_size * kvH * (hd * isize
                                                             + sbytes)
    return total


def kv_bytes_per_token(cfg, kv_dtype: str, kv_shards: int = 1) -> int:
    """Bytes of paged-pool KV one token occupies (and one decode step must
    stream per context token) across all global-attention layers — the
    quantity the int8 pool halves-or-better vs float32.  Per device when
    ``kv_shards > 1`` (see ``kv_page_bytes``)."""
    return kv_page_bytes(cfg, 1, kv_dtype, kv_shards)


class _PrefixNode:
    """One full page of prompt tokens in the prefix trie.

    ``children`` maps the NEXT page's token tuple to its node, so a cached
    prefix is a root-to-node chain of full pages.  Refcounts live in the
    pool's per-page array; a node is evictable when its page's refcount is
    0 and it has no children (leaf-first eviction keeps every cached chain
    reachable from the root — an active request holds refs on its whole
    matched path, so refcounts are monotone non-increasing down the trie)."""

    __slots__ = ("key", "page", "parent", "children", "last_used")

    def __init__(self, key: Optional[Tuple[int, ...]], page: int,
                 parent: Optional["_PrefixNode"]):
        self.key = key
        self.page = page
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_PrefixNode"] = {}
        self.last_used = 0


class PagePool:
    """Refcounted two-tier page allocator doubling as a prefix cache (see
    module docstring).  ``index_enabled=False`` degrades it to a plain FIFO
    page allocator: every match misses and released pages free immediately.
    ``host_pages=0`` (the default) disables the host tier: eviction drops
    pages exactly as it always did."""

    def __init__(self, n_pages: int, page_size: int, *,
                 index_enabled: bool = True, host_pages: int = 0):
        if n_pages < 0 or page_size < 1:
            raise ValueError(f"bad pool shape ({n_pages=}, {page_size=})")
        if host_pages < 0:
            raise ValueError(f"bad host tier size ({host_pages=})")
        self.n_pages = n_pages
        self.page_size = page_size
        self.index_enabled = bool(index_enabled)
        self._free: List[int] = list(range(n_pages))
        self._ref = np.zeros(n_pages, np.int64)  # per-page refcounts
        self._root = _PrefixNode(None, -1, None)  # trie of cached prefixes
        self._page_node: Dict[int, _PrefixNode] = {}  # page -> trie node
        self._clock = 0  # LRU counter (bumped per touch)
        # host tier: slot -> trie node for demoted pages (encoded in the
        # trie as page id ``n_pages + slot``); no refcounts — a pure cache
        self.host_pages = int(host_pages)
        self._host_free: List[int] = list(range(self.host_pages))
        self._host_node: Dict[int, _PrefixNode] = {}
        self._host_pinned: set = set()  # slots mid-promotion: not evictable
        # host slots holding a PREEMPTED request's parked pages: outside the
        # trie (not matchable), never host-evictable — live-request state
        # outranks cache.  Freed only by unpark (resume) or drop_parked.
        self._parked: set = set()
        # chronological demote/promote/hevict log for the engine to apply
        # to device state (``drain_events``)
        self.events: List[tuple] = []
        self.stats = {"evictions": 0, "demotions": 0, "promotions": 0,
                      "host_evictions": 0,
                      # preemption swap traffic: pages parked device->host,
                      # unparked host->device, and parks abandoned
                      "park_demotions": 0, "park_promotions": 0,
                      "parks_dropped": 0}

    # -- introspection ----------------------------------------------------
    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def cached_pages(self) -> int:
        """Pages currently held by the prefix index."""
        return len(self._page_node)

    @property
    def pages_in_use(self) -> int:
        """Pages some live request currently holds (refcount > 0)."""
        return int((self._ref > 0).sum())

    @property
    def reclaimable_pages(self) -> int:
        """Free pages plus refcount-0 cached pages — the allocator can hand
        all of these out; equals ``n_pages`` whenever no page is pinned."""
        return len(self._free) + self.evictable()

    @property
    def host_cached_pages(self) -> int:
        """Pages resident in the host tier (demoted, still matchable)."""
        return len(self._host_node)

    @property
    def host_free_slots(self) -> int:
        return len(self._host_free)

    @property
    def parked_pages(self) -> int:
        """Host slots holding preempted requests' parked pages."""
        return len(self._parked)

    def is_host(self, page: int) -> bool:
        """True for an encoded host-tier page id (``n_pages + slot``)."""
        return page >= self.n_pages

    def is_indexed(self, page: int) -> bool:
        """True when a device page is owned by the prefix index.

        The speculative-decoding safety contract leans on this: only full
        PROMPT pages ever enter the index (``index_page`` is driven by
        prefill advancing ``fill``; decode and draft tokens never advance
        it), so a slot's decode/draft positions always land in pages this
        returns False for — privately allocated or COW'd, refcount-held by
        the slot alone.  Rejected-tail rollback therefore can never corrupt
        an indexed prefix page or its int8 scale rows: the rolled-back rows
        live exclusively in non-indexed pages, and the rollback itself only
        touches per-slot kpos/slen metadata anyway.  The engine asserts
        this when packing draft chains."""
        return page in self._page_node

    def ref(self, page: int) -> int:
        return int(self._ref[page])

    def evictable(self) -> int:
        """Cached device pages reclaimable under pressure (refcount 0) —
        by demotion with a host tier, by dropping without one; either way
        the device page becomes allocator supply."""
        return sum(1 for p in self._page_node if self._ref[p] == 0)

    def available(self, pinned: Sequence[int] = ()) -> int:
        """Device pages an admission could obtain AFTER it pins ``pinned``:
        free + evictable, minus currently-refcount-0 cached pages the caller
        is about to hold — a page the request itself pins must not be
        counted as reclaimable supply for its own allocation.  Encoded
        host-tier ids in ``pinned`` are ignored: promoting them CONSUMES a
        device page, which callers price into their demand instead."""
        held = sum(1 for p in set(pinned)
                   if p < self.n_pages and self._ref[p] == 0)
        return len(self._free) + self.evictable() - held

    def drain_events(self) -> List[tuple]:
        """Hand over (and clear) the chronological tier-traffic log.  The
        engine must apply entries IN ORDER before any other device-state
        mutation of the admission round: ("demote", page, slot) gathers the
        device page's bytes into host storage BEFORE the freed page is
        reused, ("promote", slot, page) scatters host bytes into the newly
        allocated device page, ("hevict", slot) discards host storage.  A
        slot freed by a promote may be reused by a later demote in the same
        round — chronological application makes that correct by
        construction."""
        ev, self.events = self.events, []
        return ev

    # -- refcounts / allocation -------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Pop ``n`` pages, LRU-evicting cached refcount-0 pages as needed.
        Returned pages carry refcount 1 (the caller owns them)."""
        while len(self._free) < n:
            if not self.evict_one():
                raise RuntimeError(  # unreachable when callers gate on
                    "page pool exhausted with nothing evictable")  # available()
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] += 1
        return out

    def share(self, pages: Sequence[int]) -> None:
        """Add one reference per page (mapping cached pages into a slot)."""
        for p in pages:
            self._ref[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one reference per page.  Refcount-0 pages stay resident if
        the prefix trie indexes them (the pool IS the cache; tiered eviction
        reclaims them under pressure) and are freed immediately otherwise."""
        for p in pages:
            self._ref[p] -= 1
            assert self._ref[p] >= 0, f"page {p} over-released"
            if self._ref[p] == 0 and p not in self._page_node:
                self._free.append(p)

    def acquire(self, pages: Sequence[int]) -> List[int]:
        """Take one reference per matched page, PROMOTING host-tier hits.

        Device pages are ``share``d; encoded host ids get a device page
        allocated (demoting under pressure), their trie entry moved back to
        the device tier, and a ("promote", slot, page) event appended for
        the engine to scatter the host bytes in.  Returns the resolved
        all-device page list — every returned page carries one reference
        for the caller.

        Pages must arrive in chain (root-first) order, as ``match_prefix``
        returns them: the matched chain's device prefix is then referenced
        before any promotion can trigger a demotion, and each promotion
        re-closes the device region of the trie before the next.  Pending
        host slots are pinned against host eviction for the duration — a
        promotion's own demotions can never evict the tail it is about to
        promote."""
        pending = {p - self.n_pages for p in pages if p >= self.n_pages}
        self._host_pinned |= pending
        out: List[int] = []
        try:
            for p in pages:
                if p < self.n_pages:
                    self._ref[p] += 1
                    out.append(p)
                    continue
                slot = p - self.n_pages
                (dev,) = self.alloc(1)  # arrives refcounted
                node = self._host_node.pop(slot)
                node.page = dev
                self._page_node[dev] = node
                self._host_free.append(slot)
                self._host_pinned.discard(slot)
                self.events.append(("promote", slot, dev))
                self.stats["promotions"] += 1
                out.append(dev)
        finally:
            self._host_pinned -= pending
        return out

    # -- preemption swap (park / unpark) ----------------------------------
    def park(self, pages: Sequence[int]) -> Optional[List[int]]:
        """Swap a preempted slot's PRIVATE pages out to pinned host slots.

        Each page must be refcount-1 and non-indexed (the victim slot is
        its sole owner — generated-token pages and prompt duplicates; the
        victim's indexed prefix pages are simply ``release``d instead and
        stay matchable as cache).  Emits the same ("demote", page, slot)
        events as cache demotion, so the engine's event drain moves the
        bytes with the machinery it already has; the slots land in
        ``_parked`` — never in the trie — so neither matching nor host
        eviction can touch them until ``unpark``/``drop_parked``.

        ALL-OR-NOTHING: returns the host slot list (parallel to ``pages``),
        or ``None`` without side effects when the host tier is absent or
        cannot take every page — a resume needs contiguous coverage, so a
        partial park is worth nothing.  Making room may hevict cached host
        nodes (live-request state outranks the pure cache)."""
        pages = list(pages)
        if not pages:
            return []
        if self.host_pages == 0:
            return None
        # conservative capacity probe: free slots + currently-evictable
        # cache nodes (evictions can only expose more candidates)
        cap = len(self._host_free) + sum(
            1 for s, nd in self._host_node.items()
            if s not in self._host_pinned and not nd.children)
        if cap < len(pages):
            return None
        slots: List[int] = []
        for p in pages:
            assert self._ref[p] == 1 and p not in self._page_node, \
                f"park of a shared or indexed page {p}"
            slot = self._host_slot_for_demote()
            assert slot is not None, "capacity probe admitted a full tier"
            self.events.append(("demote", p, slot))
            self._ref[p] -= 1
            self._free.append(p)
            self._parked.add(slot)
            slots.append(slot)
        self.stats["park_demotions"] += len(slots)
        return slots

    def unpark(self, slots: Sequence[int]) -> List[int]:
        """Resume a park: allocate one device page per parked slot and emit
        ("promote", slot, page) events for the engine to scatter the bytes
        back.  Returned pages carry refcount 1 (the resumed slot owns
        them); the host slots return to the cache's free list.  Callers
        gate on ``available()`` for the whole resume demand first, exactly
        like admission."""
        out: List[int] = []
        for slot in slots:
            assert slot in self._parked, f"unpark of a non-parked slot {slot}"
            # alloc BEFORE freeing the slot: an eviction this alloc triggers
            # then cannot demote into a slot whose bytes are still pending
            # promotion (chronological event order handles later reuse)
            (dev,) = self.alloc(1)
            self.events.append(("promote", slot, dev))
            self._parked.discard(slot)
            self._host_free.append(slot)
            out.append(dev)
        self.stats["park_promotions"] += len(out)
        return out

    def drop_parked(self, slots: Sequence[int]) -> None:
        """Abandon a park (cancel, deadline expiry, chaos eviction storm):
        the host slots free and ("hevict", slot) events tell the engine to
        discard the bytes.  The preempted request can still resume — it
        re-prefills from its own token history instead of promoting."""
        n = 0
        for slot in slots:
            if slot not in self._parked:
                continue
            self._parked.discard(slot)
            self._host_free.append(slot)
            self.events.append(("hevict", slot))
            n += 1
        self.stats["parks_dropped"] += n

    # -- prefix index -----------------------------------------------------
    @property
    def root(self) -> _PrefixNode:
        return self._root

    def _walk_full_pages(self, prompt: np.ndarray, touch: bool):
        """Walk the trie one full page of ``prompt`` at a time; returns
        (last node, matched pages, matched tokens).  ``touch`` refreshes
        LRU recency — the one difference between a real match and the
        schedulers' non-mutating probe, which must share this walk so their
        notions of "cached prefix" can never drift apart."""
        P = self.page_size
        node, pages, matched = self._root, [], 0
        while matched + P <= len(prompt):
            child = node.children.get(
                tuple(int(t) for t in prompt[matched:matched + P]))
            if child is None:
                break
            if touch:
                child.last_used = self._clock
            node = child
            pages.append(child.page)
            matched += P
        return node, pages, matched

    def match_prefix(self, prompt: np.ndarray):
        """Longest cached prefix of ``prompt`` ACROSS BOTH TIERS: walk the
        trie a full page at a time, then probe the children of the last
        matched node for a partial-page hit (longest common prefix ≥ 1
        token → COW candidate; device tier only — a mid-page reuse is an
        optimization, not worth a promotion).

        Returns (node, pages, matched_tokens, cow) with ``pages`` the full
        shared pages IN CHAIN ORDER — host-tier hits appear as encoded ids
        ``n_pages + slot``, always a contiguous tail of the list (the
        device region of the trie is prefix-closed) — and ``cow`` either
        None or (src_page, extra_tokens).  Refcounts are NOT touched — the
        caller ``acquire``s what it keeps (which also promotes the host
        hits)."""
        if not self.index_enabled:
            return self._root, [], 0, None
        self._clock += 1
        node, pages, matched = self._walk_full_pages(prompt, touch=True)
        cow = None
        rem = prompt[matched:]
        if rem.size and node.children:
            best_len, best = 0, None
            for key, child in node.children.items():
                if self.is_host(child.page):
                    continue
                k = np.asarray(key[:rem.size], np.int32)
                lcp = int((np.cumprod(k == rem[:k.size]) if k.size else
                           np.zeros(0)).sum())
                if lcp > best_len:
                    best_len, best = lcp, child
            if best is not None:
                best.last_used = self._clock
                cow = (best.page, best_len)
        return node, pages, matched, cow

    def probe_prefix_len(self, prompt: np.ndarray) -> int:
        """Tokens of ``prompt`` covered by cached FULL pages (either tier)
        — a non-mutating ``match_prefix`` (no LRU touch) for schedulers
        ranking queued requests by expected reuse."""
        if not self.index_enabled:
            return 0
        return self._walk_full_pages(prompt, touch=False)[2]

    def probe_prefix_split(self, prompt: np.ndarray) -> Tuple[int, int]:
        """(device_tokens, host_tokens) of the cached full-page prefix — a
        non-mutating probe for tier-aware schedulers: a device hit is free,
        a host hit costs one promotion copy, a miss costs re-prefill, so
        the three candidate classes rank warm > host-warm > cold."""
        if not self.index_enabled:
            return 0, 0
        _, pages, matched = self._walk_full_pages(prompt, touch=False)
        host = sum(1 for p in pages if self.is_host(p)) * self.page_size
        return matched - host, host

    def index_page(self, node: _PrefixNode, key: Tuple[int, ...],
                   page: int) -> Optional[_PrefixNode]:
        """Extend the cached chain at ``node`` with one full page.

        Returns the chain's new tip, or ``None`` when an EQUIVALENT page
        already owns this prefix (the caller's private duplicate stays out
        of the index and is freed at its release)."""
        if not self.index_enabled:
            return None
        child = node.children.get(key)
        if child is None:
            child = _PrefixNode(key, page, node)
            node.children[key] = child
            self._page_node[page] = child
        elif child.page != page:
            return None  # prefix owned elsewhere: stop indexing
        self._clock += 1
        child.last_used = self._clock
        return child

    def storm_host_cache(self) -> int:
        """Chaos hook: hevict EVERY evictable host cache node (leaf-first,
        until none remain).  Parked slots and pinned (mid-promotion) nodes
        survive — a storm models cache-tier loss, and live-request state is
        not cache.  Returns the number of slots dropped."""
        n = 0
        progress = True
        while progress:
            progress = False
            for slot, nd in list(self._host_node.items()):
                if slot in self._host_pinned or nd.children:
                    continue
                self._hevict(nd)
                n += 1
                progress = True
        return n

    # -- eviction / demotion ----------------------------------------------
    def evict_one(self) -> bool:
        """Reclaim one device page from the cache.

        With a host tier this is a DEMOTION: the least-recently-used
        refcount-0 device node with no DEVICE children (host children may
        hang below — the device region stays prefix-closed) moves its page
        to a host slot; the trie entry survives with an encoded host id and
        a ("demote", page, slot) event tells the engine to gather the bytes
        out before the freed page is reused.  Host capacity is made by
        dropping the LRU childless, unpinned host node first.

        Without a host tier — or in the corner where every host slot is
        pinned by an in-flight promotion — the page is DROPPED as the
        untiered pool always did (any host descendants are dropped with it
        so every surviving chain stays rooted).  Device-leaf-first plus
        refcount monotonicity (active requests hold their whole matched
        path) means repetition drains any evictable subtree."""
        best = None
        stack = list(self._root.children.values())
        while stack:
            nd = stack.pop()
            stack.extend(nd.children.values())
            if self.is_host(nd.page) or self._ref[nd.page] != 0:
                continue
            if any(not self.is_host(c.page) for c in nd.children.values()):
                continue
            if best is None or nd.last_used < best.last_used:
                best = nd
        if best is None:
            return False
        slot = self._host_slot_for_demote()
        if slot is None:
            self._drop_device_node(best)
            return True
        self.events.append(("demote", best.page, slot))
        del self._page_node[best.page]
        self._free.append(best.page)
        self._host_node[slot] = best
        best.page = self.n_pages + slot
        self.stats["demotions"] += 1
        return True

    def _host_slot_for_demote(self) -> Optional[int]:
        """A free host slot for an incoming demotion, evicting the LRU
        childless (and unpinned) host node if the tier is full; ``None``
        when the tier is disabled or nothing can make room."""
        if self.host_pages == 0:
            return None
        if self._host_free:
            return self._host_free.pop()
        best = None
        for slot, nd in self._host_node.items():
            if slot in self._host_pinned or nd.children:
                continue
            if best is None or nd.last_used < self._host_node[best].last_used:
                best = slot
        if best is None:
            return None
        self._hevict(self._host_node[best])
        return self._host_free.pop()

    def _hevict(self, node: _PrefixNode) -> None:
        """Drop one host-tier node: trie entry out, slot freed, ("hevict",
        slot) event so the engine discards the host-side bytes."""
        slot = node.page - self.n_pages
        del node.parent.children[node.key]
        del self._host_node[slot]
        self._host_free.append(slot)
        self.events.append(("hevict", slot))
        self.stats["host_evictions"] += 1

    def _drop_device_node(self, node: _PrefixNode) -> None:
        """Discard a device node outright (untiered eviction, or the
        all-host-slots-pinned corner), cascading its host descendants
        children-first so no chain is left unrooted."""
        def drop_host(nd: _PrefixNode) -> None:
            for c in list(nd.children.values()):
                drop_host(c)
            if self.is_host(nd.page):
                self._hevict(nd)
        for c in list(node.children.values()):
            drop_host(c)
        del node.parent.children[node.key]
        del self._page_node[node.page]
        self._free.append(node.page)
        self.stats["evictions"] += 1

    def drop_cache(self) -> int:
        """Discard every refcount-0 cached page in BOTH tiers (A/B runs,
        tests) — nothing is demoted; the cache is emptied.  Returns the
        number of DEVICE pages returned to the free list.  Callers holding
        host-side storage must still drain the ("hevict", slot) events."""
        n = 0

        def drop(nd: _PrefixNode) -> None:
            nonlocal n
            for c in list(nd.children.values()):
                drop(c)
            if nd.children:
                return  # a kept (referenced) descendant pins the chain
            if self.is_host(nd.page):
                self._hevict(nd)
            elif self._ref[nd.page] == 0:
                del nd.parent.children[nd.key]
                del self._page_node[nd.page]
                self._free.append(nd.page)
                self.stats["evictions"] += 1
                n += 1

        for c in list(self._root.children.values()):
            drop(c)
        return n
