"""gemma3-4b [dense] — 34L d=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.

5:1 local:global attention interleave, 128k context [hf:google/gemma-3-*].
Local layers: sliding window 1024, rope theta 10k; global layers: full
attention, rope theta 1M.  34 layers = 5×(5 local + 1 global) + 4 local.
Runs long_500k: local layers keep only window-sized KV; the 1-in-6 global
layers hold full 512k KV (linear per decode step).
"""
from repro.configs.base import ModelCfg, Stage
from repro.configs.util import attn_block

_LOCAL = attn_block(8, 4, 256, 10240, window=1024, rope_theta=1e4)
_GLOBAL = attn_block(8, 4, 256, 10240, rope_theta=1e6)

FULL = ModelCfg(
    name="gemma3-4b", d_model=2560, vocab_size=262144,
    stages=(Stage((_LOCAL,) * 5 + (_GLOBAL,), 5), Stage((_LOCAL,) * 4, 1)),
    tie_embeddings=True, max_seq_len=524288,
)

_L = attn_block(4, 2, 16, 128, window=16, rope_theta=1e4)
_G = attn_block(4, 2, 16, 128, rope_theta=1e4)
SMOKE = ModelCfg(
    name="gemma3-4b-smoke", d_model=64, vocab_size=512,
    stages=(Stage((_L, _L, _G), 1),), tie_embeddings=True, max_seq_len=128,
)
