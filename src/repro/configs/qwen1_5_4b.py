"""qwen1.5-4b [dense] — 40L d=2560 20H (kv=20, i.e. MHA) d_ff=6912 vocab=151936.

QKV bias [hf:Qwen/Qwen1.5-*]. ~4B params.
"""
from repro.configs.util import dense_lm

FULL = dense_lm("qwen1.5-4b", n_layers=40, d_model=2560, n_heads=20, n_kv=20,
                head_dim=128, d_ff=6912, vocab=151936, qkv_bias=True,
                rope_theta=1e6, tie=False, param_dtype="bfloat16")

SMOKE = dense_lm("qwen1.5-4b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=4,
                 head_dim=16, d_ff=128, vocab=512, qkv_bias=True,
                 rope_theta=1e4, tie=False, max_seq_len=128)
