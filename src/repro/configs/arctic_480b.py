"""arctic-480b [moe] — 35L d=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128 experts top-2 + dense residual FFN in every layer
[hf:Snowflake/snowflake-arctic-base].  ~480B total / ~17B active.
"""
from repro.configs.base import MLPCfg, ModelCfg, MoECfg, Stage
from repro.configs.util import attn_block

_MOE = MoECfg(num_experts=128, top_k=2, d_ff=4864, capacity_factor=1.25,
              dense_residual=MLPCfg(d_ff=4864))

FULL = ModelCfg(
    name="arctic-480b", d_model=7168, vocab_size=32000,
    stages=(Stage((attn_block(56, 8, 128, 4864, ffn="moe", moe=_MOE),), 35),),
    tie_embeddings=False, max_seq_len=32768, param_dtype="bfloat16",
)

_SM = MoECfg(num_experts=8, top_k=2, d_ff=96, dense_residual=MLPCfg(d_ff=96))
SMOKE = ModelCfg(
    name="arctic-480b-smoke", d_model=64, vocab_size=512,
    stages=(Stage((attn_block(4, 2, 16, 96, rope_theta=1e4, ffn="moe", moe=_SM),), 2),),
    tie_embeddings=False, max_seq_len=128,
)
