"""xlstm-350m [ssm] — 24L d=1024 4H vocab=50304 [arXiv:2405.04517].

7:1 mLSTM:sLSTM interleave (xLSTM[7:1]); d_ff=0 in the assignment means no
separate FFN — the mLSTM block carries a 2× pre-up-projection and the sLSTM
block a 4/3 post-up-projection MLP, per the paper.  At the assigned
d_model=1024/24L this counts ~0.49B params (the paper's 350M uses a narrower
block; the assignment shapes are authoritative — noted in DESIGN.md).
Runs long_500k (recurrent state is O(1) per token).  sLSTM's block-diagonal
recurrence is implemented dense (systems-equivalent FLOP shape).
"""
from repro.configs.base import BlockCfg, MLPCfg, ModelCfg, Stage, XLSTMCfg

_M = BlockCfg(mixer="mlstm", xlstm=XLSTMCfg(kind="mlstm", num_heads=4, proj_factor=2.0))
_S = BlockCfg(mixer="slstm", xlstm=XLSTMCfg(kind="slstm", num_heads=4, proj_factor=1.0),
              ffn="mlp", mlp=MLPCfg(d_ff=1368, gated=True, act="gelu"))

FULL = ModelCfg(
    name="xlstm-350m", d_model=1024, vocab_size=50304,
    stages=(Stage((_M,) * 7 + (_S,), 3),), tie_embeddings=True,
    max_seq_len=524288,
)

_MS = BlockCfg(mixer="mlstm", xlstm=XLSTMCfg(kind="mlstm", num_heads=2, proj_factor=2.0))
_SS = BlockCfg(mixer="slstm", xlstm=XLSTMCfg(kind="slstm", num_heads=2, proj_factor=1.0),
               ffn="mlp", mlp=MLPCfg(d_ff=96, gated=True, act="gelu"))
SMOKE = ModelCfg(
    name="xlstm-smoke", d_model=64, vocab_size=512,
    stages=(Stage((_MS, _SS), 2),), tie_embeddings=True, max_seq_len=128,
)
