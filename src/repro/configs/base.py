"""Config dataclasses for the composable model substrate.

Every assigned architecture is expressed as a ``ModelCfg``: a sequence of
``Stage``s, each a repeated ``pattern`` of ``BlockCfg``s.  Homogeneous repeats
are scanned with ``lax.scan`` so HLO size is O(pattern), not O(depth).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Mixers


@dataclass(frozen=True)
class AttnCfg:
    """Self- or cross-attention mixer (GQA with optional RoPE / window)."""

    num_heads: int
    num_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_theta: Optional[float] = 10000.0  # None = no RoPE (abs-pos upstream)
    window: Optional[int] = None  # sliding-window size; None = full attention
    causal: bool = True
    cross: bool = False  # kv comes from encoder states (vision frontend)


@dataclass(frozen=True)
class MambaCfg:
    """Mamba-1 selective SSM mixer."""

    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: Optional[int] = None  # default: ceil(d_model / 16)


@dataclass(frozen=True)
class XLSTMCfg:
    """sLSTM / mLSTM mixer (xLSTM, arXiv:2405.04517)."""

    kind: str = "mlstm"  # "mlstm" | "slstm"
    num_heads: int = 4
    proj_factor: float = 2.0  # pre-up-projection factor (mLSTM)


# ---------------------------------------------------------------------------
# FFNs


@dataclass(frozen=True)
class MLPCfg:
    d_ff: int
    gated: bool = True  # SwiGLU-style gate
    act: str = "silu"  # "silu" | "gelu"


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff: int
    capacity_factor: float = 1.25
    dense_residual: Optional[MLPCfg] = None  # arctic-style parallel dense FFN
    impl: str = "dispatch"  # "dispatch" (capacity einsum) | "ragged" (dropless)


# ---------------------------------------------------------------------------
# Blocks / stages / model


@dataclass(frozen=True)
class BlockCfg:
    """One residual block = mixer (+ optional FFN sub-block)."""

    mixer: str  # "attn" | "cross_attn" | "mamba" | "mlstm" | "slstm"
    attn: Optional[AttnCfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    ffn: Optional[str] = None  # "mlp" | "moe" | None
    mlp: Optional[MLPCfg] = None
    moe: Optional[MoECfg] = None


@dataclass(frozen=True)
class Stage:
    pattern: Tuple[BlockCfg, ...]
    repeats: int = 1


@dataclass(frozen=True)
class ModelCfg:
    name: str
    d_model: int
    vocab_size: int
    stages: Tuple[Stage, ...]
    max_seq_len: int = 131072
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    is_encoder: bool = False  # bidirectional, no decode step (hubert)
    frontend: Optional[str] = None  # None | "audio" | "vision"
    n_img_tokens: int = 1024  # vision cross-attn stub: patch-embedding count
    dtype: str = "bfloat16"
    param_dtype: str = "float32"  # big archs use bf16 storage (see configs)
    remat: str = "full"  # memory-mode knob: "none" | "dots" | "full"
    seq_shard_residuals: bool = True  # Megatron-SP-style saved boundaries
    attn_q_chunk: int = 128  # q-chunk for the online-softmax attention path
    use_flash: bool = False  # route attention through the Pallas kernel
    abs_pos: str = "none"  # "none" | "sinusoidal" (encoders without RoPE)

    # ---- derived -----------------------------------------------------
    @property
    def n_layers(self) -> int:
        return sum(len(s.pattern) * s.repeats for s in self.stages)

    def replace(self, **kw) -> "ModelCfg":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes assigned to the LM family (seq_len × global_batch)


@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = ShapeCfg("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCfg("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCfg("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCfg("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def param_count(cfg: ModelCfg) -> int:
    """Analytic parameter count (for MODEL_FLOPS = 6·N·D and sanity checks)."""
    d = cfg.d_model
    n = cfg.vocab_size * d  # embedding
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d
    if cfg.abs_pos == "learned":
        n += cfg.max_seq_len * d
    for st in cfg.stages:
        for blk in st.pattern:
            n += st.repeats * _block_params(cfg, blk)
    n += d  # final norm
    return n


def active_param_count(cfg: ModelCfg) -> int:
    """Params touched per token (MoE: only top_k experts + shared)."""
    d = cfg.d_model
    n = cfg.vocab_size * d
    if not cfg.tie_embeddings:
        n += cfg.vocab_size * d
    for st in cfg.stages:
        for blk in st.pattern:
            n += st.repeats * _block_params(cfg, blk, active_only=True)
    n += d
    return n


def _mlp_params(d: int, m: MLPCfg) -> int:
    return d * m.d_ff * (3 if m.gated else 2)


def _block_params(cfg: ModelCfg, blk: BlockCfg, active_only: bool = False) -> int:
    d = cfg.d_model
    n = 0
    if blk.mixer in ("attn", "cross_attn"):
        a = blk.attn
        q = d * a.num_heads * a.head_dim
        kv = 2 * d * a.num_kv_heads * a.head_dim
        o = a.num_heads * a.head_dim * d
        n += q + kv + o + d  # + pre-norm scale
        if a.qkv_bias:
            n += (a.num_heads + 2 * a.num_kv_heads) * a.head_dim
        if blk.mixer == "cross_attn":
            n += d  # kv-norm scale
    elif blk.mixer == "mamba":
        mc = blk.mamba
        d_in = mc.expand * d
        dt_rank = mc.dt_rank or -(-d // 16)
        n += d * 2 * d_in  # in_proj
        n += d_in * mc.d_conv + d_in  # depthwise conv + bias
        n += d_in * (dt_rank + 2 * mc.d_state)  # x_proj
        n += dt_rank * d_in + d_in  # dt_proj
        n += d_in * mc.d_state + d_in  # A_log, D
        n += d_in * d  # out_proj
        n += d  # pre-norm
    elif blk.mixer in ("mlstm", "slstm"):
        xc = blk.xlstm
        if xc.kind == "mlstm":
            d_in = int(xc.proj_factor * d)
            n += d * 2 * d_in  # up proj (x, gate)
            n += 3 * d_in * d_in  # q,k,v
            n += 2 * d_in  # i,f gate biases-as-projections (per-head scalars)
            n += 2 * d_in * xc.num_heads  # igate/fgate projections (low rank)
            n += d_in * d  # down proj
            n += d
        else:  # slstm
            n += 4 * d * d + 4 * d  # i,f,z,o recurrent-free projections
            n += 4 * d * d  # recurrent (block-diagonal approximated dense)
            n += d
            n += _mlp_params(d, MLPCfg(d_ff=int(4 * d * xc.proj_factor / 3), gated=True))
    if blk.ffn == "mlp":
        n += _mlp_params(d, blk.mlp) + d
    elif blk.ffn == "moe":
        mo = blk.moe
        e = mo.top_k if active_only else mo.num_experts
        n += e * d * mo.d_ff * 3 + d * mo.num_experts + d  # experts + router + norm
        if mo.dense_residual is not None:
            n += _mlp_params(d, mo.dense_residual)
    return n
