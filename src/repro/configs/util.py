"""Shared builders for architecture configs."""
from __future__ import annotations

from repro.configs.base import (AttnCfg, BlockCfg, MLPCfg, ModelCfg, MoECfg, Stage)


def attn_block(num_heads, num_kv_heads, head_dim, d_ff, *, qkv_bias=False,
               rope_theta=1e6, window=None, causal=True, gated=True,
               act="silu", ffn="mlp", moe=None, cross=False):
    a = AttnCfg(num_heads=num_heads, num_kv_heads=num_kv_heads, head_dim=head_dim,
                qkv_bias=qkv_bias, rope_theta=rope_theta, window=window,
                causal=causal, cross=cross)
    kw = dict(mixer="cross_attn" if cross else "attn", attn=a, ffn=ffn)
    if ffn == "mlp":
        kw["mlp"] = MLPCfg(d_ff=d_ff, gated=gated, act=act)
    elif ffn == "moe":
        kw["moe"] = moe
    return BlockCfg(**kw)


def dense_lm(name, *, n_layers, d_model, n_heads, n_kv, d_ff, vocab,
             head_dim=None, qkv_bias=False, rope_theta=1e6, tie=True,
             max_seq_len=32768, **model_kw):
    blk = attn_block(n_heads, n_kv, head_dim or d_model // n_heads, d_ff,
                     qkv_bias=qkv_bias, rope_theta=rope_theta)
    return ModelCfg(name=name, d_model=d_model, vocab_size=vocab,
                    stages=(Stage((blk,), n_layers),), tie_embeddings=tie,
                    max_seq_len=max_seq_len, **model_kw)
