"""glm4-9b [dense] — 40L d=4096 32H (GQA kv=2) d_ff=13696 vocab=151552.

RoPE + GQA + QKV bias [hf:THUDM/glm-4-9b].  (GLM's partial-rotary is
approximated with full RoPE — systems-equivalent; noted in DESIGN.md.)
"""
from repro.configs.util import dense_lm

FULL = dense_lm("glm4-9b", n_layers=40, d_model=4096, n_heads=32, n_kv=2,
                head_dim=128, d_ff=13696, vocab=151552, qkv_bias=True,
                rope_theta=1e6, tie=False)

SMOKE = dense_lm("glm4-9b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 head_dim=16, d_ff=160, vocab=512, qkv_bias=True,
                 rope_theta=1e4, tie=False, max_seq_len=128)
