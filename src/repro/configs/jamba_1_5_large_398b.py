"""jamba-1.5-large-398b [hybrid] — 72L d=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16 experts top-2 [arXiv:2403.19887].

Mamba:attention 7:1 interleave; MoE every other layer.  Pattern (period 8):
positions 0..7 are Mamba except position 4 (attention); odd positions carry
MoE FFNs, even positions dense FFNs.  9 repeats → 72 layers, 9 attention,
36 MoE.  Runs long_500k: only the 9 attention layers hold full-length KV.
~398B total params.
"""
from repro.configs.base import BlockCfg, MambaCfg, MLPCfg, ModelCfg, MoECfg, Stage
from repro.configs.util import attn_block

_MOE = MoECfg(num_experts=16, top_k=2, d_ff=24576, capacity_factor=1.25)
_MAMBA = MambaCfg(d_state=16, d_conv=4, expand=2)


def _mamba_blk(ffn, moe=None, d_ff=24576):
    kw = dict(mixer="mamba", mamba=_MAMBA, ffn=ffn)
    if ffn == "mlp":
        kw["mlp"] = MLPCfg(d_ff=d_ff)
    else:
        kw["moe"] = moe
    return BlockCfg(**kw)


_PATTERN = (
    _mamba_blk("mlp"),
    _mamba_blk("moe", _MOE),
    _mamba_blk("mlp"),
    _mamba_blk("moe", _MOE),
    attn_block(64, 8, 128, 24576),
    _mamba_blk("moe", _MOE),
    _mamba_blk("mlp"),
    _mamba_blk("moe", _MOE),
)

FULL = ModelCfg(
    name="jamba-1.5-large-398b", d_model=8192, vocab_size=65536,
    stages=(Stage(_PATTERN, 9),), tie_embeddings=False,
    max_seq_len=524288, param_dtype="bfloat16",
)

_SMOE = MoECfg(num_experts=4, top_k=2, d_ff=128)
_SMAMBA = MambaCfg(d_state=4, d_conv=4, expand=2)
SMOKE = ModelCfg(
    name="jamba-smoke", d_model=64, vocab_size=512,
    stages=(Stage((
        BlockCfg(mixer="mamba", mamba=_SMAMBA, ffn="mlp", mlp=MLPCfg(d_ff=128)),
        BlockCfg(mixer="mamba", mamba=_SMAMBA, ffn="moe", moe=_SMOE),
        attn_block(4, 2, 16, 128, rope_theta=1e4),
    ), 2),),
    tie_embeddings=False, max_seq_len=128,
)
