"""llama4-maverick-400b-a17b [moe] — 48L d=5120 40H (GQA kv=8) d_ff=8192
vocab=202048, MoE 128 experts top-1 + shared expert, alternating dense/MoE
layers [hf:meta-llama/Llama-4-*].  ~400B total / ~17B active params.

"Early fusion" multimodality is out of the backbone scope (text tokens only;
the assignment marks this entry [moe], not [vlm]).
"""
from repro.configs.base import MLPCfg, ModelCfg, MoECfg, Stage
from repro.configs.util import attn_block

_MOE = MoECfg(num_experts=128, top_k=1, d_ff=8192, capacity_factor=1.25,
              dense_residual=MLPCfg(d_ff=8192))  # shared expert
_DENSE = attn_block(40, 8, 128, 8192, rope_theta=5e5)
_MOE_BLK = attn_block(40, 8, 128, 8192, rope_theta=5e5, ffn="moe", moe=_MOE)

FULL = ModelCfg(
    name="llama4-maverick-400b-a17b", d_model=5120, vocab_size=202048,
    stages=(Stage((_DENSE, _MOE_BLK), 24),), tie_embeddings=False,
    max_seq_len=32768, param_dtype="bfloat16",
)

_SM = MoECfg(num_experts=8, top_k=1, d_ff=128, dense_residual=MLPCfg(d_ff=128))
SMOKE = ModelCfg(
    name="llama4-maverick-smoke", d_model=64, vocab_size=512,
    stages=(Stage((attn_block(4, 2, 16, 128, rope_theta=1e4),
                   attn_block(4, 2, 16, 128, rope_theta=1e4, ffn="moe", moe=_SM)), 1),),
    tie_embeddings=False, max_seq_len=128,
)
