"""qwen2-1.5b [dense] — 28L d=1536 12H (GQA kv=2) d_ff=8960 vocab=151936.

GQA + QKV bias [arXiv:2407.10671]. ~1.5B params.
"""
from repro.configs.util import dense_lm

FULL = dense_lm("qwen2-1.5b", n_layers=28, d_model=1536, n_heads=12, n_kv=2,
                head_dim=128, d_ff=8960, vocab=151936, qkv_bias=True,
                rope_theta=1e6, tie=True)

SMOKE = dense_lm("qwen2-1.5b-smoke", n_layers=2, d_model=64, n_heads=4, n_kv=2,
                 head_dim=16, d_ff=128, vocab=512, qkv_bias=True,
                 rope_theta=1e4, tie=True, max_seq_len=128)
