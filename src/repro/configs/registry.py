"""Architecture registry: ``--arch <id>`` lookup, input specs, skip table."""
from __future__ import annotations

import importlib
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelCfg, ShapeCfg, SHAPES_BY_NAME, ALL_SHAPES

_MODULES = {
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "glm4-9b": "repro.configs.glm4_9b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "arctic-480b": "repro.configs.arctic_480b",
    "jamba-1.5-large-398b": "repro.configs.jamba_1_5_large_398b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "llama-3.2-vision-11b": "repro.configs.llama3_2_vision_11b",
    "xlstm-350m": "repro.configs.xlstm_350m",
}

ARCH_NAMES = tuple(_MODULES)

# archs with a sub-quadratic long-context path (run long_500k)
_SUBQUADRATIC = {"gemma3-4b", "jamba-1.5-large-398b", "xlstm-350m"}


def get_config(name: str, smoke: bool = False) -> ModelCfg:
    mod = importlib.import_module(_MODULES[name])
    return mod.SMOKE if smoke else mod.FULL


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    """None if the (arch, shape) cell runs; else the documented skip reason."""
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    if cfg.is_encoder and shape.kind == "decode":
        return "encoder-only: no autoregressive decode step"
    if shape_name == "long_500k" and arch not in _SUBQUADRATIC:
        return "pure full-attention arch: no sub-quadratic path for 512k decode"
    return None


def all_cells(include_skipped: bool = False):
    """Yield (arch, shape_name[, skip_reason])."""
    for arch in ARCH_NAMES:
        for shape in ALL_SHAPES:
            r = skip_reason(arch, shape.name)
            if r is None:
                yield (arch, shape.name)
            elif include_skipped:
                yield (arch, shape.name, r)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs — never allocate)


def input_specs(cfg: ModelCfg, shape: ShapeCfg) -> Dict:
    """Model inputs for a (cfg, shape) cell as ShapeDtypeStructs.

    train    -> {tokens, labels [, feats/img_feats]}
    prefill  -> same minus labels (lowered as a forward pass)
    decode   -> {tokens_t}; the KV cache is derived separately (it is state,
                not input — see launch/dryrun.py).
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens_t": jax.ShapeDtypeStruct((B, 1), i32)}
    specs: Dict = {}
    if cfg.frontend == "audio":
        specs["feats"] = jax.ShapeDtypeStruct((B, S, cfg.d_model // 2), bf16)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    if cfg.frontend == "vision":
        specs["img_feats"] = jax.ShapeDtypeStruct(
            (B, cfg.n_img_tokens, cfg.d_model // 2), bf16)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    return specs
