from repro.configs.base import (  # noqa: F401
    ALL_SHAPES,
    AttnCfg,
    BlockCfg,
    MLPCfg,
    MambaCfg,
    ModelCfg,
    MoECfg,
    SHAPES_BY_NAME,
    ShapeCfg,
    Stage,
    XLSTMCfg,
    active_param_count,
    param_count,
)
from repro.configs.registry import (  # noqa: F401
    ARCH_NAMES,
    all_cells,
    get_config,
    input_specs,
    skip_reason,
)
