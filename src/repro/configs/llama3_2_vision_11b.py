"""llama-3.2-vision-11b [vlm] — 40L d=4096 32H (GQA kv=8) d_ff=14336
vocab=128256 [hf:meta-llama/Llama-3.2-11B-Vision].

Cross-attention image layers every 5th layer (8 of 40).  The vision tower is
a STUB: ``img_feats`` arrive as precomputed patch embeddings
(B, n_img_tokens, d_model/2); a linear projects them to d_model and the
cross-attn layers attend over them.  long_500k skipped (pure full attention).
"""
from repro.configs.base import ModelCfg, Stage
from repro.configs.util import attn_block

_SELF = attn_block(32, 8, 128, 14336, rope_theta=5e5)
_CROSS = attn_block(32, 8, 128, 14336, rope_theta=None, cross=True)

FULL = ModelCfg(
    name="llama-3.2-vision-11b", d_model=4096, vocab_size=128256,
    stages=(Stage((_SELF, _SELF, _SELF, _SELF, _CROSS), 8),),
    tie_embeddings=False, frontend="vision", n_img_tokens=1024,
    max_seq_len=32768,
)

SMOKE = ModelCfg(
    name="llama-vision-smoke", d_model=64, vocab_size=512,
    stages=(Stage((attn_block(4, 2, 16, 128, rope_theta=1e4),
                   attn_block(4, 2, 16, 128, rope_theta=None, cross=True)), 2),),
    tie_embeddings=False, frontend="vision", n_img_tokens=16, max_seq_len=128,
)
