"""hubert-xlarge [audio] — 48L d=1280 16H (MHA) d_ff=5120 vocab=504→512.

Encoder-only (same backbone as wav2vec2-XL) [arXiv:2106.07447].  The conv
waveform frontend is a STUB: inputs are precomputed frame embeddings
(B, frames, d_model/2) projected by a linear layer.  Output head predicts
504 cluster targets; vocab is padded to 512 so the vocab axis shards over
the 16-way 'model' axis (8 padding classes, noted).
No decode shapes (encoder has no autoregressive step); prefill_32k lowers
the encoder forward.
"""
from repro.configs.base import ModelCfg, Stage
from repro.configs.util import attn_block

_BLK = attn_block(16, 16, 80, 5120, rope_theta=None, causal=False,
                  gated=False, act="gelu")

FULL = ModelCfg(
    name="hubert-xlarge", d_model=1280, vocab_size=512,
    stages=(Stage((_BLK,), 48),), tie_embeddings=False, is_encoder=True,
    frontend="audio", abs_pos="sinusoidal", max_seq_len=32768,
)

SMOKE = ModelCfg(
    name="hubert-smoke", d_model=64, vocab_size=64,
    stages=(Stage((attn_block(4, 4, 16, 128, rope_theta=None, causal=False,
                              gated=False, act="gelu"),), 2),),
    tie_embeddings=False, is_encoder=True, frontend="audio",
    abs_pos="sinusoidal", max_seq_len=128,
)
