"""Memory-mode policies — the TPU analogue of KNL MCDRAM/NUMA configuration.

KNL's boot-time memory modes decide how the fast near memory (16 GB MCDRAM)
mediates access to far memory (192 GB DRAM).  A TPU has the same two-level
structure per chip (VMEM ~128 MB fast, HBM 16 GB far) but the policy is set
at *compile time*, not boot time.  The mapping (DESIGN.md §2):

  near-memory policy ({cache, flat, hybrid})  ->  what stays resident:
    cache  : XLA-managed staging; remat "dots" (matmul outputs saved —
             HBM acts as backing store, recompute only cheap ops)
    flat   : everything resident, no remat ("none") — max HBM footprint,
             min recompute, like flat-mode's explicit allocation
    hybrid : full remat ("full") + seq-sharded residuals — min footprint,
             max recompute (half-and-half tradeoff)

  NUMA hash ({all2all, quadrant, ...})  ->  how the matmul iteration space
  tiles over VMEM (Pallas BlockSpec shapes + K-accumulation policy) — swept
  in benchmarks/memory_modes.py and core/sweep.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

from repro.configs.base import ModelCfg


@dataclass(frozen=True)
class MemoryMode:
    name: str
    remat: str  # "none" | "dots" | "full"
    # Pallas matmul tiling (the NUMA-hash analogue)
    block: Tuple[int, int, int] = (512, 512, 512)  # (bm, bk, bn)
    k_splits: int = 1  # 1 = single-pass accumulate ("cache"); >1 revisits C
    moe_impl: str = "dispatch"  # "dispatch" | "ragged"

    def vmem_bytes(self, dtype_bytes: int = 2) -> int:
        """Working set one grid step keeps in VMEM (A, B tiles + f32 C)."""
        bm, bk, bn = self.block
        return bm * bk * dtype_bytes + bk * bn * dtype_bytes + bm * bn * 4


# the three near-memory policies (× default tiling)
CACHE = MemoryMode("cache", remat="dots")
FLAT = MemoryMode("flat", remat="none")
HYBRID = MemoryMode("hybrid", remat="full")

MODES = {m.name: m for m in (CACHE, FLAT, HYBRID)}


def apply(cfg: ModelCfg, mode: MemoryMode) -> ModelCfg:
    return cfg.replace(remat=mode.remat)


def tiling_grid(vmem_budget: int = 100 * 2**20):
    """The '15 configurations' analogue: tilings × accumulation policies
    that fit VMEM.  Returns [(name, MemoryMode)] for the sweep."""
    out = []
    for bm, bk, bn in [(256, 256, 256), (512, 512, 512), (512, 1024, 512),
                       (1024, 512, 1024), (128, 2048, 128)]:
        for k_splits, tag in [(1, "cache"), (2, "hybrid"), (8, "flat")]:
            m = MemoryMode(f"b{bm}x{bk}x{bn}-{tag}", remat="dots",
                           block=(bm, bk, bn), k_splits=k_splits)
            if m.vmem_bytes() <= vmem_budget:
                out.append(m)
    return out
