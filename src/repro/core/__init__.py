"""The paper's contribution, generalized for TPU pods:

- sweep:        Nproc×Nthread-analogue mesh-factorization sweep (constant
                total memory, per the paper's N = 48000/√Nproc protocol)
- autotune:     pick {mesh split, memory mode, placement} from compiled-HLO
                roofline terms (the operator's "set good defaults" role)
- affinity:     torus-topology device ordering = `taskset` pinning analogue
- memory_modes: compile-time VMEM/remat policies = MCDRAM mode analogue
- roofline:     the three-term model everything is scored by
- hlo_cost:     loop-aware FLOP/collective extraction from compiled HLO
"""
