"""Topology-aware device ordering — the TPU analogue of `taskset` pinning.

The paper pins each Matlab/Octave process to a physically contiguous block of
cores (Fig. 3) so OpenMP threads stay near their data.  On a TPU pod the
analogous decision is *which physical chip* each (data, model) mesh
coordinate maps to: the 'model' axis carries the per-layer TP collectives, so
its members should be ICI neighbours.

This module models the v5e pod as a 2-D (16×16) torus, produces "pinned"
(torus-contiguous, what mesh_utils.create_device_mesh does on real hardware)
and "naive" (arbitrary enumeration) orderings, and scores a mesh by ring-hop
cost — the multiplier on every collective's wire time.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

TORUS = (16, 16)  # v5e pod: 16×16 2-D torus (256 chips)


def torus_coords(n: int = 256, torus: Tuple[int, int] = TORUS) -> np.ndarray:
    """Physical coordinates of chip i (row-major enumeration)."""
    rows, cols = torus
    idx = np.arange(n)
    return np.stack([idx // cols, idx % cols], axis=1)


def hop_distance(a, b, torus: Tuple[int, int] = TORUS) -> int:
    """Manhattan distance on the wrap-around torus."""
    d = 0
    for x, y, m in zip(a, b, torus):
        dd = abs(int(x) - int(y))
        d += min(dd, m - dd)
    return d


def ring_cost(order: Sequence[int], coords: np.ndarray) -> int:
    """Total hops for one ring pass over devices in `order` (incl. wrap)."""
    n = len(order)
    return sum(hop_distance(coords[order[i]], coords[order[(i + 1) % n]])
               for i in range(n))


@dataclass
class MeshPlacement:
    name: str
    device_order: np.ndarray  # (data, model) -> physical chip index
    axis_ring_cost: Dict[str, float]  # avg hops per ring step, per axis


def pinned_placement(data: int = 16, model: int = 16) -> MeshPlacement:
    """'model' groups = torus rows (1 hop/step rings); 'data' = columns."""
    order = np.arange(data * model).reshape(data, model)  # row-major = rows
    return _score("pinned", order)


def naive_placement(data: int = 16, model: int = 16, seed: int = 0) -> MeshPlacement:
    """Arbitrary (shuffled) enumeration — an unpinned scheduler's placement."""
    rng = np.random.RandomState(seed)
    order = rng.permutation(data * model).reshape(data, model)
    return _score("naive", order)


def _score(name: str, order: np.ndarray) -> MeshPlacement:
    coords = torus_coords(order.size)
    data, model = order.shape
    model_cost = np.mean([ring_cost(order[i, :], coords) / model
                          for i in range(data)])
    data_cost = np.mean([ring_cost(order[:, j], coords) / data
                         for j in range(model)])
    return MeshPlacement(name, order,
                         {"model": float(model_cost), "data": float(data_cost)})


def collective_slowdown(placement: MeshPlacement, axis: str) -> float:
    """Wire-time multiplier vs the ideal 1-hop ring for collectives on axis."""
    return placement.axis_ring_cost[axis] / 1.0


def placement_table() -> List[Dict]:
    rows = []
    for p in (pinned_placement(), naive_placement()):
        rows.append({
            "placement": p.name,
            "model_ring_hops_per_step": p.axis_ring_cost["model"],
            "data_ring_hops_per_step": p.axis_ring_cost["data"],
            "model_collective_slowdown": collective_slowdown(p, "model"),
            "data_collective_slowdown": collective_slowdown(p, "data"),
        })
    return rows
