"""Three-term roofline model over compiled-HLO artifacts.

    compute    = HLO_FLOPs_per_device / peak_FLOP/s
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / link_bw

Hardware constants are TPU v5e (the target; this container is CPU-only so
terms are derived from the dry-run's compiled artifacts, not measured).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs import SHAPES_BY_NAME, active_param_count, get_config, param_count


@dataclass(frozen=True)
class HwSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12  # bf16 FLOP/s per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per ICI link
    # host->device promotion bandwidth (PCIe-class): the governing term of
    # the tiered KV cache, exactly as the Xeon Phi studies measured the
    # DDR->MCDRAM path to be the governing term of cache mode
    h2d_bw: float = 32e9  # bytes/s per chip


V5E = HwSpec()


def roofline_terms(result: Dict, hw: HwSpec = V5E, cfg=None,
                   microbatches=None) -> Dict:
    """``result`` is one dry-run row (launch/dryrun.lower_cell output).

    The memory term is the ANALYTIC HBM-traffic model (core/memory_model):
    the HLO-walked proxy double-counts CPU-backend artifacts (f32 weight
    copies, Pallas-interpret VMEM traffic) — it is still reported as
    ``memory_s_hlo_proxy`` for comparison.
    """
    from repro.core import memory_model

    cfg0 = cfg if cfg is not None else get_config(result["arch"])
    shape0 = SHAPES_BY_NAME[result["shape"]]
    mesh_dims = [int(x) for x in result["mesh"].split("x")]
    mesh_shape = dict(zip(("pod", "data", "model")[-len(mesh_dims):], mesh_dims))
    mb = microbatches if microbatches is not None else (
        8 if param_count(cfg0) > 50e9 and shape0.kind == "train" else 1)
    analytic_bytes = memory_model.analytic_traffic(cfg0, shape0, mesh_shape, mb)

    t_comp = result["flops_per_device"] / hw.peak_flops
    t_mem = analytic_bytes / hw.hbm_bw
    t_coll = result["collective_bytes_per_device"] / hw.ici_bw
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    total = max(t_comp + t_mem + t_coll, 1e-30)

    # useful model FLOPs: 6·N·D train (fwd+bwd), 2·N·D forward-only;
    # D = tokens processed by the step
    n_active = active_param_count(cfg0)
    shape = shape0
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    hlo_flops_global = result["flops_per_device"] * result["n_devices"]
    useful_ratio = model_flops / max(hlo_flops_global, 1.0)
    # fraction of the compute roofline actually achieved if the step ran at
    # the dominant-term time (the paper's "66% of practical peak" analogue)
    ideal_t = model_flops / (result["n_devices"] * hw.peak_flops)
    roofline_frac = ideal_t / max(terms[dom], 1e-30)

    return {
        **terms,
        "memory_s_hlo_proxy": result["bytes_per_device"] / hw.hbm_bw,
        "dominant": dom,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_flops_global,
        "useful_flop_ratio": useful_ratio,
        "roofline_fraction": min(roofline_frac, 1.0),
        "step_time_lower_bound_s": terms[dom],
        "compute_fraction_of_total": t_comp / total,
    }


# the single source of truth for KV-pool storage costs (serve.engine sizes
# its byte-denominated page budget from these same tables)
KV_ITEMSIZE = {"float32": 4, "bfloat16": 2, "int8": 1}
# int8 pools carry one float32 scale per pool entry per KV head
KV_SCALE_BYTES = {"float32": 0, "bfloat16": 0, "int8": 4}


def _kv_elem_bytes(kv_dtype, head_dim: int, act_bytes: float) -> float:
    """Bytes one stored KV element costs under a pool representation,
    including the amortized per-entry-per-head scale of int8 pools
    (KV_SCALE_BYTES spread over ``head_dim`` elements).  ``kv_dtype=None``
    follows the activation dtype — the unquantized pool."""
    if kv_dtype is None:
        return act_bytes
    kvd = str(kv_dtype)
    return KV_ITEMSIZE[kvd] + KV_SCALE_BYTES[kvd] / head_dim


def decode_bound(cfg, batch: int, context_len: int, hw: HwSpec = V5E,
                 page_size: int = None, kv_dtype=None,
                 n_devices: int = 1) -> Dict:
    """Analytic tokens/s upper bound for one batched decode tick.

    The serving-engine analogue of the paper's practical-peak line: a decode
    step reads every active parameter plus each attention layer's live KV,
    and computes 2·N_active FLOPs per token plus the attention dot-products.
    ``page_size`` models the paged cache's read granularity (a slot's KV
    traffic rounds up to whole pages); windowed layers clamp their context
    to the window.  ``kv_dtype`` (None | "float32" | "bfloat16" | "int8")
    makes the KV-byte term representation-aware: an int8 pool streams
    ``1 + 4/hd`` bytes per element (values + amortized scales) instead of
    the activation dtype's 2-4 — the decode side of serving is memory-bound
    (the KNL follow-up's regime), so this term is usually the bound.
    benchmarks/serve_sweep.py scores measured engine throughput against
    ``tokens_per_s`` from this bound.

    ``n_devices`` models KV-head tensor parallelism (serve.engine ``mesh=``):
    each device holds 1/N of the paged KV pools and attends over only its
    head slice, so the attention FLOPs and KV byte terms divide by N.  The
    parameter sweep does NOT divide — serving TP replicates the weights
    (the KV pool, not the params, is what outgrows one device) — which is
    why decode throughput scales sub-linearly and saturates once the
    per-device bound goes param-sweep-dominated.
    """
    n_act = active_param_count(cfg)
    param_bytes = n_act * (2 if cfg.param_dtype == "bfloat16" else 4)
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4

    flops = 2.0 * n_act * batch
    kv_bytes = 0.0
    for st in cfg.stages:
        for blk in st.pattern:
            if blk.mixer not in ("attn", "cross_attn") or blk.attn is None:
                continue
            a = blk.attn
            t_eff = context_len if a.window is None else min(a.window,
                                                             context_len)
            eb = act_bytes
            if a.window is None:
                # only global layers page (and quantize); windowed layers
                # keep dense activation-dtype per-slot circular buffers
                # (see attention.init_paged_cache)
                if page_size:
                    t_eff = -(-t_eff // page_size) * page_size
                eb = _kv_elem_bytes(kv_dtype, a.head_dim, act_bytes)
            # per-device KV-head shard count: only global (paged) layers
            # shard, and only when the head count divides
            shards = (n_devices if a.window is None
                      and a.num_kv_heads % n_devices == 0 else 1)
            # qk^T + pv per query token, grouped heads
            flops += (st.repeats * 4.0 * batch * t_eff * a.num_heads
                      * a.head_dim / shards)
            kv_bytes += (st.repeats * 2.0 * batch * t_eff * a.num_kv_heads
                         * a.head_dim * eb / shards)

    t_comp = flops / hw.peak_flops
    t_mem = (param_bytes + kv_bytes) / hw.hbm_bw
    t = max(t_comp, t_mem, 1e-30)
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "dominant": "compute" if t_comp >= t_mem else "memory",
        "kv_bytes": kv_bytes,
        "param_bytes": param_bytes,
        "tokens_per_s": batch / t,
    }


def mixed_bound(cfg, n_decode: int, n_prefill: int, context_len: int,
                hw: HwSpec = V5E, page_size: int = None,
                kv_dtype=None, n_devices: int = 1,
                promoted_pages: float = 0.0,
                draft_tokens: float = 0.0,
                accept_rate: float = 0.0,
                swapped_pages: float = 0.0) -> Dict:
    """Analytic bound for ONE ragged tick — the decode/prefill roofline blend.

    Scores a pack of ``n_decode`` decode tokens + ``n_prefill`` prefill-chunk
    tokens against the hardware roofline.  The active parameters are swept
    ONCE per tick regardless of the mix — that is the ragged engine's
    structural win: the two-phase engine serves the same mix with a prefill
    tick AND a decode tick, paying the parameter sweep (the memory-bound
    term that dominates small-batch serving) twice.  Decode tokens read
    their slot's full live KV (page-rounded, like ``decode_bound``); prefill
    tokens attend over ~half the context on average and add their own KV
    writes.

    ``kv_dtype`` makes the paged-pool byte terms representation-aware (see
    ``decode_bound``): int8 streams ``1 + 4/hd`` bytes per stored element —
    values plus amortized scales — on BOTH the decode-side reads and the
    write side, which is what moves a memory-dominated tick's bound.

    Returns per-tick terms, byte terms (``kv_read_bytes`` /
    ``kv_write_bytes`` — the decode-side traffic the int8 pool halves or
    better), ``tokens_per_s`` for the whole pack, and
    ``speedup_vs_two_phase`` — the bound-level ratio against running the
    same tokens as separate prefill + decode programs.  The serve sweep
    reports measured ragged throughput against this bound.

    ``n_devices`` models KV-head tensor parallelism exactly as in
    ``decode_bound``: paged-layer attention FLOPs and KV read/write bytes
    divide by N (when the layer's KV-head count divides), the replicated
    parameter sweep does not.

    ``promoted_pages`` prices the TIERED KV cache's host→device traffic:
    the average pool pages per tick promoted from the host tier on prefix
    hits (``ServeEngine(host_pages=...)``).  Promotion bytes cross the
    ``hw.h2d_bw`` link — the governing term of the paper's cache mode —
    but the copy is issued at admission and OVERLAPPED with the tick's
    compute, so the tick time is ``max(compute, memory, promotion)``, not
    a sum: tiering is free until H2D traffic becomes the binding roof
    (reported as ``promotion_s`` / ``promoted_bytes``).  The alternative
    the term is priced against is re-prefilling the same tokens, which
    pays compute AND pool writes — a host hit wins whenever
    ``promotion_s`` is below the re-prefill tick it replaces.

    ``swapped_pages`` prices PREEMPTION swap traffic the same way: the
    average pool pages per tick moving across the host link for slot
    preemption — parks (device→host demote gathers of a victim's private
    pages) plus unparks (host→device promote scatters at resume).  Swap
    bytes are identical to promotion bytes per page and cross the same
    ``hw.h2d_bw`` link, overlapped with the tick's compute just like
    promotions (the gather is issued at preemption, the scatter at
    re-admission), so they fold into the SAME third roof:
    ``max(compute, memory, promotion + swap)``.  What preemption buys
    against that cost: the stall arm pays the victim's pages sitting idle
    under head-of-line blocking; the preempt arm pays one park + one
    unpark per victim — goodput wins whenever the blocked requests'
    tokens outweigh the swap roof (the ``preemption_scenario`` A/B
    measures exactly this).

    ``draft_tokens`` / ``accept_rate`` price SPECULATIVE decoding
    (``ServeEngine(spec_k=...)``): ``draft_tokens`` verify tokens ride
    along per decoding slot, of which ``accept_rate`` are expected to be
    accepted.  The asymmetry this model exists to show: a verify token
    pays full compute (a query over the slot's whole context, plus its
    share of the parameter matmuls) and writes its KV row, but adds
    NOTHING to the KV read side — the slot's page-stream is already being
    read for its base decode token, and the verify rows share it.  Since
    small-batch decode ticks are memory-bound on exactly that page-stream
    (plus the parameter sweep), verify tokens are near-free until the
    added compute reaches the memory roof — which is why the bound's
    ``tokens_per_s`` (EMITTED tokens: ``n_decode · (1 + accept_rate ·
    draft_tokens) + n_prefill`` per tick) grows almost linearly in the
    accepted depth.  Defaults (0, 0) reproduce the non-speculative bound
    bit for bit.
    """
    if not 0.0 <= accept_rate <= 1.0:
        raise ValueError(f"accept_rate must be in [0, 1], got {accept_rate}")
    if draft_tokens < 0:
        raise ValueError(f"draft_tokens must be >= 0, got {draft_tokens}")
    n_act = active_param_count(cfg)
    param_bytes = n_act * (2 if cfg.param_dtype == "bfloat16" else 4)
    act_bytes = 2 if cfg.dtype == "bfloat16" else 4
    total = (n_decode * (1.0 + accept_rate * draft_tokens)) + n_prefill

    def _tick(n_dec, n_pre, n_draft=0.0):
        toks = n_dec + n_pre + n_draft
        flops = 2.0 * n_act * toks
        kv_read = kv_write = 0.0
        for st in cfg.stages:
            for blk in st.pattern:
                if blk.mixer not in ("attn", "cross_attn") or blk.attn is None:
                    continue
                a = blk.attn
                t_eff = (context_len if a.window is None
                         else min(a.window, context_len))
                eb = act_bytes
                if a.window is None:
                    if page_size:
                        t_eff = -(-t_eff // page_size) * page_size
                    eb = _kv_elem_bytes(kv_dtype, a.head_dim, act_bytes)
                shards = (n_devices if a.window is None
                          and a.num_kv_heads % n_devices == 0 else 1)
                # decode AND draft tokens see the whole context; prefill
                # tokens see ~half of it on average (causal positions
                # 0..ctx).  COMPUTE scales with every query token...
                q_ctx = (n_dec + n_draft) * t_eff + n_pre * t_eff / 2.0
                # ...but the KV READ stream does not scale with drafts:
                # verify rows share the page-stream their slot's base
                # decode token already reads (the near-free-verify claim)
                q_ctx_read = n_dec * t_eff + n_pre * t_eff / 2.0
                flops += (st.repeats * 4.0 * q_ctx * a.num_heads
                          * a.head_dim / shards)
                kv_read += (st.repeats * 2.0 * q_ctx_read * a.num_kv_heads
                            * a.head_dim * eb / shards)
                kv_write += (st.repeats * 2.0 * toks * a.num_kv_heads
                             * a.head_dim * eb / shards)
        t_comp = flops / hw.peak_flops
        t_mem = (param_bytes + kv_read + kv_write) / hw.hbm_bw
        return t_comp, t_mem, max(t_comp, t_mem, 1e-30), kv_read, kv_write

    t_comp, t_mem, t, kv_read, kv_write = _tick(
        n_decode, n_prefill, n_decode * draft_tokens)
    # promotion term: pages/tick crossing the host->device link, overlapped
    # with the tick's compute (issued at admission) — a third roof, not an
    # added cost
    page_bytes = 0.0
    if promoted_pages or swapped_pages:
        ps = page_size or 1
        for st in cfg.stages:
            for blk in st.pattern:
                if (blk.mixer not in ("attn", "cross_attn")
                        or blk.attn is None or blk.attn.window is not None):
                    continue
                a = blk.attn
                eb = _kv_elem_bytes(kv_dtype, a.head_dim, act_bytes)
                shards = n_devices if a.num_kv_heads % n_devices == 0 else 1
                page_bytes += (st.repeats * 2.0 * ps * a.num_kv_heads
                               * a.head_dim * eb / shards)
    promo_bytes = page_bytes * promoted_pages
    # preemption swap bytes are priced per page exactly like promotion
    # (same layers, same dtype, same link) and share its overlap roof
    swap_bytes = page_bytes * swapped_pages
    t_promo = (promo_bytes + swap_bytes) / hw.h2d_bw
    t = max(t, t_promo, 1e-30)
    # two-phase floor: the same tokens as a decode-only tick plus a
    # prefill-only tick, each paying its own parameter sweep
    t_dec = _tick(n_decode, 0)[2]
    t_pre = _tick(0, n_prefill)[2]
    two_phase = ((t_dec if n_decode else 0.0) + (t_pre if n_prefill else 0.0)
                 or 1e-30)
    dom = "compute" if t_comp >= t_mem else "memory"
    return {
        "compute_s": t_comp,
        "memory_s": t_mem,
        "promotion_s": t_promo,
        "promoted_bytes": promo_bytes,
        "swap_s": swap_bytes / hw.h2d_bw,
        "swapped_bytes": swap_bytes,
        "dominant": "promotion" if t_promo >= max(t_comp, t_mem) and t_promo
                    else dom,
        "kv_read_bytes": kv_read,
        "kv_write_bytes": kv_write,
        "tick_s": t,
        # EMITTED tokens per second: with speculation each decode slot
        # lands 1 + accept_rate·draft_tokens accepted tokens per tick
        "tokens_per_s": total / t if total else 0.0,
        "accepted_per_slot_tick": 1.0 + accept_rate * draft_tokens,
        "drafted_tokens": n_decode * draft_tokens,
        "speedup_vs_two_phase": two_phase / t,
    }


def format_row(result: Dict, terms: Dict) -> str:
    return (f"| {result['arch']} | {result['shape']} | {result['mesh']} "
            f"| {terms['compute_s']:.3e} | {terms['memory_s']:.3e} "
            f"| {terms['collective_s']:.3e} | {terms['dominant'].replace('_s','')} "
            f"| {terms['useful_flop_ratio']:.2f} | {terms['roofline_fraction']:.1%} |")
