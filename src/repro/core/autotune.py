"""Configuration autotuner — the systems operator's role in the paper,
automated: pick {memory mode, attention path, MoE impl, microbatching} per
(arch × shape) by lowering candidates and comparing roofline terms.

The paper's conclusion ("set KMP_AFFINITY/taskset/all2all-cache once,
system-wide, and every user's Nproc×Nthread choice stays near peak") maps to
``select_defaults``: sweep candidates on the production mesh, score by the
dominant roofline term, and emit the winning config — recorded in
EXPERIMENTS.md §Perf as the tuned default.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.roofline import V5E, HwSpec, roofline_terms


@dataclass(frozen=True)
class Candidate:
    name: str
    overrides: Dict = field(default_factory=dict)  # ModelCfg.replace kwargs
    microbatches: Optional[int] = None


DEFAULT_CANDIDATES = (
    Candidate("baseline", {}),
    Candidate("remat-dots", {"remat": "dots"}),
    Candidate("remat-none", {"remat": "none"}),
    Candidate("flash-attn", {"use_flash": True}),
    Candidate("q-chunk-512", {"attn_q_chunk": 512}),
)


def evaluate(arch: str, shape_name: str, mesh, candidates=DEFAULT_CANDIDATES,
             hw: HwSpec = V5E, hbm_limit: float = 16 * 2**30) -> List[Dict]:
    """Lower every candidate; return scored rows sorted by step-time bound."""
    from repro.launch.dryrun import lower_cell

    rows = []
    for cand in candidates:
        try:
            res = lower_cell(arch, shape_name, mesh, overrides=cand.overrides)
        except Exception as e:  # servelint: ignore[broad-except] — a sweep candidate may be invalid for this arch in arbitrary ways; the error is recorded in the row, never swallowed
            rows.append({"candidate": cand.name, "error": repr(e)[:200]})
            continue
        terms = roofline_terms(res, hw)
        rows.append({
            "candidate": cand.name,
            "fits_hbm": res["analytic_hbm_bytes"] <= hbm_limit * 0.9,
            "step_bound_s": terms["step_time_lower_bound_s"],
            "dominant": terms["dominant"],
            "roofline_fraction": terms["roofline_fraction"],
            **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        })
    ok = [r for r in rows if r.get("fits_hbm")]
    # Candidate name is an explicit tie-break: float-equal step bounds (e.g.
    # two remat modes that lower to the same HLO on this backend) would
    # otherwise rank in dict/insertion order, which varies with the
    # environment that produced the rows.
    ranked = sorted(ok or [r for r in rows if "error" not in r],
                    key=lambda r: (r["step_bound_s"], r["candidate"]))
    for i, r in enumerate(ranked):
        r["rank"] = i
    return rows


def select_defaults(arch: str, shape_name: str, mesh, **kw) -> Dict:
    rows = evaluate(arch, shape_name, mesh, **kw)
    best = min((r for r in rows if "error" not in r),
               key=lambda r: (not r.get("fits_hbm", False), r["step_bound_s"],
                              r["candidate"]))
    return {"best": best, "table": rows}


# ---------------------------------------------------------------------------
# Serving-time autotune: ONE (token_budget, prefill_chunk, page_size,
# kv_dtype, scheduler) for all traffic — the paper's "set it once
# system-wide, every grid point stays near peak" claim at serving time,
# covering the memory representation (the analogue of the paper's decisive
# cache-mode setting) AND, since the scheduling layer became pluggable
# (serve.scheduler), the workload policy.  Instead of per-workload
# retuning, we sweep the serving knobs against the analytic roofline blend
# (core.roofline.mixed_bound) over a traffic-mix grid (decode-heavy steady
# state, a chat/doc blend, a prefill burst — each at a short-chat and a
# long-document context) and keep the config whose WORST grid point is the
# largest fraction of that point's achievable peak (max-min, not max-mean:
# the paper's figures reward flatness across the grid, not one tall corner).


# Analytic scheduler model for the two policy-sensitive traffic points the
# measured A/B (benchmarks/serve_sweep.py:scheduler_ab_scenario) exercises.
# ``residency``: fraction of a shared family prefix still resident when the
# NEXT family member is admitted, under the A/B's pressure regime (the pool
# holds roughly half the live prefix families at once): prefix-aware
# admission groups a family's requests back to back so its prefix survives
# its whole run; interleaving policies (fifo, slo) lose it to the other
# families' allocations about half the time.  ``interactive_wait``: document
# prefills an interactive arrival sits behind before admission — slo's
# class-ordered window admits it next (0); arrival-ordered policies make it
# wait out one queued document (1).
SCHEDULER_MODEL = {
    "fifo": {"residency": 0.5, "interactive_wait": 1.0},
    "prefix-aware": {"residency": 1.0, "interactive_wait": 1.0},
    "slo": {"residency": 0.5, "interactive_wait": 0.0},
    # the composite (SLO class first, family grouping within a class) keeps
    # prefix-aware's residency AND slo's interactive jump — it gives up
    # neither criterion, which under max-min is exactly what wins the axis
    "class-then-family": {"residency": 1.0, "interactive_wait": 0.0},
}


def select_serve_defaults(arch: str, *, batch_size: int = 8,
                          context_len: int = 256,
                          token_budgets=(64, 128, 256),
                          prefill_chunks=(16, 32, 64),
                          page_sizes=(8, 16, 32),
                          kv_dtypes=("float32", "bfloat16", "int8"),
                          schedulers=("fifo", "prefix-aware", "slo",
                                      "class-then-family"),
                          device_counts=(1,),
                          host_pool_pages=(0,),
                          spec_ks=(0,), spec_accept_rate: float = 0.6,
                          shared_frac: float = 0.75, gen_tokens: int = 32,
                          hw: HwSpec = V5E, smoke: bool = False) -> Dict:
    """Emit ONE tuned serving config for ``serve.ServeEngine``.

    Scores every (token_budget × prefill_chunk × page_size × kv_dtype ×
    scheduler) candidate on a traffic-mix grid via ``roofline.mixed_bound``
    (the parameter sweep is analytic — no engine runs).  The ``kv_dtype``
    axis makes the tuned config pick the MEMORY REPRESENTATION — the
    paper's "set it once" covers the decisive memory-mode knob: an int8
    pool streams roughly a quarter of the fp32 decode-side bytes, so on
    memory-dominated mixes it lifts every criterion at once.  The
    ``scheduler`` axis adds the WORKLOAD POLICY via ``SCHEDULER_MODEL``,
    scored on two extra criteria that mirror the measured A/B scenario:

    - ``warm@families`` — request throughput on shared-prefix traffic
      (``shared_frac`` of each ``context_len`` prompt is a family prefix,
      ``gen_tokens`` generated): serving a request costs
      ``(1 - hit)·S + G`` pack tokens where ``hit = shared_frac ×
      residency(scheduler)``, so policies that keep a family's prefix
      resident convert the same pack rate into more emitted tokens.
    - ``interactive@arrival`` — 1 / (time to an interactive arrival's first
      token): ``interactive_wait(scheduler)`` document prefills of
      admission delay plus one tick, at the blend point's tick time.

    The remaining criteria are pack tokens/s on the mix points (prefill
    capped at what the engine can actually pack per tick) PLUS the decode
    rate under the blend tick (1/tick_s — a decoding user's inter-token gap
    is the tick, so this criterion pulls against unbounded pack growth).
    Returns::

        {"best": {token_budget, prefill_chunk, page_size, kv_dtype,
                  scheduler, score, ...},
         "table": [per-candidate rows with per-criterion values/fractions]}

    ``score`` is the candidate's worst-case fraction of the per-criterion
    best across all candidates (1.0 = this config is on the peak for every
    criterion) — under max-min the scheduler axis is typically decided by
    whichever criterion a policy sacrifices LEAST (slo gives up some warm
    throughput, prefix-aware gives up the interactive jump; fifo gives up
    both and can never win the axis).  benchmarks/serve_sweep.py records
    the selection next to the measured rows in BENCH_serve.json.

    ``device_counts`` adds the KV-head tensor-parallel axis (ServeEngine
    ``mesh=``): each count is threaded to ``mixed_bound(n_devices=...)``,
    which divides the paged-layer attention FLOPs and KV byte terms but not
    the replicated parameter sweep — so the tuner sees exactly where TP
    stops paying (once the per-device bound goes param-dominated).  The
    default ``(1,)`` keeps the single-device grid (and table size)
    unchanged; rows and ``best`` carry ``n_devices`` either way.

    ``host_pool_pages`` adds the TIERED-CACHE axis (ServeEngine
    ``host_pages=``).  When a nonzero size is on the axis, every candidate
    is additionally scored on ``spill@replay``: warm-replay traffic whose
    prefix working set exceeds the device pool (the tiered bench scenario).
    Untiered (0), a spilled prefix re-prefills — the slot is occupied for
    ``ceil(S / chunk)`` prefill ticks (per-slot chunk rate, capped by the
    leftover budget split across the replaying slots — the engine's pack
    bound) before its ``G`` decode ticks; tiered, it PROMOTES —
    ``S/page_size`` pages of host→device traffic priced by
    ``mixed_bound(promoted_pages=...)`` against ``hw.h2d_bw``, overlapped
    with decode, so the request costs only its ``G`` decode ticks at the
    (possibly promotion-roofed) tick time.  The default ``(0,)`` skips the
    criterion entirely: the existing selection is bit-identical.

    ``spec_ks`` adds the SPECULATIVE-DECODING axis (ServeEngine
    ``spec_k=`` / ``serve.scheduler.SpeculativeScheduler``).  When a
    nonzero k is on the axis, every candidate is additionally scored on
    ``spec@repetitive``: accepted-token goodput on repetitive decode-heavy
    traffic (the prompt-lookup drafter's home turf), priced by
    ``mixed_bound(draft_tokens=, accept_rate=spec_accept_rate)`` — draft
    rows pay compute and KV writes but share the slot's KV page-stream, so
    on memory-dominated ticks acceptance is nearly free throughput.  The
    effective k is capped by the leftover budget per decoding slot
    (``(token_budget - batch) // batch`` — the engine packs drafts strictly
    after decode and prefill), so the axis pulls TOWARD bigger budgets in a
    way the other criteria must balance.  The default ``(0,)`` skips the
    criterion entirely: the existing selection is bit-identical.
    """
    from repro.configs import get_config
    from repro.core.roofline import mixed_bound

    cfg = get_config(arch, smoke=smoke)
    chat_ctx = max(context_len // 4, 1)

    def mix_points(budget, chunk):
        # prefill per tick is bounded by BOTH the leftover budget and the
        # per-slot chunk cap times the slot count — the engine can never
        # pack more (see ServeEngine._ragged_tick), so crediting a candidate
        # with an unpackable burst would make big budgets win for free
        dec = min(batch_size, budget)
        packable = chunk * batch_size
        blend = min(packable, max(budget - dec, 0))
        burst = min(packable, budget - 1)
        return (("decode@doc", dec, 0, context_len),
                ("decode@chat", dec, 0, chat_ctx),
                ("blend@doc", dec, blend, context_len),
                ("burst@chat", 1, burst, chat_ctx))

    rows: List[Dict] = []
    for tb in token_budgets:
        if tb < batch_size:
            continue  # engine invariant: every decoding slot packs per tick
        for pc in prefill_chunks:
            if pc >= tb:
                continue  # a chunk that fills the whole budget starves decode
            for ps in page_sizes:
                for kvd, ndev in ((kvd, ndev) for kvd in kv_dtypes
                                  for ndev in device_counts):
                    tps = {}
                    blend_tick_s = 1e-30
                    blend_tps = 0.0
                    for name, nd, npf, ctx in mix_points(tb, pc):
                        r = mixed_bound(cfg, n_decode=nd, n_prefill=npf,
                                        context_len=ctx, hw=hw, page_size=ps,
                                        kv_dtype=kvd, n_devices=ndev)
                        tps[name] = r["tokens_per_s"]
                        if name == "blend@doc":
                            blend_tick_s = max(r["tick_s"], 1e-30)
                            blend_tps = r["tokens_per_s"]
                            # a decoding user's inter-token gap IS the tick:
                            # the latency criterion pulls AGAINST ever-bigger
                            # packs, so max-min trades throughput off against
                            # p50 decode latency under concurrent prefill
                            # (the PR 2 metric)
                            tps["decode_rate@blend"] = 1.0 / blend_tick_s
                    # tiered-cache axis: replay throughput when the prefix
                    # working set spills past the device pool.  Scheduler-
                    # independent, so computed once per (knobs, host size).
                    S = max(int(context_len * shared_frac), 1)
                    G = max(gen_tokens, 1)
                    tier_on = any(h > 0 for h in host_pool_pages)
                    spill = {}
                    for h in host_pool_pages:
                        if not tier_on:
                            continue
                        dec = min(batch_size, tb)
                        if h > 0:
                            # each replayed request promotes its S/ps spilled
                            # pages once over its G decode ticks, overlapped
                            rp = mixed_bound(
                                cfg, n_decode=dec, n_prefill=0,
                                context_len=context_len, hw=hw, page_size=ps,
                                kv_dtype=kvd, n_devices=ndev,
                                promoted_pages=dec * max(S // ps, 1) / G)
                            spill[h] = dec / (G * max(rp["tick_s"], 1e-30))
                        else:
                            # both tiers miss: before its G decode ticks the
                            # slot re-prefills its spilled prefix at the
                            # per-slot chunk rate (the leftover budget split
                            # across the replaying slots caps the chunk —
                            # exactly the engine's pack bound)
                            chunk_eff = max(
                                min(pc, max(tb - dec, 0) // max(dec, 1)), 1)
                            prefill_ticks = -(-S // chunk_eff)
                            spill[h] = dec / ((prefill_ticks + G)
                                              * blend_tick_s)
                    # speculative axis: accepted-token goodput on repetitive
                    # decode-heavy traffic.  Scheduler-independent (the
                    # drafter rides on top of any ordering policy), so
                    # computed once per (knobs, k).
                    spec_on = any(k > 0 for k in spec_ks)
                    spec = {}
                    for sk in spec_ks:
                        if not spec_on:
                            continue
                        dec = min(batch_size, tb)
                        # drafts pack only in the budget left after every
                        # decoding slot's base token — the engine's strict
                        # decode-first priority caps k per slot
                        k_eff = min(int(sk), max(tb - dec, 0) // max(dec, 1))
                        rs = mixed_bound(
                            cfg, n_decode=dec, n_prefill=0,
                            context_len=context_len, hw=hw, page_size=ps,
                            kv_dtype=kvd, n_devices=ndev,
                            draft_tokens=float(k_eff),
                            accept_rate=spec_accept_rate if k_eff else 0.0)
                        spec[sk] = rs["tokens_per_s"]
                    for sched, h, sk in ((s, h, sk) for s in schedulers
                                         for h in host_pool_pages
                                         for sk in spec_ks):
                        model = SCHEDULER_MODEL[sched]
                        hit = shared_frac * model["residency"]
                        # pack tokens a warm-family request still costs vs
                        # the full cold S+G — the scheduler's reuse leverage
                        crit = dict(tps)
                        crit["warm@families"] = (
                            blend_tps * (S + G) / ((1.0 - hit) * S + G))
                        # ONE document occupies one slot and prefills at
                        # most prefill_chunk tokens per tick (the leftover
                        # budget caps it too) — not chunk x batch_size
                        prefill_ticks = -(-context_len // max(
                            min(pc, tb - 1), 1))
                        crit["interactive@arrival"] = 1.0 / (
                            blend_tick_s
                            * (1 + model["interactive_wait"] * prefill_ticks))
                        if tier_on:
                            crit["spill@replay"] = spill[h]
                        if spec_on:
                            crit["spec@repetitive"] = spec[sk]
                        rows.append({"token_budget": tb, "prefill_chunk": pc,
                                     "page_size": ps, "kv_dtype": kvd,
                                     "scheduler": sched, "n_devices": ndev,
                                     "host_pool_pages": h, "spec_k": sk,
                                     "criteria": crit})
    if not rows:
        raise ValueError("no valid (token_budget, prefill_chunk, page_size, "
                         "kv_dtype, scheduler) candidate for the given grids")
    peak = {name: max(r["criteria"][name] for r in rows)
            for name in rows[0]["criteria"]}
    for r in rows:
        frac = {name: r["criteria"][name] / max(peak[name], 1e-30)
                for name in r["criteria"]}
        r["fraction_of_peak"] = frac
        r["score"] = min(frac.values())
        r["mean_fraction"] = sum(frac.values()) / len(frac)
    best = max(rows, key=lambda r: (r["score"], r["mean_fraction"]))
    return {"best": {k: best[k] for k in ("token_budget", "prefill_chunk",
                                          "page_size", "kv_dtype",
                                          "scheduler", "n_devices",
                                          "host_pool_pages", "spec_k",
                                          "score", "mean_fraction")},
            "table": rows}
