"""Configuration autotuner — the systems operator's role in the paper,
automated: pick {memory mode, attention path, MoE impl, microbatching} per
(arch × shape) by lowering candidates and comparing roofline terms.

The paper's conclusion ("set KMP_AFFINITY/taskset/all2all-cache once,
system-wide, and every user's Nproc×Nthread choice stays near peak") maps to
``select_defaults``: sweep candidates on the production mesh, score by the
dominant roofline term, and emit the winning config — recorded in
EXPERIMENTS.md §Perf as the tuned default.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.roofline import V5E, HwSpec, roofline_terms


@dataclass(frozen=True)
class Candidate:
    name: str
    overrides: Dict = field(default_factory=dict)  # ModelCfg.replace kwargs
    microbatches: Optional[int] = None


DEFAULT_CANDIDATES = (
    Candidate("baseline", {}),
    Candidate("remat-dots", {"remat": "dots"}),
    Candidate("remat-none", {"remat": "none"}),
    Candidate("flash-attn", {"use_flash": True}),
    Candidate("q-chunk-512", {"attn_q_chunk": 512}),
)


def evaluate(arch: str, shape_name: str, mesh, candidates=DEFAULT_CANDIDATES,
             hw: HwSpec = V5E, hbm_limit: float = 16 * 2**30) -> List[Dict]:
    """Lower every candidate; return scored rows sorted by step-time bound."""
    from repro.launch.dryrun import lower_cell

    rows = []
    for cand in candidates:
        try:
            res = lower_cell(arch, shape_name, mesh, overrides=cand.overrides)
        except Exception as e:  # candidate may be invalid for this arch
            rows.append({"candidate": cand.name, "error": repr(e)[:200]})
            continue
        terms = roofline_terms(res, hw)
        rows.append({
            "candidate": cand.name,
            "fits_hbm": res["analytic_hbm_bytes"] <= hbm_limit * 0.9,
            "step_bound_s": terms["step_time_lower_bound_s"],
            "dominant": terms["dominant"],
            "roofline_fraction": terms["roofline_fraction"],
            **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        })
    ok = [r for r in rows if r.get("fits_hbm")]
    ranked = sorted(ok or [r for r in rows if "error" not in r],
                    key=lambda r: r["step_bound_s"])
    for i, r in enumerate(ranked):
        r["rank"] = i
    return rows


def select_defaults(arch: str, shape_name: str, mesh, **kw) -> Dict:
    rows = evaluate(arch, shape_name, mesh, **kw)
    best = min((r for r in rows if "error" not in r),
               key=lambda r: (not r.get("fits_hbm", False), r["step_bound_s"]))
    return {"best": best, "table": rows}
