"""The paper's experiment, on a TPU pod: matrix-multiply throughput swept
over every factorization of the chip count into (Nproc × Nthread) at
constant total memory.

Mapping (DESIGN.md §2):
  Nproc   -> data-parallel replicas (independent matmul instances)
  Nthread -> model-parallel width inside one instance (how many chips one
             ``C = A*B`` spreads over — OpenMP threads inside one BLAS call)
  N = N0/√Nproc  -> identical protocol: constant total bytes across sweep
  memory modes   -> placement (how B/C hash over the TP group: colsplit /
                    inner / 2d ≈ all2all / hemisphere / quadrant) ×
                    near-memory policy (cache = single-pass accumulate,
                    hybrid = 2 K-passes, flat = 8 K-passes)

Each cell is lowered + compiled on the fake-device mesh and scored by the
three-term roofline (core/roofline.py) — the analytic analogue of the
paper's GFLOPs plots in Figs. 4/5.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import hlo_cost
from repro.core.roofline import HwSpec, V5E

PLACEMENTS = ("colsplit", "inner", "2d")
MEMORIES = {"cache": 1, "hybrid": 2, "flat": 8}


@dataclass(frozen=True)
class SweepCell:
    nproc: int  # data-parallel replicas
    nthread: int  # model-parallel width per replica
    placement: str = "colsplit"
    memory: str = "cache"
    n0: int = 98304  # N = n0/√nproc (constant total bytes, paper protocol)
    dtype: str = "bfloat16"

    @property
    def n(self) -> int:
        return max(256, int(round(self.n0 / math.sqrt(self.nproc) / 256)) * 256)


def factorizations(n_units: int) -> List:
    """All power-of-two (Nproc, Nthread) splits of a pod (1×256 … 256×1)."""
    out = []
    p = 1
    while p <= n_units:
        out.append((p, n_units // p))
        p *= 2
    return out


def _mesh_for(cell: SweepCell) -> Mesh:
    n = cell.nproc * cell.nthread
    devs = np.asarray(jax.devices()[:n])
    if cell.placement == "2d" and cell.nthread > 1:
        m1 = 2 ** (int(math.log2(cell.nthread)) // 2)
        m2 = cell.nthread // m1
        return Mesh(devs.reshape(cell.nproc, m1, m2), ("data", "mrow", "mcol"))
    return Mesh(devs.reshape(cell.nproc, cell.nthread), ("data", "model"))


def _matmul_fn(cell: SweepCell, k_splits: int):
    def f(a, b):
        if k_splits == 1:
            return jnp.einsum("pij,pjk->pik", a, b)
        # K-split accumulation: C revisited per pass ("flat"/"hybrid" modes)
        chunks = jnp.split(a, k_splits, axis=2)
        bchunks = jnp.split(b, k_splits, axis=1)
        acc = jnp.zeros((a.shape[0], a.shape[1], b.shape[2]), jnp.float32)
        for ac, bc in zip(chunks, bchunks):
            acc = acc + jnp.einsum("pij,pjk->pik", ac, bc,
                                   preferred_element_type=jnp.float32)
        return acc.astype(a.dtype)

    return f


def _shardings(cell: SweepCell, mesh: Mesh):
    if cell.placement == "colsplit":
        a = P("data", None, None)  # A replicated over the TP group
        b = P("data", None, "model")
        c = P("data", None, "model")
    elif cell.placement == "inner":
        a = P("data", None, "model")  # contraction sharded -> all-reduce
        b = P("data", "model", None)
        c = P("data", None, None)
    else:  # 2d
        a = P("data", "mrow", None)
        b = P("data", None, "mcol")
        c = P("data", "mrow", "mcol")
    return tuple(NamedSharding(mesh, s) for s in (a, b, c))


def lower_cell(cell: SweepCell) -> Dict:
    """Lower + compile one sweep cell; return roofline terms per device."""
    mesh = _mesh_for(cell)
    N = cell.n
    dt = jnp.dtype(cell.dtype)
    a = jax.ShapeDtypeStruct((cell.nproc, N, N), dt)
    b = jax.ShapeDtypeStruct((cell.nproc, N, N), dt)
    sa, sb, sc = _shardings(cell, mesh)
    fn = _matmul_fn(cell, MEMORIES[cell.memory])
    with mesh:
        compiled = jax.jit(fn, in_shardings=(sa, sb),
                           out_shardings=sc).lower(a, b).compile()
    walked = hlo_cost.analyze(compiled.as_text())
    mem = compiled.memory_analysis()
    n_dev = mesh.devices.size
    return {
        "nproc": cell.nproc, "nthread": cell.nthread,
        "placement": cell.placement, "memory": cell.memory, "N": N,
        "flops_per_device": walked["flops"],
        "bytes_per_device": walked["traffic_bytes"],
        "collective_bytes_per_device": walked["collective_bytes"],
        "peak_bytes": (mem.argument_size_in_bytes + mem.output_size_in_bytes
                       + mem.temp_size_in_bytes - mem.alias_size_in_bytes),
        "model_flops": 2.0 * cell.nproc * N ** 3,
        "n_devices": n_dev,
    }


def score(row: Dict, hw: HwSpec = V5E) -> Dict:
    """Paper-style efficiency: useful GF/s/chip vs practical peak."""
    t_comp = row["flops_per_device"] / hw.peak_flops
    t_mem = row["bytes_per_device"] / hw.hbm_bw
    t_coll = row["collective_bytes_per_device"] / hw.ici_bw
    t = max(t_comp, t_mem, t_coll, 1e-30)
    useful = row["model_flops"] / row["n_devices"]
    eff = useful / (t * hw.peak_flops)
    return {**row, "compute_s": t_comp, "memory_s": t_mem,
            "collective_s": t_coll,
            "dominant": max([("compute", t_comp), ("memory", t_mem),
                             ("collective", t_coll)], key=lambda kv: kv[1])[0],
            "peak_fraction": min(eff, 1.0),
            "gflops_per_chip": useful / t / 1e9}


def run_sweep(n_units: int = 256, placements=PLACEMENTS,
              memories=tuple(MEMORIES), n0: int = 98304,
              splits: Optional[List] = None) -> List[Dict]:
    rows = []
    for nproc, nthread in (splits or factorizations(n_units)):
        for pl_ in placements:
            if pl_ == "2d" and nthread < 4:
                continue
            for mem in memories:
                cell = SweepCell(nproc, nthread, pl_, mem, n0=n0)
                rows.append(score(lower_cell(cell)))
    return rows


# ---------------------------------------------------------------------------
# Measured mode (CPU wall clock — the benchmark harness entry)


def measured_gflops(engine: str, nproc: int, n0: int = 2048, reps: int = 3,
                    dtype=jnp.float32) -> Dict:
    """Single-host measured analogue of Figs. 4/5: per-'process' matrix
    N = n0/√nproc, batched matmul, wall-clock GFLOP/s.  engine: xla|pallas."""
    import time

    N = max(64, int(round(n0 / math.sqrt(nproc) / 64)) * 64)
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (nproc, N, N), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (nproc, N, N), dtype)
    if engine == "xla":
        f = jax.jit(lambda a, b: jnp.einsum("pij,pjk->pik", a, b))
    else:
        from repro.kernels import ops

        def f(a, b):
            return jnp.stack([ops.matmul(a[i], b[i], block=(256, 256, 256))
                              for i in range(a.shape[0])])
    out = f(a, b)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = f(a, b)
    jax.block_until_ready(out)
    dt_s = (time.perf_counter() - t0) / reps
    gf = 2.0 * nproc * N ** 3 / dt_s / 1e9
    return {"engine": engine, "nproc": nproc, "N": N,
            "us_per_call": dt_s * 1e6, "gflops": gf}
