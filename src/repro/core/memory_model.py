"""Analytic per-device HBM model for each (arch × shape × mesh) cell.

Why this exists: the dry-run's ``memory_analysis()`` runs against the CPU
backend, which materializes an f32 copy of every bf16 weight operand at each
dot (no native bf16 GEMM).  On jamba-398b that alone is 84 × 805 MB of
"temp" — an artifact with no TPU equivalent (MXU consumes bf16 directly).
This model computes what a TPU actually has to hold:

  params + optimizer state + gradient/accum buffer
  + saved remat boundaries (seq-sharded, see transformer.stage_fwd)
  + logits block + one block's transient working set
  (decode: params + KV cache/recurrent state + small step buffers)

Both numbers are reported in EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg
from repro.configs import param_count


def _dtype_size(name: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[name]


def estimate(cfg: ModelCfg, shape: ShapeCfg, mesh_shape: Dict[str, int],
             microbatches: int = 1, opt_int8: bool = None) -> Dict[str, float]:
    n_dev = int(np.prod(list(mesh_shape.values())))
    data = int(np.prod([v for k, v in mesh_shape.items() if k in ("pod", "data")]))
    model = mesh_shape.get("model", 1)
    P = param_count(cfg)
    psz = _dtype_size(cfg.param_dtype)
    if opt_int8 is None:
        opt_int8 = P > 50e9

    params_b = P * psz / n_dev
    opt_b = (2 * P / n_dev) if opt_int8 else (8 * P / n_dev)
    grads_b = P * psz / n_dev  # accum buffer (microbatched) or transient

    d = cfg.d_model
    out: Dict[str, float] = {"params": params_b, "opt_state": opt_b}

    if shape.kind == "decode":
        kv = 0.0
        state = 0.0
        for st in cfg.stages:
            for blk in st.pattern:
                if blk.mixer == "attn":
                    a = blk.attn
                    cap = min(shape.seq_len, a.window or shape.seq_len)
                    kv += (st.repeats * 2 * shape.global_batch * cap
                           * a.num_kv_heads * a.head_dim * 2)
                elif blk.mixer == "mamba":
                    d_in = blk.mamba.expand * d
                    state += st.repeats * shape.global_batch * d_in * (
                        blk.mamba.d_state * 4 + (blk.mamba.d_conv - 1) * 2)
                elif blk.mixer == "mlstm":
                    d_in = int(blk.xlstm.proj_factor * d)
                    hd = d_in // blk.xlstm.num_heads
                    state += st.repeats * shape.global_batch * (
                        blk.xlstm.num_heads * hd * hd * 4 + 3 * d_in * 2)
                elif blk.mixer == "slstm":
                    state += st.repeats * shape.global_batch * 4 * d * 4
        out["kv_cache"] = kv / n_dev  # sharded over batch(+seq for long ctx)
        out["recurrent_state"] = state / max(data, 1)
        out["step_buffers"] = shape.global_batch * d * 2 * 8 / max(data, 1)
        out.pop("opt_state")
        out["total"] = sum(out.values())
        return out

    # train / prefill
    B_mb = shape.global_batch // microbatches
    tok_local = B_mb * shape.seq_len / data
    n_groups = sum(st.repeats for st in cfg.stages)
    max_pattern = max(len(st.pattern) for st in cfg.stages)
    boundary = tok_local * d * 2 / model  # seq-sharded saved carry
    boundaries_b = boundary * (n_groups + max_pattern)

    # largest single-block live set during backward (bf16 activations)
    per_tok = 0
    for st in cfg.stages:
        for blk in st.pattern:
            t = 0
            if blk.mixer in ("attn", "cross_attn"):
                a = blk.attn
                t += 3 * a.num_heads * a.head_dim * 2  # q,k,v (gathered)
                t += a.num_heads * a.head_dim * 2  # out
            elif blk.mixer == "mamba":
                d_in = blk.mamba.expand * d
                t += 2 * 2 * d_in * 2 + 2 * d_in * 2  # xz, x_c, dt (bf16)
                t += d_in * 4  # f32 recurrence slice amortized
            elif blk.mixer in ("mlstm", "slstm"):
                d_in = int(blk.xlstm.proj_factor * d)
                t += (2 * d_in + 3 * d_in) * 2 + d_in * 4
            if blk.ffn == "mlp":
                t += 3 * blk.mlp.d_ff * 2 / model
            elif blk.ffn == "moe":
                mo = blk.moe
                cf = mo.capacity_factor * mo.top_k
                t += cf * (2 * d + 2 * mo.d_ff) * 2 / model  # dispatched acts
                t += 2 * cf * 2 * 2  # dispatch/combine one-hots (E·C ≈ cf·S)
            per_tok = max(per_tok, t)
    transient_b = tok_local * per_tok * 2.5  # fwd+bwd live-set factor

    logits_b = 3 * tok_local * cfg.vocab_size * 2 / model  # bf16+f32 slices

    out.update({"grads": grads_b, "remat_boundaries": boundaries_b,
                "block_transient": transient_b, "logits": logits_b})
    out["total"] = sum(out.values())
    return out


def fits_hbm(total_bytes: float, hbm_bytes: float = 16 * 2**30,
             headroom: float = 0.9) -> bool:
    return total_bytes <= hbm_bytes * headroom


# ---------------------------------------------------------------------------
# Analytic HBM traffic (the roofline memory term)
#
# The HLO-walked traffic proxy counts every materialized buffer × loop trips,
# which (a) includes the CPU backend's f32 weight-conversion copies and
# (b) counts Pallas-interpret VMEM traffic as HBM.  A TPU's actual HBM
# traffic is weights-read + activation flow; this model computes that.


def _block_act_bytes_per_token(cfg: ModelCfg, blk, model: int) -> float:
    """bf16 bytes of activations materialized per token in one block
    (inputs/outputs of the matmuls; model-sharded dims divided by `model`)."""
    d = cfg.d_model
    t = 2 * d * 2  # residual in/out
    if blk.mixer in ("attn", "cross_attn"):
        a = blk.attn
        t += (a.num_heads + 2 * a.num_kv_heads) * a.head_dim * 2  # q,k,v
        t += a.num_heads * a.head_dim * 2  # attn out
    elif blk.mixer == "mamba":
        d_in = blk.mamba.expand * d
        t += (2 * d_in + 3 * d_in) * 2 / model + d_in * 4 / model
    elif blk.mixer in ("mlstm", "slstm"):
        d_in = int(blk.xlstm.proj_factor * d)
        t += 6 * d_in * 2 / model
    if blk.ffn == "mlp":
        t += 3 * blk.mlp.d_ff * 2 / model
    elif blk.ffn == "moe":
        mo = blk.moe
        t += mo.top_k * mo.capacity_factor * (2 * d + 3 * mo.d_ff / model) * 2
    return t


def analytic_traffic(cfg: ModelCfg, shape: ShapeCfg,
                     mesh_shape: Dict[str, int], microbatches: int = 1) -> float:
    """Per-device HBM bytes per step (weights + activations + logits)."""
    n_dev = int(np.prod(list(mesh_shape.values())))
    data = int(np.prod([v for k, v in mesh_shape.items() if k in ("pod", "data")]))
    model = mesh_shape.get("model", 1)
    P = param_count(cfg)
    psz = _dtype_size(cfg.param_dtype)

    if shape.kind == "decode":
        # weight-stationary: each device reads its own param shard once per
        # token; KV cache read once; states rewritten
        from repro.configs import SHAPES_BY_NAME  # noqa

        kv = estimate(cfg, shape, mesh_shape)
        return P * psz / n_dev + kv.get("kv_cache", 0.0) + kv.get(
            "recurrent_state", 0.0)

    tok_local = shape.global_batch * shape.seq_len / data
    passes = {"none": 2.0, "dots": 2.5, "full": 3.0}[cfg.remat]
    # ZeRO-3: the full model-shard of weights is (re)gathered and read per
    # microbatch for forward, recompute, and backward-transpose
    weights = passes * microbatches * P * psz / model
    acts = 0.0
    for st in cfg.stages:
        for blk in st.pattern:
            acts += st.repeats * _block_act_bytes_per_token(cfg, blk, model)
    acts *= tok_local * passes
    logits = tok_local * cfg.vocab_size / model * (2 + 4 + 4)  # bf16+f32+grad
    if shape.kind == "prefill":
        weights = P * psz / model
        acts /= passes
        logits = tok_local * cfg.vocab_size / model * 2
    return weights + acts + logits
