"""Loop-aware cost extraction from compiled (post-SPMD) HLO text.

XLA's ``compiled.cost_analysis()`` on the CPU backend counts while-loop
bodies ONCE, so any scanned program (layer stacks, microbatching, chunked
attention) is undercounted by its trip counts.  This walker parses the HLO
module text, recovers trip counts from loop conditions, and accumulates

  - dot FLOPs           (exact: 2 · |result| · K per dot, × trips)
  - collective bytes    (result bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute)
  - traffic proxy bytes (result bytes of materializing ops — an HBM-traffic
                         estimate; fusion-internal reuse not modelled)

All numbers are PER DEVICE: the input is the partitioned module, so
replication redundancy (e.g. attention replicated across the TP axis) is
visible — which is exactly what the roofline analysis needs to expose.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "c64": 8, "c128": 16}
_SHAPE_RE = re.compile(
    r"\b(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_CALL_ATTR_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRUE_FALSE_RE = re.compile(r"(?:true_computation|false_computation)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
        out.append((dt, shape))
    return out


def _bytes_of(text: str) -> float:
    return sum(_DTYPE_BYTES[dt] * _prod(s) for dt, s in _shapes(text))


def _prod(shape) -> int:
    n = 1
    for d in shape:
        n *= d
    return n


@dataclass
class Computation:
    name: str
    lines: List[str] = field(default_factory=list)
    params: Dict[str, Tuple[str, Tuple[int, ...]]] = field(default_factory=dict)


@dataclass
class Cost:
    flops: float = 0.0
    collective_bytes: float = 0.0
    traffic_bytes: float = 0.0
    collective_by_type: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.collective_bytes += o.collective_bytes
        self.traffic_bytes += o.traffic_bytes
        for k, v in o.collective_by_type.items():
            self.collective_by_type[k] = self.collective_by_type.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.collective_bytes * m,
                    self.traffic_bytes * m,
                    {k: v * m for k, v in self.collective_by_type.items()})


class HloCostModel:
    def __init__(self, hlo_text: str):
        self.comps = _parse_computations(hlo_text)
        self.entry = next((n for n in self.comps if n.startswith("main")
                           or "ENTRY" in self.comps[n].lines[0]), None)
        if self.entry is None:  # fall back: computation named in ENTRY line
            for n, c in self.comps.items():
                if c.lines and c.lines[0].lstrip().startswith("ENTRY"):
                    self.entry = n
                    break
        if self.entry is None:
            self.entry = list(self.comps)[0]
        self._memo: Dict[str, Cost] = {}

    # -- public -----------------------------------------------------------
    def cost(self) -> Cost:
        return self._comp_cost(self.entry)

    # -- internals ----------------------------------------------------------
    def _comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        comp = self.comps.get(name)
        total = Cost()
        if comp is None:
            return total
        self._memo[name] = total  # break cycles defensively
        symtab = dict(comp.params)
        for raw in comp.lines[1:]:
            m = _DEF_RE.match(raw)
            if not m:
                continue
            var, rhs = m.groups()
            res_end = _op_start(rhs)
            res_text = rhs[:res_end]
            shp = _shapes(res_text)
            if shp:
                symtab[var] = shp[0] if len(shp) == 1 else ("tuple", None)
                # keep all tuple element shapes for gte? coarse: store text
                symtab[var + "!full"] = res_text  # for tuple byte sums
            body = rhs[res_end:]
            total += self._op_cost(body, res_text, symtab)
        self._memo[name] = total
        return total

    def _op_cost(self, body: str, res_text: str, symtab) -> Cost:
        c = Cost()
        op = body.split("(", 1)[0].strip().split()[-1] if "(" in body else body.strip()
        res_bytes = _bytes_of(res_text)

        if op == "while":
            names = dict(
                (k, v) for k, v in re.findall(r"(condition|body)=%?([\w.\-]+)", body))
            # XLA's loop analysis stamps the resolved trip count into
            # backend_config — trust it first; fall back to scraping the
            # largest constant out of the condition computation.
            tc = re.search(r'"known_trip_count"\s*:\s*\{"n"\s*:\s*"?(\d+)', body)
            trips = float(tc.group(1)) if tc else self._trip_count(
                names.get("condition"))
            inner = self._comp_cost(names.get("body", ""))
            c += inner.scaled(trips)
            c.traffic_bytes += res_bytes
            return c
        if op == "fusion" or op == "call":
            mm = _CALL_ATTR_RE.search(body)
            if mm:
                c += self._comp_cost(mm.group(1))
            c.traffic_bytes += res_bytes
            return c
        if op == "conditional":
            branches = _BRANCH_RE.search(body)
            names = (branches.group(1).replace("%", "").split(", ")
                     if branches else _TRUE_FALSE_RE.findall(body))
            sub = [self._comp_cost(n.strip()) for n in names if n.strip()]
            if sub:
                # worst-case branch
                c += max(sub, key=lambda x: x.flops + x.collective_bytes)
            return c
        for coll in _COLLECTIVES:
            if op == coll or op == coll + "-start":
                c.collective_bytes += res_bytes
                c.traffic_bytes += res_bytes
                c.collective_by_type[coll] = (
                    c.collective_by_type.get(coll, 0.0) + res_bytes)
                return c
        if op in ("dot", "convolution"):
            c.flops += self._dot_flops(body, res_text, symtab)
            c.traffic_bytes += res_bytes
            return c
        if op in ("copy", "transpose", "reshape", "broadcast", "dynamic-slice",
                  "dynamic-update-slice", "slice", "concatenate", "reduce",
                  "scatter", "gather", "add", "multiply", "select", "exponential"):
            c.traffic_bytes += res_bytes
        return c

    def _dot_flops(self, body: str, res_text: str, symtab) -> float:
        res = _shapes(res_text)
        out_elems = _prod(res[0][1]) if res else 0
        k = 1
        mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", body)
        if mm:
            lhs = None
            # Operand group of the dot: text between the first "(...)".
            # Operands are usually fully typed ("f32[4,32]{1,0} %arg"), so
            # the lhs shape is simply the FIRST shape literal in the group —
            # splitting on "," would break on the layout annotation's commas.
            operands = re.findall(r"\(([^)]*)\)", body)
            if operands:
                shp = _shapes(operands[0])
                if shp:
                    lhs = shp[0]
                else:  # untyped operands: "dot(%a, %b)" — fall back to symtab
                    nm = re.match(r"\s*%?([\w.\-]+)", operands[0])
                    if nm:
                        lhs = symtab.get(nm.group(1))
            if lhs and lhs[1] is not None:
                for d in mm.group(1).split(","):
                    if d:
                        k *= lhs[1][int(d)] if int(d) < len(lhs[1]) else 1
        return 2.0 * out_elems * k

    def _trip_count(self, cond_name: Optional[str]) -> float:
        comp = self.comps.get(cond_name or "")
        if comp is None:
            return 1.0
        consts = [int(x) for line in comp.lines for x in _CONST_RE.findall(line)]
        # also search fusions called from the condition
        for line in comp.lines:
            mm = _CALL_ATTR_RE.search(line)
            if mm and mm.group(1) in self.comps:
                consts += [int(x) for l2 in self.comps[mm.group(1)].lines
                           for x in _CONST_RE.findall(l2)]
        return float(max(consts)) if consts else 1.0


def _op_start(rhs: str) -> int:
    """Index where the op name starts (after the result type)."""
    depth = 0
    i = 0
    n = len(rhs)
    while i < n:
        ch = rhs[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif depth == 0 and ch == " ":
            # result type ends at the first space at depth 0 (after optional
            # tuple parens and the layout annotation)
            rest = rhs[i + 1:]
            if not rest.startswith(("{", "(")):  # not a layout continuation
                return i + 1
        i += 1
    return 0


def _parse_computations(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                cur.lines.append(line)
                for pm in re.finditer(r"%?([\w.\-]+):\s*([^,)]+)", m.group(2)):
                    shp = _shapes(pm.group(2))
                    if shp:
                        cur.params[pm.group(1)] = shp[0]
        else:
            cur.lines.append(line)
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
    return comps


def analyze(hlo_text: str) -> Dict[str, float]:
    c = HloCostModel(hlo_text).cost()
    return {
        "flops": c.flops,
        "collective_bytes": c.collective_bytes,
        "traffic_bytes": c.traffic_bytes,
        **{f"coll_{k}": v for k, v in c.collective_by_type.items()},
    }
