from repro.data.pipeline import SyntheticLMData, make_data  # noqa: F401
