"""Deterministic synthetic data pipeline.

``batch_at(step)`` is a pure function of (seed, step): restart/resume lands
on exactly the batch it would have seen (no data replay after a failure),
and elastic rescale keeps the global batch identical across mesh changes.
A bounded background prefetcher overlaps host batch construction with
device compute.
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelCfg, ShapeCfg


class SyntheticLMData:
    """Markov-ish synthetic tokens (not uniform noise, so loss can fall)."""

    def __init__(self, cfg: ModelCfg, shape: ShapeCfg, seed: int = 0,
                 batch_override: Optional[int] = None):
        self.cfg = cfg
        self.seq = shape.seq_len
        self.batch = batch_override or shape.global_batch
        self.seed = seed

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) % 2**31)
        B, S, V = self.batch, self.seq, self.cfg.vocab_size
        # low-entropy stream: next token = (token + drift) mod V with noise
        start = rng.randint(0, V, size=(B, 1))
        drift = rng.randint(1, 7, size=(B, 1))
        idx = np.arange(S + 1)[None, :]
        toks = (start + drift * idx) % V
        noise = rng.rand(B, S + 1) < 0.05
        toks = np.where(noise, rng.randint(0, V, size=(B, S + 1)), toks)
        batch = {"tokens": toks[:, :S].astype(np.int32),
                 "labels": toks[:, 1 : S + 1].astype(np.int32)}
        if self.cfg.frontend == "audio":
            batch = {"feats": rng.randn(B, S, self.cfg.d_model // 2)
                     .astype(np.float32),
                     "labels": batch["labels"] % self.cfg.vocab_size}
        elif self.cfg.frontend == "vision":
            batch["img_feats"] = rng.randn(
                B, self.cfg.n_img_tokens, self.cfg.d_model // 2).astype(np.float32)
        return batch

    def iter_from(self, step: int, shardings=None, prefetch: int = 2
                  ) -> Iterator[Dict]:
        """Device-placed iterator with background prefetch."""
        q: "queue.Queue" = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            s = step
            while not stop.is_set():
                q.put(self.batch_at(s))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                host = q.get()
                if shardings is not None:
                    yield {k: jax.device_put(v, shardings[k])
                           for k, v in host.items()}
                else:
                    yield {k: jnp.asarray(v) for k, v in host.items()}
        finally:
            stop.set()


def make_data(cfg: ModelCfg, shape: ShapeCfg, seed: int = 0,
              batch_override: Optional[int] = None) -> SyntheticLMData:
    return SyntheticLMData(cfg, shape, seed, batch_override)
