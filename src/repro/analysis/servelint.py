"""servelint — repo-specific AST lint for the serving stack.

The serving engine's performance story rests on discipline the type system
cannot see: exactly one serve-path trace, page ids passed as DATA, no
wall-clock or RNG in the tick loop, no silently-swallowed failures.  These
rules encode that discipline statically, so a PR that breaks it fails CI
before a single tick runs.

Rules (scopes in parentheses; paths are relative to ``src/repro``):

- ``jit-outside-factory`` (all of src): a ``jax.jit(...)`` call anywhere but
  the registered factory sites (``JIT_FACTORY_SITES``) or a decorator
  position.  A stray jit in the tick path is a per-call retrace machine;
  new program factories must be registered here ON PURPOSE, which is the
  review hook.
- ``hot-nondeterminism`` (serve/, kernels/): ``np.random.*``, wall-clock
  ``time.*`` reads, or iteration over an unordered set in the serve/kernel
  hot paths.  Allowlisted: the two seeded ``default_rng((seed, ...))``
  sites in ``engine.py``/``chaos.py`` — tuple-keyed, deterministic by
  construction (the packing-invariant sampling and chaos-schedule
  contracts depend on exactly that form).  Order-insensitive reducers over
  sets (``sum``/``min``/``max``/``len``/``all``/``any``/``sorted``) pass.
- ``broad-except`` (all of src): bare ``except:`` or ``except Exception/
  BaseException``.  Intentional catch-alls (autotune candidate sweeps,
  dry-run cell loops) carry a reasoned inline suppression instead.
- ``mutable-default`` (all of src): mutable default arguments.
- ``retrace-bomb`` (serve/): a registered jitted program
  (``JITTED_PROGRAM_ATTRS``) invoked with a Python-scalar argument — an
  int/float literal, ``int()``/``float()``/``len()`` call, or arithmetic
  over those — which jit treats as a compile-time constant and retraces
  for every new value.  The page movers additionally require their page-id
  argument wrapped as array data (``np.int32(page)`` — the "page id as
  DATA" rule from ``serve_step.py``).

The analysis package itself is excluded from scanning (it builds jits and
transition tables as part of CHECKING them, not serving).
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import List, Optional, Set, Tuple

from repro.analysis.findings import Finding, Suppressions

__all__ = ["lint_file", "lint_tree", "JIT_FACTORY_SITES",
           "JITTED_PROGRAM_ATTRS"]

# (path relative to src/repro, enclosing function name) pairs where calling
# jax.jit is legitimate: the engine/reference/train constructors (programs
# built once per object) and the offline lowering helpers.  Everything else
# is a finding — add a pair here deliberately when introducing a factory.
JIT_FACTORY_SITES: Set[Tuple[str, str]] = {
    ("serve/engine.py", "__init__"),
    ("serve/reference.py", "__init__"),
    ("train/loop.py", "__init__"),
    ("core/sweep.py", "lower_cell"),
    ("core/sweep.py", "measured_gflops"),
    ("launch/dryrun.py", "_lower_train"),
    ("launch/dryrun.py", "_lower_prefill"),
    ("launch/dryrun.py", "_lower_decode"),
}

# the engine's compiled-program attributes: calls to these are the jitted
# hot path, so their arguments must be arrays (or pytrees of arrays), never
# fresh Python scalars
JITTED_PROGRAM_ATTRS: Set[str] = {
    "_ragged_step", "_chunk_step", "_decode_step", "_reset", "_copy",
    "_gather_page", "_insert_page", "_spec_rollback", "_decode",
}
# movers whose trailing page-id argument must be wrapped as array data
_PAGE_ARG_MOVERS = {"_gather_page", "_insert_page"}

_HOT_SCOPES = ("serve/", "kernels/")
_RNG_ALLOWLIST_FILES = {"serve/engine.py", "serve/chaos.py"}
_TIME_ATTRS = {"time", "perf_counter", "monotonic", "time_ns",
               "process_time"}
_ORDER_FREE_REDUCERS = {"sum", "len", "min", "max", "all", "any", "sorted",
                        "set", "frozenset", "sorted"}
_ARRAY_NAMESPACES = {"np", "jnp", "numpy"}


def _attr_chain(node: ast.AST) -> List[str]:
    """['np', 'random', 'default_rng'] for np.random.default_rng, else []"""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return parts[::-1]
    return []


def _is_py_scalar(node: ast.AST) -> bool:
    """Expression that jit would treat as a fresh Python scalar constant."""
    if isinstance(node, ast.Constant):
        return isinstance(node.value, (int, float)) \
            and not isinstance(node.value, bool)
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
        return node.func.id in ("int", "float", "len")
    if isinstance(node, ast.BinOp):
        return _is_py_scalar(node.left) or _is_py_scalar(node.right)
    if isinstance(node, ast.UnaryOp):
        return _is_py_scalar(node.operand)
    return False


def _is_set_expr(node: ast.AST) -> bool:
    if isinstance(node, ast.Set):
        return True
    return (isinstance(node, ast.Call) and isinstance(node.func, ast.Name)
            and node.func.id in ("set", "frozenset"))


class _Linter(ast.NodeVisitor):
    def __init__(self, rel: str, source: str):
        self.rel = rel  # path relative to src/repro, posix separators
        self.hot = rel.startswith(_HOT_SCOPES)
        self.in_serve = rel.startswith("serve/")
        self.findings: List[Finding] = []
        self._func_stack: List[str] = []
        self._decorator_nodes: Set[int] = set()
        self._parents: dict = {}
        tree = ast.parse(source)
        for parent in ast.walk(tree):
            for child in ast.iter_child_nodes(parent):
                self._parents[id(child)] = parent
        self.visit(tree)

    # -- plumbing ---------------------------------------------------------
    def _flag(self, rule: str, node: ast.AST, msg: str) -> None:
        self.findings.append(
            Finding(rule, f"src/repro/{self.rel}", node.lineno, msg))

    def _enclosing(self) -> str:
        return self._func_stack[-1] if self._func_stack else "<module>"

    # -- defs: factory scoping, decorators, mutable defaults --------------
    def _visit_def(self, node) -> None:
        for dec in node.decorator_list:
            for sub in ast.walk(dec):
                self._decorator_nodes.add(id(sub))
        args = node.args
        for default in list(args.defaults) + [
                d for d in args.kw_defaults if d is not None]:
            mutable = isinstance(default, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(default, ast.Call)
                and isinstance(default.func, ast.Name)
                and default.func.id in ("list", "dict", "set"))
            if mutable:
                self._flag("mutable-default", default,
                           f"mutable default argument in {node.name}() is "
                           "shared across calls; default to None and build "
                           "inside")
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def

    # -- except handlers ---------------------------------------------------
    def visit_ExceptHandler(self, node: ast.ExceptHandler) -> None:
        names = []
        if node.type is None:
            names = ["<bare>"]
        else:
            types = (node.type.elts if isinstance(node.type, ast.Tuple)
                     else [node.type])
            for t in types:
                chain = _attr_chain(t)
                if chain and chain[-1] in ("Exception", "BaseException"):
                    names.append(chain[-1])
        if names:
            self._flag("broad-except", node,
                       f"except {'/'.join(names)} swallows unrelated "
                       "failures; catch the specific exceptions (or "
                       "suppress with a reason if the catch-all is the "
                       "point)")
        self.generic_visit(node)

    # -- loops / comprehensions: unordered-set iteration -------------------
    def _check_set_iter(self, iter_node: ast.AST, holder: ast.AST) -> None:
        if not (self.hot and _is_set_expr(iter_node)):
            return
        # an order-insensitive reducer consuming the iteration is fine:
        # sum(1 for p in set(x) ...), sorted(set(x)), max({...})
        scan: Optional[ast.AST] = holder
        while scan is not None:
            parent = self._parents.get(id(scan))
            if isinstance(parent, ast.Call) and isinstance(
                    parent.func, ast.Name) \
                    and parent.func.id in _ORDER_FREE_REDUCERS:
                return
            if isinstance(parent, (ast.stmt, type(None))):
                break
            scan = parent
        self._flag("hot-nondeterminism", iter_node,
                   "iteration order over a set is unordered across runs; "
                   "sort first or reduce order-insensitively")

    def visit_For(self, node: ast.For) -> None:
        self._check_set_iter(node.iter, node)
        self.generic_visit(node)

    def _visit_comp(self, node) -> None:
        for gen in node.generators:
            self._check_set_iter(gen.iter, node)
        self.generic_visit(node)

    visit_ListComp = visit_SetComp = visit_DictComp = _visit_comp
    visit_GeneratorExp = _visit_comp

    # -- calls: jit factories, RNG/clock, retrace bombs --------------------
    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if chain == ["jax", "jit"] and id(node) not in self._decorator_nodes:
            site = (self.rel, self._enclosing())
            if site not in JIT_FACTORY_SITES:
                self._flag(
                    "jit-outside-factory", node,
                    f"jax.jit called in {self._enclosing()}() — programs "
                    "are built once at registered factory sites "
                    "(servelint.JIT_FACTORY_SITES); a jit in the run path "
                    "retraces per call")
        if self.hot:
            self._check_hot_call(node, chain)
        if self.in_serve:
            self._check_jitted_program_call(node)
        self.generic_visit(node)

    def _check_hot_call(self, node: ast.Call, chain: List[str]) -> None:
        if len(chain) >= 2 and chain[0] in ("np", "numpy") \
                and chain[1] == "random":
            allowed = (self.rel in _RNG_ALLOWLIST_FILES
                       and chain[-1] == "default_rng"
                       and len(node.args) == 1
                       and isinstance(node.args[0], ast.Tuple))
            if not allowed:
                self._flag("hot-nondeterminism", node,
                           f"{'.'.join(chain)} in a serve/kernel hot path; "
                           "only tuple-seeded default_rng((seed, ...)) in "
                           "engine.py/chaos.py is deterministic by "
                           "construction")
        elif len(chain) == 2 and chain[0] == "time" \
                and chain[1] in _TIME_ATTRS:
            self._flag("hot-nondeterminism", node,
                       f"time.{chain[1]}() in a serve/kernel hot path; "
                       "wall-clock reads must never influence control flow "
                       "(measurement-only uses carry a reasoned "
                       "suppression)")

    def _check_jitted_program_call(self, node: ast.Call) -> None:
        func = node.func
        if not (isinstance(func, ast.Attribute)
                and isinstance(func.value, ast.Name)
                and func.value.id == "self"
                and func.attr in JITTED_PROGRAM_ATTRS):
            return
        for i, arg in enumerate(node.args):
            if _is_py_scalar(arg):
                self._flag(
                    "retrace-bomb", arg,
                    f"self.{func.attr}(...) argument {i} is a Python "
                    "scalar: jit specializes on it and retraces per "
                    "value — pass array data (np.int32(x)) instead")
        if func.attr in _PAGE_ARG_MOVERS and node.args:
            page = node.args[-1]
            wrapped = (isinstance(page, ast.Call)
                       and bool(_attr_chain(page.func))
                       and _attr_chain(page.func)[0] in _ARRAY_NAMESPACES)
            if not wrapped:
                self._flag(
                    "retrace-bomb", page,
                    f"self.{func.attr}(...) page id must be passed as "
                    "DATA (np.int32(page)): a bare Python page id bakes "
                    "into the trace and compiles one program per page")


def lint_file(path: Path, rel: Optional[str] = None) -> List[Finding]:
    """Lint one file.  ``rel`` overrides the src/repro-relative path used
    for scoping (tests point fixture files at serve/-scoped rules)."""
    source = Path(path).read_text()
    if rel is None:
        parts = Path(path).resolve().parts
        idx = len(parts) - 1 - parts[::-1].index("repro")
        rel = "/".join(parts[idx + 1:])
    findings = _Linter(rel, source).findings
    sup = Suppressions(source)
    return [sup.apply(f) for f in findings]


def lint_tree(root: Optional[Path] = None) -> List[Finding]:
    """Lint every module under ``src/repro`` (the analysis package and its
    fixtures excluded — it constructs jits and broken tables on purpose)."""
    root = Path(root) if root else Path(__file__).resolve().parents[1]
    findings: List[Finding] = []
    for p in sorted(root.rglob("*.py")):
        rel = p.relative_to(root).as_posix()
        if rel.startswith("analysis/"):
            continue
        findings.extend(lint_file(p, rel))
    return findings
