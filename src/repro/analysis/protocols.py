"""Scheduler protocol conformance — structural checks over the registry.

The engine trusts every policy in ``serve.scheduler.SCHEDULERS`` to honor
the ``Scheduler`` protocol's typing that the language can't express:

- ``admission_order`` returns UNIQUE indices into ``view.queue`` (a
  permutation prefix — omitted indices wait, duplicates would double-admit);
- ``decode_order`` / ``prefill_order`` return a PERMUTATION of the slot
  list the engine computed — reordering decides priority within the pack,
  never whether a slot packs at all (the engine's per-tick liveness
  invariant);
- ``preempt_order`` returns a SUBSEQUENCE-with-reorder of the candidate
  victims (a policy may exempt slots, never invent them);
- a WRAPPER policy (anything carrying an ``inner`` scheduler, today
  ``SpeculativeScheduler``) must delegate all four orderings to ``inner``
  VERBATIM — a wrapper that edits an ordering silently forks the wrapped
  policy's fairness/SLO guarantees.  This one is checked on the AST: each
  ordering method's body must be a single ``return self.inner.<same
  method>(<same arguments>)``.

These run against SYNTHETIC ``EngineView`` snapshots (mixed priorities,
shared prefixes, empty and deep queues, repeat consultations to exercise
the bounded-reorder bookkeeping), so the pass costs milliseconds and no
model is built.
"""
from __future__ import annotations

import ast
import inspect
from pathlib import Path
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.analysis.findings import Finding

_RULE = "scheduler-protocol"
_ORDERINGS = ("admission_order", "decode_order", "prefill_order",
              "preempt_order")

__all__ = ["check_protocols"]


def _sched_path() -> Tuple[str, Path]:
    import repro.serve.scheduler as S
    p = Path(S.__file__).resolve()
    try:
        rel = str(p.relative_to(Path(__file__).resolve().parents[3]))
    except ValueError:
        rel = "src/repro/serve/scheduler.py"
    return rel, p


def _mk_request(uid: int, prompt, priority: int = 0):
    from repro.serve.handle import Request
    return Request(uid=uid, prompt=np.asarray(prompt, dtype=np.int32),
                   max_tokens=8, priority=priority)


def _views() -> List:
    """Synthetic snapshots spanning the shapes policies branch on."""
    from repro.serve.scheduler import EngineView
    P = 4  # page_size: prompts of len >= 4 form prefix families

    def warm(prompt) -> int:
        # first family (prefix [1,2,3,4]) is "warm", everything else cold
        p = np.asarray(prompt).ravel()
        return P if p.size >= P and list(p[:P]) == [1, 2, 3, 4] else 0

    def split(prompt) -> Tuple[int, int]:
        w = warm(prompt)
        return (w, 0) if w else (0, 0)

    shared = [1, 2, 3, 4]
    queues = [
        (),  # empty
        tuple(_mk_request(i, shared + [i], priority=i % 2)
              for i in range(6)),  # two families' worth, mixed classes
        tuple(_mk_request(10 + i, [9, 9] if i % 2 else shared + [7, i],
                          priority=0) for i in range(5)),  # sub-page solos
        tuple(_mk_request(20 + i, [5 + i] * (P + i), priority=2 - (i % 3))
              for i in range(9)),  # deep, three classes, all cold
    ]
    slot_reqs = (
        _mk_request(100, shared, priority=1),
        _mk_request(101, [7] * 6, priority=0),
        None,
        _mk_request(103, [8] * 5, priority=0),
    )
    views = []
    for q in queues:
        for ms in (None, split):
            views.append(EngineView(
                queue=q, slot_requests=slot_reqs,
                slot_fill=(4, 6, 0, 2), budget=16, chunk=8, page_size=P,
                match_len=warm, match_split=ms))
    return views


def _check_instance(name: str, sched, rel: str, line: int) -> List[Finding]:
    out: List[Finding] = []

    def bad(msg: str) -> None:
        out.append(Finding(_RULE, rel, line, f"{name}: {msg}"))

    views = _views()
    slots_with_req = [0, 1, 3]  # slot 2 is free (None) in every view
    for repeat in range(3):  # re-consult: bounded-reorder state paths
        for v in views:
            adm = list(sched.admission_order(v))
            if len(set(adm)) != len(adm):
                bad(f"admission_order returned duplicate indices {adm} "
                    f"(double-admission) for |queue|={len(v.queue)}")
            if any(not (0 <= i < len(v.queue)) for i in adm):
                bad(f"admission_order returned out-of-range index in {adm} "
                    f"for |queue|={len(v.queue)}")
            for meth in ("decode_order", "prefill_order"):
                got = list(getattr(sched, meth)(v, list(slots_with_req)))
                if sorted(got) != sorted(slots_with_req):
                    bad(f"{meth} must PERMUTE the engine's slot list "
                        f"{slots_with_req}, got {got} (a dropped slot "
                        "starves; an invented slot packs garbage)")
            vic = list(sched.preempt_order(v, list(slots_with_req)))
            if len(set(vic)) != len(vic) or \
                    any(b not in slots_with_req for b in vic):
                bad(f"preempt_order must return a subsequence of the "
                    f"candidates {slots_with_req}, got {vic}")
            if out:
                return out  # one consultation's diagnosis is enough
    return out


def _delegates_verbatim(fn: ast.FunctionDef) -> bool:
    """Body is exactly ``return self.inner.<name>(<params verbatim>)``
    (docstring allowed)."""
    body = [n for n in fn.body
            if not (isinstance(n, ast.Expr)
                    and isinstance(n.value, ast.Constant))]
    if len(body) != 1 or not isinstance(body[0], ast.Return):
        return False
    call = body[0].value
    if not isinstance(call, ast.Call) or call.keywords:
        return False
    f = call.func
    if not (isinstance(f, ast.Attribute) and f.attr == fn.name
            and isinstance(f.value, ast.Attribute)
            and f.value.attr == "inner"
            and isinstance(f.value.value, ast.Name)
            and f.value.value.id == "self"):
        return False
    params = [a.arg for a in fn.args.args[1:]]  # skip self
    passed = [a.id if isinstance(a, ast.Name) else None for a in call.args]
    return passed == params


def _check_wrapper_delegation(rel: str, src_path: Path) -> List[Finding]:
    """Every class that holds an ``inner`` scheduler must delegate the four
    orderings verbatim (identified by ``self.inner = ...`` in __init__)."""
    out: List[Finding] = []
    tree = ast.parse(src_path.read_text(), filename=str(src_path))
    for cls in [n for n in ast.walk(tree) if isinstance(n, ast.ClassDef)]:
        is_wrapper = any(
            isinstance(n, ast.Assign) and any(
                isinstance(t, ast.Attribute) and t.attr == "inner"
                and isinstance(t.value, ast.Name) and t.value.id == "self"
                for t in n.targets)
            for n in ast.walk(cls))
        if not is_wrapper:
            continue
        methods = {n.name: n for n in cls.body
                   if isinstance(n, ast.FunctionDef)}
        for name in _ORDERINGS:
            fn = methods.get(name)
            if fn is None:
                continue  # inherited default — base Scheduler, acceptable
            if not _delegates_verbatim(fn):
                out.append(Finding(
                    _RULE, rel, fn.lineno,
                    f"{cls.name}.{name} must delegate VERBATIM to "
                    "self.inner (single `return self.inner."
                    f"{name}(...)` with the same arguments) — editing "
                    "an ordering forks the wrapped policy's guarantees"))
    return out


def check_protocols() -> Tuple[List[Finding], Dict]:
    """Run both layers over the live registry + the scheduler module AST."""
    from repro.serve.scheduler import SCHEDULERS

    rel, src_path = _sched_path()
    findings: List[Finding] = []
    for name, cls in sorted(SCHEDULERS.items()):
        try:
            line = inspect.getsourcelines(cls)[1]
        except (OSError, TypeError):
            line = 1
        sched = cls()
        findings.extend(_check_instance(name, sched, rel, line))
    findings.extend(_check_wrapper_delegation(rel, src_path))
    stats = {"schedulers": sorted(SCHEDULERS),
             "views_per_scheduler": len(_views()) * 3}
    return findings, stats
