"""Page-lifecycle model checker: exhaustive exploration of a small-pool
twin of ``serve.pool.PagePool``.

The pool's docstring promises a lifecycle — alloc → (release) → demote →
promote → park → unpark → drop → free — and the test suite checks it
dynamically with hypothesis interleavings that must happen to reach the
bad path.  This module encodes the lifecycle as an EXPLICIT transition
system over a counting abstraction of the two-tier pool and explores the
ENTIRE reachable state space by BFS (the space is finite: a few pages, a
few host slots, refcounts capped), proving that on every reachable state:

- **no leak** — device slots are conserved: free + active + device-cached
  == n_pages, and the host tier never exceeds its capacity;
- **no double-free / negative refcount** — every counter stays in range
  and every live allocation's refcount is >= 1;
- **no parked-page eviction** — the parked population always equals the
  outstanding preempted-request park records: host eviction and cache
  storms can never touch a parked page (the PR 9 pinning contract).

Because the exploration is exhaustive over the abstraction, a property
that holds here holds for EVERY interleaving of the modeled operations at
this pool size — the static twin of the hypothesis properties.  The model
is deliberately a table (``DEFAULT_MODEL``: name -> (guard, apply)) so a
test can swap in a BROKEN transition (``broken_model``) and assert the
checker reports a counterexample trace for it.

Abstraction notes: pages are interchangeable, so the state tracks COUNTS
plus the multiset of live refcounts — exact for every property above
(none depends on page identity).  Refcounts cap at ``REF_CAP`` (sharing
beyond 2 adds no new transitions to the properties checked).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

from repro.analysis.findings import Finding

__all__ = ["PoolModel", "DEFAULT_MODEL", "broken_model", "check_lifecycle",
           "LifecycleResult"]

_RULE = "page-lifecycle"

REF_CAP = 2  # refcounts beyond 2 are bisimilar for every checked property


@dataclasses.dataclass(frozen=True)
class PoolState:
    """Counting abstraction of a two-tier pool.

    ``active`` is the sorted multiset of live allocations' refcounts; the
    other fields count entries per tier.  Device slot conservation:
    ``free + len(active) + device_cached == n_pages``.  Host occupancy:
    ``host_cached + parked <= host_slots``.  ``park_records`` counts
    preempted requests holding a park — the pinning invariant is
    ``parked == park_records`` on every reachable state."""

    free: int
    active: Tuple[int, ...]
    device_cached: int
    host_cached: int
    parked: int
    park_records: int


def _with(s: PoolState, **kw) -> PoolState:
    return dataclasses.replace(s, **kw)


def _pop_ref(active: Tuple[int, ...], ref: int) -> Tuple[int, ...]:
    out = list(active)
    out.remove(ref)
    return tuple(out)


def _push_ref(active: Tuple[int, ...], ref: int) -> Tuple[int, ...]:
    return tuple(sorted(active + (ref,)))


# -- the transition table ----------------------------------------------------
# Each op: (guard(state, n_pages, host_slots) -> bool,
#           apply(state) -> state).  Ops model PagePool's public surface at
# the lifecycle level; nondeterministic outcomes (e.g. whether a released
# page was indexed) are separate ops so BFS explores both branches.

def _ops() -> Dict[str, Tuple[Callable, Callable]]:
    return {
        # alloc: a free device page becomes a live allocation (refcount 1)
        "alloc": (
            lambda s, D, H: s.free > 0,
            lambda s: _with(s, free=s.free - 1,
                            active=_push_ref(s.active, 1))),
        # share: a prefix hit maps an existing allocation (refcount ++)
        "share": (
            lambda s, D, H: any(r < REF_CAP for r in s.active),
            lambda s: _with(s, active=_push_ref(
                _pop_ref(s.active, next(r for r in s.active
                                        if r < REF_CAP)),
                next(r for r in s.active if r < REF_CAP) + 1))),
        # release of a shared page: refcount --
        "release_shared": (
            lambda s, D, H: any(r > 1 for r in s.active),
            lambda s: _with(s, active=_push_ref(
                _pop_ref(s.active, max(s.active)), max(s.active) - 1))),
        # release of a refcount-1 UNINDEXED page: straight back to free
        "release_private": (
            lambda s, D, H: 1 in s.active,
            lambda s: _with(s, free=s.free + 1,
                            active=_pop_ref(s.active, 1))),
        # release of a refcount-1 INDEXED page: stays resident as cache
        "release_indexed": (
            lambda s, D, H: 1 in s.active,
            lambda s: _with(s, device_cached=s.device_cached + 1,
                            active=_pop_ref(s.active, 1))),
        # demote: LRU device-cached entry moves device -> host under
        # pressure; its device slot frees (needs a host slot)
        "demote": (
            lambda s, D, H: s.device_cached > 0
            and s.host_cached + s.parked < H,
            lambda s: _with(s, device_cached=s.device_cached - 1,
                            host_cached=s.host_cached + 1,
                            free=s.free + 1)),
        # drop-evict: untiered eviction (or no host room) — entry is lost
        "drop_evict": (
            lambda s, D, H: s.device_cached > 0,
            lambda s: _with(s, device_cached=s.device_cached - 1,
                            free=s.free + 1)),
        # promote: a prefix hit on a host-resident entry acquires it back
        # to the device tier as a live allocation (needs a free page)
        "promote": (
            lambda s, D, H: s.host_cached > 0 and s.free > 0,
            lambda s: _with(s, host_cached=s.host_cached - 1,
                            free=s.free - 1,
                            active=_push_ref(s.active, 1))),
        # hevict: the finite host tier drops its LRU CACHE entry to make
        # room — by construction it can only see cache entries, not parks
        "hevict": (
            lambda s, D, H: s.host_cached > 0,
            lambda s: _with(s, host_cached=s.host_cached - 1)),
        # park: preemption swaps a victim's private refcount-1 page to the
        # host tier (pinned) and records the preempted request
        "park": (
            lambda s, D, H: 1 in s.active
            and s.host_cached + s.parked < H,
            lambda s: _with(s, active=_pop_ref(s.active, 1),
                            free=s.free + 1, parked=s.parked + 1,
                            park_records=s.park_records + 1)),
        # unpark: resume promotes the parked page back into a live slot
        "unpark": (
            lambda s, D, H: s.parked > 0 and s.free > 0,
            lambda s: _with(s, parked=s.parked - 1,
                            park_records=s.park_records - 1,
                            free=s.free - 1,
                            active=_push_ref(s.active, 1))),
        # drop_parked: cancel/deadline-expiry abandons the park entirely
        "drop_parked": (
            lambda s, D, H: s.parked > 0,
            lambda s: _with(s, parked=s.parked - 1,
                            park_records=s.park_records - 1)),
        # storm: a chaos host-eviction storm clears the host CACHE tier;
        # parked pages survive by construction (the pinning contract)
        "storm": (
            lambda s, D, H: s.host_cached > 0,
            lambda s: _with(s, host_cached=0)),
    }


# -- invariants --------------------------------------------------------------

def _invariants() -> Dict[str, Callable[[PoolState, int, int],
                                        Optional[str]]]:
    def conservation(s: PoolState, D: int, H: int) -> Optional[str]:
        total = s.free + len(s.active) + s.device_cached
        if total != D:
            return (f"device slots not conserved: free={s.free} + "
                    f"active={len(s.active)} + cached={s.device_cached} "
                    f"= {total} != n_pages={D} (leak or double-free)")
        return None

    def in_range(s: PoolState, D: int, H: int) -> Optional[str]:
        if s.free < 0 or s.device_cached < 0 or s.host_cached < 0 \
                or s.parked < 0 or s.park_records < 0:
            return f"negative counter in {s}"
        if any(r < 1 for r in s.active):
            return f"live allocation with refcount < 1 in {s}"
        return None

    def host_capacity(s: PoolState, D: int, H: int) -> Optional[str]:
        if s.host_cached + s.parked > H:
            return (f"host tier over capacity: cached={s.host_cached} + "
                    f"parked={s.parked} > host_slots={H}")
        return None

    def parked_pinned(s: PoolState, D: int, H: int) -> Optional[str]:
        if s.parked != s.park_records:
            return (f"parked pages ({s.parked}) != outstanding park "
                    f"records ({s.park_records}): a parked page was "
                    "evicted (or leaked) — resume would lose live "
                    "request state")
        return None

    return {"conservation": conservation, "in-range": in_range,
            "host-capacity": host_capacity, "parked-pinned": parked_pinned}


@dataclasses.dataclass
class PoolModel:
    """A transition system instance: ops + invariants + pool sizes."""

    n_pages: int = 3
    host_slots: int = 2
    ops: Dict[str, Tuple[Callable, Callable]] = \
        dataclasses.field(default_factory=_ops)
    invariants: Dict[str, Callable] = \
        dataclasses.field(default_factory=_invariants)

    def initial(self) -> PoolState:
        return PoolState(free=self.n_pages, active=(), device_cached=0,
                         host_cached=0, parked=0, park_records=0)


DEFAULT_MODEL = PoolModel


def broken_model(which: str = "storm-drops-parks", **kw) -> PoolModel:
    """A deliberately broken transition table, for testing the checker:

    - "storm-drops-parks": the chaos storm also clears PARKED pages —
      violating the pinning contract (parked != park_records).
    - "release-leaks": releasing a private page forgets to return its
      device slot to the free list — a page leak (conservation).
    - "double-free": releasing a private page returns TWO slots —
      a double free (conservation, from the other side).
    """
    m = PoolModel(**kw)
    if which == "storm-drops-parks":
        m.ops["storm"] = (
            lambda s, D, H: s.host_cached > 0 or s.parked > 0,
            lambda s: _with(s, host_cached=0, parked=0))
    elif which == "release-leaks":
        m.ops["release_private"] = (
            lambda s, D, H: 1 in s.active,
            lambda s: _with(s, active=_pop_ref(s.active, 1)))
    elif which == "double-free":
        m.ops["release_private"] = (
            lambda s, D, H: 1 in s.active,
            lambda s: _with(s, free=s.free + 2,
                            active=_pop_ref(s.active, 1)))
    else:
        raise ValueError(f"unknown breakage {which!r}")
    return m


@dataclasses.dataclass
class LifecycleResult:
    states_explored: int
    transitions: int
    violations: List[Tuple[str, str, List[str]]]  # (invariant, msg, trace)

    @property
    def ok(self) -> bool:
        return not self.violations


def check_lifecycle(model: Optional[PoolModel] = None,
                    max_states: int = 200_000) -> LifecycleResult:
    """BFS the full reachable state space, checking every invariant at
    every state.  Returns the first violation per invariant with the op
    trace that reaches it (shortest, since BFS)."""
    model = model or PoolModel()
    D, H = model.n_pages, model.host_slots
    init = model.initial()
    seen = {init: None}  # state -> (prev_state, op) for trace rebuild
    frontier = deque([init])
    violations: List[Tuple[str, str, List[str]]] = []
    tripped = set()
    transitions = 0

    def trace(state: PoolState) -> List[str]:
        ops: List[str] = []
        while seen[state] is not None:
            state, op = seen[state]
            ops.append(op)
        return ops[::-1]

    def check(state: PoolState) -> None:
        for name, inv in model.invariants.items():
            if name in tripped:
                continue
            msg = inv(state, D, H)
            if msg:
                tripped.add(name)
                violations.append((name, msg, trace(state)))

    check(init)
    while frontier and len(seen) < max_states:
        state = frontier.popleft()
        for op, (guard, apply) in model.ops.items():
            if not guard(state, D, H):
                continue
            transitions += 1
            nxt = apply(state)
            if nxt in seen:
                continue
            seen[nxt] = (state, op)
            check(nxt)
            frontier.append(nxt)
    return LifecycleResult(states_explored=len(seen),
                           transitions=transitions, violations=violations)


def check_lifecycle_findings() -> Tuple[List[Finding], Dict]:
    """CLI adapter: run the default model, report violations as findings
    anchored at the pool module the model abstracts."""
    res = check_lifecycle()
    findings = [
        Finding(_RULE, "src/repro/serve/pool.py", 1,
                f"{inv}: {msg} — counterexample: {' -> '.join(tr) or '<init>'}")
        for inv, msg, tr in res.violations]
    stats = {"states_explored": res.states_explored,
             "transitions": res.transitions,
             "exhaustive": res.states_explored < 200_000}
    return findings, stats
