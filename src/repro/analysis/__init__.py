"""repro.analysis — static invariant analyzer for the serving stack.

Four passes, one findings model, one CLI (``python -m repro.analysis``):

- ``servelint``  — repo-specific AST lint (jit factory discipline, hot-path
  nondeterminism, broad except, mutable defaults, retrace bombs);
- ``contracts``  — donation contract checker: compiles every serve program
  on shape-only dummies and PROVES the input_output_alias table donates
  the state pools (and that the page gather doesn't);
- ``lifecycle``  — page-lifecycle model checker: exhaustive BFS over a
  small-pool transition system proving no leak / double-free /
  parked-page eviction is reachable;
- ``protocols``  — scheduler registry conformance (orderings are
  permutations/subsequences; wrappers delegate verbatim).

Findings are typed ``file:line`` records; ``# servelint: ignore[rule] —
reason`` suppresses inline; ``baseline.json`` is checked in EMPTY and must
stay empty.  See README.md in this directory for the rule reference.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import (BASELINE_PATH, Finding, Suppressions,
                                     load_baseline, split_new)

__all__ = ["Finding", "Suppressions", "load_baseline", "split_new",
           "BASELINE_PATH", "run_all", "PASSES"]

PASSES = ("lint", "contracts", "lifecycle", "protocols")


def run_all(passes: Optional[Sequence[str]] = None, *,
            compile_programs: bool = True
            ) -> Tuple[List[Finding], Dict[str, Dict]]:
    """Run the selected passes (default: all) and aggregate findings.

    ``compile_programs=False`` skips the lower+compile proof inside the
    contracts pass (its AST layers still run) — used by fast test paths.
    """
    selected = tuple(passes) if passes else PASSES
    unknown = sorted(set(selected) - set(PASSES))
    if unknown:
        raise ValueError(f"unknown passes {unknown} (pick from {PASSES})")
    findings: List[Finding] = []
    stats: Dict[str, Dict] = {}
    if "lint" in selected:
        from repro.analysis.servelint import lint_tree
        lint = lint_tree()
        findings.extend(lint)
        stats["lint"] = {"findings": len(lint)}
    if "contracts" in selected:
        from repro.analysis.contracts import check_contracts
        got, s = check_contracts(compile_programs=compile_programs)
        findings.extend(got)
        stats["contracts"] = {**s, "findings": len(got)}
    if "lifecycle" in selected:
        from repro.analysis.lifecycle import check_lifecycle_findings
        got, s = check_lifecycle_findings()
        findings.extend(got)
        stats["lifecycle"] = {**s, "findings": len(got)}
    if "protocols" in selected:
        from repro.analysis.protocols import check_protocols
        got, s = check_protocols()
        findings.extend(got)
        stats["protocols"] = {**s, "findings": len(got)}
    return findings, stats
