"""Typed findings, the suppression model, and the checked-in baseline.

Every analysis pass (servelint / contracts / lifecycle / protocols) reports
``Finding`` records — (rule, file, line, message) — never free-form text, so
the CLI can diff them against the baseline and CI can gate on the count.

Suppression syntax, checked at the flagged line or the line directly above:

    x = risky()  # servelint: ignore[rule-id] — reason the rule is wrong here
    # servelint: ignore[rule-a,rule-b] — reason
    y = also_risky()

A suppression must name the rule(s) it silences (no blanket ignores) and
SHOULD carry a reason after the bracket — the CLI report prints it.  The
baseline (``baseline.json`` next to this module) is the list of finding
keys tolerated at head; it is checked in EMPTY and must stay empty — new
findings either get fixed or get an inline, reasoned suppression.
"""
from __future__ import annotations

import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, List, Sequence, Set, Tuple

__all__ = ["Finding", "Suppressions", "load_baseline", "BASELINE_PATH"]

BASELINE_PATH = Path(__file__).with_name("baseline.json")

_SUPPRESS_RE = re.compile(
    r"#\s*servelint:\s*ignore\[([a-z0-9_,\s-]+)\]\s*(?:[—–:-]\s*(.*))?")


@dataclasses.dataclass(frozen=True)
class Finding:
    """One analyzer verdict, anchored to a file:line."""

    rule: str
    path: str  # repo-relative, e.g. "src/repro/serve/engine.py"
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""  # the suppression's reason text, when suppressed

    @property
    def key(self) -> str:
        """Stable identity for baseline diffing (message-free on purpose:
        rewording a message must not un-baseline a finding)."""
        return f"{self.path}:{self.line}:{self.rule}"

    def __str__(self) -> str:
        tag = f" [suppressed: {self.reason or 'no reason given'}]" \
            if self.suppressed else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tag}"


class Suppressions:
    """Per-file `# servelint: ignore[rule]` index, built from source text."""

    def __init__(self, source: str):
        # line (1-based) -> {rule: reason}; a comment on its own line also
        # covers the next line, so multi-line statements can hoist the
        # suppression above the flagged expression
        self._by_line: Dict[int, Dict[str, str]] = {}
        for i, text in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(text)
            if not m:
                continue
            rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
            reason = (m.group(2) or "").strip()
            entry = {r: reason for r in rules}
            self._by_line.setdefault(i, {}).update(entry)
            if text.lstrip().startswith("#"):  # own-line comment: covers
                self._by_line.setdefault(i + 1, {}).update(entry)  # next line

    def lookup(self, line: int, rule: str) -> Tuple[bool, str]:
        """Is ``rule`` suppressed at ``line`` (same line or line above)?"""
        for ln in (line, line - 1):
            entry = self._by_line.get(ln)
            if entry and rule in entry:
                return True, entry[rule]
        return False, ""

    def apply(self, finding: Finding) -> Finding:
        hit, reason = self.lookup(finding.line, finding.rule)
        if not hit:
            return finding
        return dataclasses.replace(finding, suppressed=True, reason=reason)


def load_baseline(path: Path = BASELINE_PATH) -> Set[str]:
    """Finding keys tolerated at head.  Checked in empty; stays empty."""
    if not path.exists():
        return set()
    return set(json.loads(path.read_text()))


def split_new(findings: Sequence[Finding],
              baseline: Set[str]) -> Tuple[List[Finding], List[Finding]]:
    """(actionable, tolerated): unsuppressed findings not in the baseline
    are actionable; suppressed or baselined ones are tolerated."""
    actionable, tolerated = [], []
    for f in findings:
        (tolerated if f.suppressed or f.key in baseline
         else actionable).append(f)
    return actionable, tolerated
