"""Donation contract checker: a static PROOF that the serve programs
update the KV pools in place.

The engine's hot-loop no-copy contract — every tick's new KV entries
scatter into the existing page pools instead of copying them — has been
enforced dynamically since PR 4 by a buffer-pointer identity assert that a
test must happen to execute.  This module turns it into a static property
of the compiled artifacts: each serve program the engine builds in
``ServeEngine.__init__`` is lowered and compiled on SHAPE-ONLY dummies
(``jax.jit(...).lower(...).compile()`` — no weights, no execution), and the
executable's ``input_output_alias`` table is checked to actually donate
every pool leaf (``POOL_LEAVES``: kp/vp value pools and ks/vs int8 scale
pools) of the donated state argument — while ``make_page_gather``, whose
state must stay LIVE (the engine reads the gathered rows out to host RAM
afterwards), is proven to alias nothing.

Three layers of checking, strongest last:

1. **Alias feasibility** (abstract eval): every pool leaf of the donated
   input has a same-shape/same-dtype twin in the output — the necessary
   condition for XLA to alias them.
2. **Compiled aliasing** (the proof): the executable's input_output_alias
   table maps every pool-leaf parameter of the donated argument to an
   output — XLA will reuse those buffers, so the pools can never be
   copied by this program.
3. **Engine source cross-check** (AST): ``ServeEngine.__init__`` really
   jits each program with the registered donation signature (so the
   checked programs are the shipped ones, not lookalikes), and at every
   call site of a donated program the donated state variable is REBOUND by
   the call's own assignment — a donated buffer is invalid after the call,
   and rebinding is the static guarantee nothing reads it post-call.

``assert_donated`` / ``pool_buffer_pointers`` are the shared RUNTIME form
of the same contract (used by tests/test_kv_quant.py), kept here so the
dynamic assert and the static checker read the same ``POOL_LEAVES`` list
and cannot drift.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.findings import Finding

__all__ = ["POOL_LEAVES", "pool_buffer_pointers", "assert_donated",
           "check_contracts", "SERVE_PROGRAMS"]

# the pool-sized decode-state leaves the no-copy contract covers: KV value
# pools and their int8 scale pools (serve_step.STATE_AXES names the rest)
POOL_LEAVES = ("kp", "vp", "ks", "vs")

_RULE = "donation-contract"


# ---------------------------------------------------------------------------
# Runtime form (shared with tests): buffer-pointer identity


def _leaf_name(path) -> Optional[str]:
    for p in reversed(path):
        if isinstance(p, jax.tree_util.DictKey):
            return p.key
    return None


def pool_buffer_pointers(state) -> Optional[Dict[str, int]]:
    """{tree path: device buffer pointer} for every pool leaf, or None when
    the backend exposes no buffer pointers (donation untestable there)."""
    ptrs: Dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        if _leaf_name(path) in POOL_LEAVES:
            try:
                ptrs[jax.tree_util.keystr(path)] = leaf.unsafe_buffer_pointer()
            except Exception:  # backend-specific error types
                return None
    return ptrs


def assert_donated(before: Dict[str, int], state) -> str:
    """Runtime no-copy check: ``before`` is a ``pool_buffer_pointers``
    snapshot taken pre-call, ``state`` the post-call pytree.  Returns
    "donated" when every pool buffer was updated in place, "undonated"
    when the backend donated nothing (tolerated — some backends can't),
    and raises AssertionError on a PARTIAL donation, which is always a
    bug: some pools copied while others aliased."""
    after: Dict[str, int] = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(state)[0]:
        key = jax.tree_util.keystr(path)
        if key in before:
            after[key] = leaf.unsafe_buffer_pointer()
    missing = sorted(set(before) - set(after))
    assert not missing, f"pool leaves vanished from the state: {missing}"
    if after == before:
        return "donated"
    assert all(after[k] != before[k] for k in before), (
        "pools partially donated: some copied, some aliased — "
        f"{ {k: (before[k], after[k]) for k in before} }")
    return "undonated"


# ---------------------------------------------------------------------------
# Static form: lower + compile on shape dummies, prove input_output_alias


def _smoke_setup():
    """Tiny all-global-attention config + shape-only dummies mirroring
    ``ServeEngine.__init__``/``_ensure_state`` exactly (int8 pools so the
    scale-pool leaves exist; spec_k > 0 so the rollback program and the
    widened logit_idx are exercised)."""
    from repro.configs import get_config
    from repro.models import model as M

    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    B, cache_len, page, chunk, T, spec_k = 2, 64, 8, 8, 16, 2
    pps = -(-cache_len // page)
    n_pages = B * pps
    pshapes = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), cfg))
    sshapes = jax.eval_shape(
        lambda p: M.init_paged_state(p, cfg, B, cache_len, page_size=page,
                                     n_pages=n_pages, window_extra=chunk,
                                     kv_dtype="int8"), pshapes)
    dims = dict(B=B, chunk=chunk, T=T, pps=pps, spec_k=spec_k)
    return cfg, pshapes, sshapes, dims


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _build_programs(cfg, pshapes, sshapes, dims):
    """(name, fn, args, donate_argnums, donated_argnum, expect_donated) for
    every program ``ServeEngine.__init__`` jits — built from the SAME
    builders and the same ``STATE_DONATE_ARGNUM`` the engine uses."""
    from repro.models import model as M
    from repro.serve.serve_step import (STATE_DONATE_ARGNUM,
                                        make_page_gather, make_page_insert,
                                        make_ragged_step, make_spec_rollback)

    B, chunk, T, pps, spec_k = (dims["B"], dims["chunk"], dims["T"],
                                dims["pps"], dims["spec_k"])
    i32, b_ = jnp.int32, jnp.bool_
    page = _sds((), i32)
    page_data = jax.eval_shape(
        lambda s, p: M.gather_kv_page(cfg, s, p), sshapes, page)
    step = lambda wl: (lambda p, s, t, qp, v: M.paged_step(
        p, cfg, s, t, qp, v, with_logits=wl))
    donate = (STATE_DONATE_ARGNUM,)
    return [
        ("_ragged_step",
         make_ragged_step(cfg, width=max(chunk + 1, 1 + spec_k)),
         (pshapes, sshapes, _sds((T,), i32), _sds((T,), i32),
          _sds((T,), i32), _sds((T,), i32), _sds((T,), b_),
          _sds((B, 1 + spec_k), i32)),
         donate, STATE_DONATE_ARGNUM, True),
        ("_chunk_step", step(False),
         (pshapes, sshapes, _sds((B, chunk), i32), _sds((B, chunk), i32),
          _sds((B, chunk), b_)),
         donate, STATE_DONATE_ARGNUM, True),
        ("_decode_step", step(True),
         (pshapes, sshapes, _sds((B, 1), i32), _sds((B, 1), i32),
          _sds((B, 1), b_)),
         donate, STATE_DONATE_ARGNUM, True),
        ("_reset",
         lambda s, s0, m, rows, plen: M.reset_paged_slots(
             cfg, s, s0, m, rows, plen),
         (sshapes, sshapes, _sds((B,), b_), _sds((B, pps), i32),
          _sds((B,), i32)),
         (0,), 0, True),
        ("_copy",
         lambda s, src, dst: M.copy_kv_pages(cfg, s, src, dst),
         (sshapes, _sds((B,), i32), _sds((B,), i32)),
         (0,), 0, True),
        ("_gather_page", make_page_gather(cfg), (sshapes, page),
         (), 0, False),
        ("_insert_page", make_page_insert(cfg), (sshapes, page_data, page),
         (0,), 0, True),
        ("_spec_rollback", make_spec_rollback(cfg),
         (sshapes, _sds((B,), b_), _sds((B,), i32)),
         (0,), 0, True),
    ]


def _pool_leaf_indices(args: tuple, argnum: int) -> Dict[int, str]:
    """Flat entry-parameter index -> leaf path, for every pool leaf of
    ``args[argnum]``.  jit flattens the argument tuple leaf-by-leaf in
    order, so a leaf's position IS its XLA entry parameter number."""
    out: Dict[int, str] = {}
    for i, (path, _) in enumerate(
            jax.tree_util.tree_flatten_with_path(args)[0]):
        if not (path and isinstance(path[0], jax.tree_util.SequenceKey)
                and path[0].idx == argnum):
            continue
        if _leaf_name(path) in POOL_LEAVES:
            out[i] = jax.tree_util.keystr(path)
    return out


def _compiled_alias_params(compiled_text: str) -> Dict[int, tuple]:
    """Parse the HLO module header's ``input_output_alias={ {out}: (param,
    {index}, kind), ... }`` into {param_number: (out_index, kind)}."""
    start = compiled_text.find("input_output_alias={")
    if start < 0:
        return {}
    body, depth = [], 0
    for ch in compiled_text[start + len("input_output_alias="):]:
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                break
        body.append(ch)
    table = "".join(body)
    out: Dict[int, tuple] = {}
    for m in re.finditer(
            r"\{([\d,\s]*)\}:\s*\((\d+),\s*\{[\d,\s]*\}"
            r"(?:,\s*([\w-]+))?\)", table):
        out[int(m.group(2))] = (m.group(1).strip(), m.group(3) or "")
    return out


_ENGINE_PATH = Path(__file__).resolve().parents[1] / "serve" / "engine.py"
_ENGINE_REL = "src/repro/serve/engine.py"

# what ServeEngine.__init__ must pass as donate_argnums for each program
# attribute: "donate" = the shared (STATE_DONATE_ARGNUM,) tuple, an int =
# that literal argnum, None = NO donation allowed
_EXPECTED_JIT_DONATION: Dict[str, Optional[object]] = {
    "_ragged_step": "donate", "_chunk_step": "donate",
    "_decode_step": "donate", "_reset": 0, "_copy": 0,
    "_gather_page": None, "_insert_page": 0, "_spec_rollback": 0,
}
# donated-state argument position at each program's CALL sites
_DONATED_CALL_ARG: Dict[str, int] = {
    "_ragged_step": 1, "_chunk_step": 1, "_decode_step": 1,
    "_reset": 0, "_copy": 0, "_insert_page": 0, "_spec_rollback": 0,
}

SERVE_PROGRAMS = tuple(_EXPECTED_JIT_DONATION)


def _check_engine_jit_construction(tree: ast.Module) -> List[Finding]:
    """The compiled-artifact proof covers programs built from the shared
    builders; this pass pins the ENGINE's own constructor to the same
    donation signatures, so the proof is about the shipped programs."""
    findings: List[Finding] = []
    seen: Dict[str, Optional[object]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and len(node.targets) == 1):
            continue
        tgt = node.targets[0]
        if not (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
                and tgt.attr in _EXPECTED_JIT_DONATION):
            continue
        call = node.value
        if not (isinstance(call, ast.Call) and isinstance(
                call.func, ast.Attribute) and call.func.attr == "jit"):
            continue
        donate_kw = next((kw.value for kw in call.keywords
                          if kw.arg == "donate_argnums"), None)
        if donate_kw is None:
            seen[tgt.attr] = None
        elif isinstance(donate_kw, ast.Name):
            seen[tgt.attr] = donate_kw.id
        elif isinstance(donate_kw, ast.Tuple) and donate_kw.elts:
            elt = donate_kw.elts[0]
            seen[tgt.attr] = (elt.value if isinstance(elt, ast.Constant)
                              else ast.unparse(elt))
        else:
            seen[tgt.attr] = ast.unparse(donate_kw)
        if seen[tgt.attr] != _EXPECTED_JIT_DONATION[tgt.attr]:
            findings.append(Finding(
                _RULE, _ENGINE_REL, node.lineno,
                f"self.{tgt.attr} jitted with donate_argnums="
                f"{seen[tgt.attr]!r}; the registered contract requires "
                f"{_EXPECTED_JIT_DONATION[tgt.attr]!r}"))
    for attr in _EXPECTED_JIT_DONATION:
        if attr not in seen:
            findings.append(Finding(
                _RULE, _ENGINE_REL, 1,
                f"ServeEngine.__init__ no longer jits self.{attr} — update "
                "analysis.contracts if the program registry changed"))
    return findings


def _check_donated_not_read_post_call(tree: ast.Module) -> List[Finding]:
    """Every call of a donated program must REBIND its donated state
    argument in the same assignment (``state = self._reset(state, ...)``):
    the input buffer is dead after the call, and rebinding is the static
    guarantee no later statement reads it."""
    findings: List[Finding] = []
    parents: Dict[int, ast.AST] = {}
    for parent in ast.walk(tree):
        for child in ast.iter_child_nodes(parent):
            parents[id(child)] = parent
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and isinstance(node.func.value, ast.Name)
                and node.func.value.id == "self"
                and node.func.attr in _DONATED_CALL_ARG):
            continue
        pos = _DONATED_CALL_ARG[node.func.attr]
        if pos >= len(node.args):
            continue
        donated = ast.unparse(node.args[pos])
        stmt = parents.get(id(node))
        ok = False
        if isinstance(stmt, ast.Assign) and stmt.value is node:
            targets: List[str] = []
            for t in stmt.targets:
                elts = t.elts if isinstance(t, ast.Tuple) else [t]
                targets.extend(ast.unparse(e) for e in elts)
            ok = donated in targets
        if not ok:
            findings.append(Finding(
                _RULE, _ENGINE_REL, node.lineno,
                f"self.{node.func.attr}(...) donates {donated!r} but the "
                "call does not rebind it — the donated buffer is invalid "
                "after the call and any later read is use-after-free"))
    return findings


def check_contracts(compile_programs: bool = True
                    ) -> Tuple[List[Finding], Dict]:
    """Run the donation contract checker.  Returns (findings, stats);
    stats records per-program proof results for the CLI report."""
    findings: List[Finding] = []
    stats: Dict[str, Dict] = {"programs": {}}
    tree = ast.parse(_ENGINE_PATH.read_text())
    findings.extend(_check_engine_jit_construction(tree))
    findings.extend(_check_donated_not_read_post_call(tree))
    if not compile_programs:
        return findings, stats

    cfg, pshapes, sshapes, dims = _smoke_setup()
    rel = "src/repro/serve/serve_step.py"
    for (name, fn, args, donate, argnum,
         expect) in _build_programs(cfg, pshapes, sshapes, dims):
        pool_idx = _pool_leaf_indices(args, argnum)
        record = {"donated_leaves": len(pool_idx), "expect_donated": expect}
        # 1) alias feasibility: each donated pool leaf has a same-shape/
        #    dtype twin in the output pytree
        out_shapes = jax.eval_shape(fn, *args)
        out_pools = {}
        for path, leaf in jax.tree_util.tree_flatten_with_path(
                out_shapes)[0]:
            if _leaf_name(path) in POOL_LEAVES:
                out_pools.setdefault((leaf.shape, str(leaf.dtype)),
                                     0)
                out_pools[(leaf.shape, str(leaf.dtype))] += 1
        if expect:
            in_pools: Dict[tuple, int] = {}
            for path, leaf in jax.tree_util.tree_flatten_with_path(
                    args[argnum])[0]:
                if _leaf_name(path) in POOL_LEAVES:
                    k = (leaf.shape, str(leaf.dtype))
                    in_pools[k] = in_pools.get(k, 0) + 1
            for k, n in in_pools.items():
                if out_pools.get(k, 0) < n:
                    findings.append(Finding(
                        _RULE, rel, 1,
                        f"{name}: {n} donated pool leaves of shape/dtype "
                        f"{k} but only {out_pools.get(k, 0)} in the "
                        "output — aliasing is infeasible, the program "
                        "must copy"))
        # 2) the proof: compile on shape dummies, read the alias table.
        #    jit DROPS unused flat arguments at lowering (e.g. the LM-head
        #    params when with_logits=False), so a leaf's XLA entry-parameter
        #    number is its rank among the KEPT flat indices, not its flat
        #    index — remap through kept_var_idx before reading the table.
        lowered = jax.jit(fn, donate_argnums=donate).lower(*args)
        n_flat = len(jax.tree_util.tree_flatten_with_path(args)[0])
        try:
            kept = sorted(lowered._lowering.compile_args["kept_var_idx"])
        except (AttributeError, KeyError):  # private API moved: assume none
            kept = list(range(n_flat))  # dropped (conservative: flat == entry)
        entry = {flat: kept.index(flat) for flat in pool_idx if flat in kept}
        dropped = sorted(set(pool_idx) - set(entry))
        if dropped:
            findings.append(Finding(
                _RULE, rel, 1,
                f"{name}: donated pool leaves "
                f"{[pool_idx[i] for i in dropped]} are UNUSED by the "
                "program — the donated state is not the state this "
                "program updates"))
        aliased = _compiled_alias_params(lowered.compile().as_text())
        record["aliased_params"] = len(aliased)
        if expect:
            missing = [key for i, key in sorted(pool_idx.items())
                       if entry.get(i, -1) not in aliased]
            record["proved"] = not missing
            if missing:
                findings.append(Finding(
                    _RULE, rel, 1,
                    f"{name}: compiled executable does NOT donate pool "
                    f"leaves {missing} — the hot loop would copy the "
                    "pool every call"))
        else:
            stray = sorted(pool_idx[i] for i, e in entry.items()
                           if e in aliased)
            record["proved"] = not stray
            if stray:
                findings.append(Finding(
                    _RULE, rel, 1,
                    f"{name}: compiled executable aliases state "
                    f"parameters {stray} but this program's state must "
                    "stay LIVE (the engine reads it after the call)"))
        stats["programs"][name] = record
    return findings, stats
