"""CLI: ``python -m repro.analysis [--fail-on-findings] [--json PATH]
[--passes lint,contracts,...] [--no-compile]``.

Prints a per-pass report, lists actionable findings (unsuppressed and not
in the baseline), and with ``--fail-on-findings`` exits 1 when any exist —
the CI gate.  ``--json`` writes the full findings list (suppressed ones
included, marked) for the CI artifact.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
from typing import List, Optional

from repro.analysis import PASSES, load_baseline, run_all, split_new


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static invariant analyzer for the serving stack")
    ap.add_argument("--fail-on-findings", action="store_true",
                    help="exit 1 if any actionable finding remains")
    ap.add_argument("--json", metavar="PATH",
                    help="write all findings (incl. suppressed) as JSON")
    ap.add_argument("--passes", default=",".join(PASSES),
                    help=f"comma-separated subset of {','.join(PASSES)}")
    ap.add_argument("--no-compile", action="store_true",
                    help="skip the lower+compile donation proof (fast)")
    args = ap.parse_args(argv)

    passes = [p.strip() for p in args.passes.split(",") if p.strip()]
    findings, stats = run_all(passes,
                              compile_programs=not args.no_compile)
    actionable, tolerated = split_new(findings, load_baseline())

    for name in passes:
        s = dict(stats.get(name, {}))
        n = s.pop("findings", 0)
        extra = f"  {s}" if s else ""
        print(f"[{name:>9}] {n} finding(s){extra}")
    for f in sorted(actionable, key=lambda f: (f.path, f.line, f.rule)):
        print(f"  ACTIONABLE {f}")
    for f in sorted(tolerated, key=lambda f: (f.path, f.line, f.rule)):
        print(f"  tolerated  {f}")
    print(f"{len(actionable)} actionable, {len(tolerated)} tolerated "
          f"finding(s) across {len(passes)} pass(es)")

    if args.json:
        with open(args.json, "w") as fh:
            json.dump([dataclasses.asdict(f) for f in findings], fh,
                      indent=2)
        print(f"findings written to {args.json}")

    return 1 if (args.fail_on_findings and actionable) else 0


if __name__ == "__main__":
    sys.exit(main())
