"""Minimal streaming client for the serving engine.

``ServeEngine.submit`` returns a ``RequestHandle`` (an int-compatible
object carrying the request uid).  ``handle.tokens()`` yields generated
tokens as they are emitted, driving ``engine.tick()`` whenever it starves —
no thread, no callback: the engine stays a pull-based tick loop, and a tick
advances EVERY live request, so several handles can be consumed
concurrently (here: round-robin across three streams).

The second half is the cancel-on-timeout pattern: a long generation is
cancelled mid-decode once its wall-clock budget expires.  ``cancel()``
releases the request's pages refcount-safely — pages shared with other
requests (or held by the prefix cache) survive — so the demo ends by
asserting the pool is fully reclaimable: nothing leaked.

  PYTHONPATH=src python examples/serve_stream.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_size=3, cache_len=128,
                      page_size=8, prefill_chunk=16, token_budget=32)
    rng = np.random.RandomState(0)

    # -- streaming: three concurrent requests, consumed token by token ----
    handles = [eng.submit(rng.randint(0, cfg.vocab_size, n), max_tokens=6)
               for n in (12, 7, 19)]
    streams = [(h, h.tokens()) for h in handles]
    print("streaming three requests (one line per token):")
    while streams:
        for h, it in list(streams):
            tok = next(it, None)
            if tok is None:
                print(f"  req {h:3d}: done -> {h.result()}")
                streams.remove((h, it))
            else:
                print(f"  req {h:3d}: +{tok}")

    # -- cancel on timeout: stop a runaway generation mid-decode ----------
    # a real client would use only the wall-clock deadline; the token cap
    # keeps the demo deterministic on machines fast enough to finish 100
    # tokens before the clock expires
    slow = eng.submit(rng.randint(0, cfg.vocab_size, 10), max_tokens=100)
    deadline = time.perf_counter() + 0.25
    for i, tok in enumerate(slow.tokens()):
        if time.perf_counter() > deadline or i >= 11:
            slow.cancel()
            break
    print(f"cancelled after {len(slow.result())} tokens "
          f"(cancelled={slow.cancelled})")
    assert slow.cancelled and len(slow.result()) < 100
    eng.run()  # drain anything still live

    # cancellation is refcount-safe: every page is free or reclaimable cache
    assert eng.reclaimable_pages == eng.n_pages, "page leak!"
    print(f"pool clean: {eng.reclaimable_pages}/{eng.n_pages} pages "
          f"reclaimable; stats: prefix_hits={eng.stats['prefix_hits']}, "
          f"cancelled={eng.stats['cancelled']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
