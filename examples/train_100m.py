"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on synthetic data with checkpointing + resume.

  PYTHONPATH=src python examples/train_100m.py [--steps 300] [--d-model 512]

(At the default reduced width this finishes on a laptop-class CPU; the same
driver shards unchanged on a pod via launch/train.py.)
"""
import argparse

from repro.configs.base import ShapeCfg
from repro.configs.util import dense_lm
from repro.configs import param_count
from repro.train.loop import TrainLoop


def build_cfg(d_model: int, n_layers: int):
    return dense_lm("qwen2-100m", n_layers=n_layers, d_model=d_model,
                    n_heads=8, n_kv=2, head_dim=d_model // 8, d_ff=4 * d_model,
                    vocab=32768, qkv_bias=True, rope_theta=1e4, tie=True,
                    max_seq_len=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--n-layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default="runs/train_100m")
    args = ap.parse_args()

    cfg = build_cfg(args.d_model, args.n_layers)
    print(f"{cfg.name}: {param_count(cfg)/1e6:.1f}M params")
    shape = ShapeCfg("train", args.seq_len, args.batch, "train")
    loop = TrainLoop(cfg, shape, lr=1e-3, total_steps=args.steps,
                     ckpt_dir=args.ckpt_dir, save_every=50)
    hist = loop.run(args.steps)
    k = max(1, len(hist) // 10)
    for i in range(0, len(hist), k):
        print(f"step {hist[i]['step']:4d}  loss {hist[i]['loss']:.4f}  "
              f"{hist[i]['time_s']*1e3:.0f} ms/step")
    print(f"final loss {hist[-1]['loss']:.4f} (from {hist[0]['loss']:.4f})")


if __name__ == "__main__":
    main()
