"""Serve mixed-length batched requests through the ragged token-budget
engine (one compiled program for any prefill/decode mix + paged KV slots +
FIFO admission).

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("gemma3-4b", smoke=True)  # local+global attention mix
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    # 4 slots, but a page pool sized for ~2.5 full sequences: admission
    # reserves pages FIFO and queues the rest — overcommit without OOM
    engine = ServeEngine(params, cfg, batch_size=4, cache_len=96,
                         page_size=16, max_pages=16, prefill_chunk=32)

    rng = np.random.RandomState(0)
    uids = [engine.submit(rng.randint(0, cfg.vocab_size, size=L),
                          max_tokens=8)
            for L in (12, 48, 7, 80, 25, 12, 60, 9, 33, 16)]
    results = engine.run()
    for uid in uids:
        print(f"request {uid:2d} -> {results[uid]}")
    assert len(results) == 10 and all(len(v) == 8 for v in results.values())
    print(f"served 10 mixed-length requests through 4 slots / 16 pages: "
          f"{engine.stats}")


if __name__ == "__main__":
    main()
