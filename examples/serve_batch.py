"""Serve a small model with batched requests through the continuous-batching
engine (prefill + lock-step decode + slot reuse).

  PYTHONPATH=src python examples/serve_batch.py
"""
import numpy as np
import jax

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine


def main():
    cfg = get_config("gemma3-4b", smoke=True)  # local+global attention mix
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    engine = ServeEngine(params, cfg, batch_size=4, cache_len=96)

    rng = np.random.RandomState(0)
    uids = [engine.submit(rng.randint(0, cfg.vocab_size, size=12),
                          max_tokens=8) for _ in range(10)]
    results = engine.run()
    for uid in uids:
        print(f"request {uid:2d} -> {results[uid]}")
    assert len(results) == 10 and all(len(v) == 8 for v in results.values())
    print("served 10 requests through 4 slots (continuous batching)")


if __name__ == "__main__":
    main()
