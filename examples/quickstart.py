"""Quickstart: build a small model, train a few steps, decode a sample.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.models import model as M
from repro.train.loop import TrainLoop

ARCH = "qwen2-1.5b"  # any of the 10 assigned archs (--arch analogue)


def main():
    cfg = get_config(ARCH, smoke=True)  # reduced config: runs on CPU
    shape = ShapeCfg("quickstart", seq_len=64, global_batch=8, kind="train")

    print(f"training {cfg.name} ({cfg.n_layers} layers, d={cfg.d_model}) ...")
    loop = TrainLoop(cfg, shape, lr=3e-3, total_steps=100)
    history = loop.run(40)
    print(f"loss: {history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")

    params = loop.final_state["params"]
    state = M.init_decode_state(params, cfg, 1, 128)
    prompt = jnp.arange(8)[None] % cfg.vocab_size
    state = M.prefill(params, cfg, state, prompt)
    tok = prompt[:, -1:]
    out = []
    for _ in range(12):
        logits, state = M.decode_step(params, cfg, state, tok)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(int(tok[0, 0]))
    print("greedy continuation:", out)


if __name__ == "__main__":
    main()
