"""The paper's experiment in miniature: measured matmul GFLOP/s across the
Nproc sweep at constant total memory (N = N0/√Nproc), both engines.

  PYTHONPATH=src python examples/sweep_demo.py

For the full pod-level (derived) sweep over placements × memory modes:
  PYTHONPATH=src python -m repro.launch.sweep --quick
"""
from repro.core.sweep import measured_gflops


def main():
    print(f"{'engine':>7} {'Nproc':>6} {'N':>6} {'ms/call':>9} {'GF/s':>8}")
    for engine, nprocs, n0 in (("xla", (1, 2, 4, 8), 1024),
                               ("pallas", (1, 2), 384)):
        for p in nprocs:
            r = measured_gflops(engine, p, n0=n0, reps=2)
            print(f"{engine:>7} {p:6d} {r['N']:6d} "
                  f"{r['us_per_call']/1e3:9.1f} {r['gflops']:8.1f}")
    print("\n(the paper's finding: with affinity+memory-mode set correctly, "
          "throughput is flat across the whole Nproc×Nthread range)")


if __name__ == "__main__":
    main()
