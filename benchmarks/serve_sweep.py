"""Serving-engine sweep — the Figs. 4/5 protocol at serving time.

The paper sweeps (Nproc × Nthread) at constant memory and shows that one
set of system settings keeps every factorization near peak.  The serving
analogue sweeps (concurrent users × prompt-length mix × page size) through
``serve.ServeEngine`` (paged KV + chunked batched prefill) and scores
measured tokens/s three ways:

- against the seed engine (``serve.reference.ReferenceEngine``, batch-1
  sequential prefill) on identical traffic — the speedup column;
- against the analytic decode roofline (``core.roofline.decode_bound``)
  at the same batch/context — the fraction-of-bound column;
- across page sizes — paging's constant-traffic claim (the all2all-cache
  analogue: per-slot KV traffic rounds to pages, so smaller pages hug the
  true context length).

  PYTHONPATH=src python benchmarks/serve_sweep.py [--arch qwen2-1.5b]
      [--users 4 16] [--page-sizes 8 32] [--max-tokens 8] [--no-baseline]

CSV: name,tokens_per_s,derived  (derived = ×-over-seed or %-of-bound)
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.roofline import decode_bound
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.reference import ReferenceEngine

# mixed-length mix: short chat turns + a few long-context stragglers
# (fractions of cache budget available for the prompt)
MIX = (0.15, 0.7, 0.3, 0.15, 0.5, 0.9, 0.2, 0.4)


def _traffic(cfg, n_users: int, prompt_budget: int, max_tokens: int, seed=0):
    rng = np.random.RandomState(seed)
    prompts = []
    for i in range(n_users):
        L = max(4, int(MIX[i % len(MIX)] * prompt_budget))
        prompts.append(rng.randint(0, cfg.vocab_size, L))
    return prompts


def _run(engine, prompts, max_tokens: int):
    uids = [engine.submit(p, max_tokens=max_tokens) for p in prompts]
    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(results[u]) for u in uids)
    assert all(len(results[u]) == max_tokens for u in uids)
    return n_tok / dt, results


def sweep(arch: str, users, page_sizes, max_tokens: int, cache_len: int,
          baseline: bool = True, warm: bool = True):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    for n_users in users:
        prompts = _traffic(cfg, n_users, cache_len - max_tokens, max_tokens)
        batch = min(n_users, 8)
        ref_tps = None
        if baseline:
            ref = ReferenceEngine(params, cfg, batch_size=batch,
                                  cache_len=cache_len)
            if warm:  # jit caches are per-engine-instance: warm then re-time
                _run(ref, prompts, max_tokens)
            ref_tps, _ = _run(ref, prompts, max_tokens)
            rows.append((f"serve/{arch}/seed/users={n_users}", ref_tps, ""))
        for ps in page_sizes:
            bound = decode_bound(cfg, batch, cache_len,
                                 page_size=ps)["tokens_per_s"]
            eng = ServeEngine(params, cfg, batch_size=batch,
                              cache_len=cache_len, page_size=ps,
                              prefill_chunk=32)
            if warm:  # compile outside the timed run (steady-state tokens/s)
                _run(eng, prompts, max_tokens)
            tps, _ = _run(eng, prompts, max_tokens)
            derived = (f"{tps / ref_tps:.1f}x-over-seed" if ref_tps
                       else f"{tps / bound:.2e}-of-bound")
            rows.append((
                f"serve/{arch}/paged/users={n_users}/page={ps}", tps, derived))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--users", type=int, nargs="+", default=[4, 16])
    ap.add_argument("--page-sizes", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--cold", action="store_true",
                    help="include compile time in the measurement")
    args = ap.parse_args(argv)
    print("name,tokens_per_s,derived")
    for name, tps, derived in sweep(args.arch, args.users, args.page_sizes,
                                    args.max_tokens, args.cache_len,
                                    baseline=not args.no_baseline,
                                    warm=not args.cold):
        print(f"{name},{tps:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
