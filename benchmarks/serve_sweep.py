"""Serving-engine sweep — the Figs. 4/5 protocol at serving time.

The paper sweeps (Nproc × Nthread) at constant memory and shows that one
set of system settings keeps every factorization near peak.  The serving
analogue sweeps (concurrent users × prompt-length mix × page size) through
``serve.ServeEngine`` and scores measured tokens/s four ways:

- against the seed engine (``serve.reference.ReferenceEngine``, batch-1
  sequential prefill) on identical traffic — the speedup column;
- **ragged vs chunked** — the same traffic through the single-program
  ragged token-budget engine and the PR 1 two-phase engine
  (``ragged=False``), the serving analogue of one-configuration-for-all
  (Nproc × Nthread) vs per-point retuning;
- against the analytic mixed roofline (``core.roofline.mixed_bound``) at
  the tick's decode/prefill blend — the fraction-of-bound column;
- **p50 decode latency under concurrent prefill** — a chat+document
  workload in which long prompts stream through the slots while short chats
  decode; the two-phase engine stalls every decoder for the length of each
  prefill burst, the ragged engine packs decode tokens into every tick.
- **prefix-cache on/off under continuous Poisson arrivals** — the paper's
  cache-mode experiment at serving time: requests sharing one system
  prompt arrive per-tick (exponential gaps, driven through the public
  ``ServeEngine.tick`` API rather than batch drain), and the refcounted
  prefix cache serves the warm prefix from resident pages instead of
  re-prefilling it.  Reports tokens/s sharing-on vs sharing-off plus
  ``prefix_hit_rate`` / ``tokens_reused``, and checks greedy outputs stay
  token-identical to the seed reference engine.
- **tiered KV cache A/B (drop-on-evict vs host-tier)** — the paper's
  cache-mode experiment applied to the page pool itself: a prefix working
  set larger than the device pool is replayed warm; the untiered arm
  re-prefills every evicted prefix, the tiered arm promotes demoted pages
  back from the host tier.  Reports replay tokens/s per arm, the
  device/host/miss admission split, pages promoted, and the token-identity
  check (tiering moves bytes, never changes them).  ``--tiered-only`` runs
  just this scenario (the CI tiered-smoke job).
- **speculative decoding A/B (spec-off vs prompt-lookup drafts)** — the
  repetitive code/doc-completion workload: tiled-pattern prompts whose
  greedy continuations the n-gram drafter predicts almost perfectly, so
  the spec-on arm verifies ``spec_k`` draft tokens per decoding slot in
  the same one-trace (T,) pack and emits >1 accepted token per slot-tick.
  Reports per arm tokens/s + the draft ledger, ``accepted_per_tick``,
  token-identity of greedy transcripts (verification is exact), and the
  page-leak gate after a cancel-mid-draft wave.  ``--spec-only`` runs just
  this scenario (the CI spec-smoke job).
- **preemption A/B (preempt vs admission-stall)** — the graceful-
  degradation experiment: an overload wave of deadline-bound interactive
  chats arrives while long batch hogs fill an undersized pool exactly.
  The stall arm queues the chats behind the hogs (tight deadlines expire
  un-served); the preempt arm parks a hog's private KV to the host tier,
  serves the chat inside its deadline, and resumes the hog token-
  identically.  Reports per arm SLO goodput (deadline-met interactive
  tokens/s), p50 interactive latency, and the preempt/resume ledger; gates
  transcripts identical to an unconstrained run, zero leaked pages on both
  tiers, one trace, and preempt goodput >= 1.2x stall.  A fault-injected
  chaos sub-run (``serve.chaos.FaultInjector``) holds the same no-leak +
  identity line off the happy path.  ``--preempt-only`` runs just this
  scenario (the CI chaos-smoke job).
- **fp32-vs-int8 KV pool A/B at a fixed page-pool BYTE budget** — the
  quantized-working-set experiment: both arms get the same pool bytes, so
  the int8 arm holds 2-4× the resident pages and admits more concurrent
  decoders on decode-heavy traffic (throughput + greedy top-1 agreement +
  p50 decode gap + max-resident-pages per arm), plus a warm-prefix pass
  on the int8 pool (hits must stay token-identical to the int8 cold path
  — quantize-at-write means a cached page replays exactly).
- **scheduler A/B (fifo vs prefix-aware vs slo)** — the pluggable-policy
  experiment on mixed shared-prefix Poisson traffic: three document
  families (long shared prefix each, batch class) interleaved with
  interactive chats, through a page pool deliberately too small to keep
  every family's prefix resident.  FIFO's arrival order ping-pongs the
  cache between families (evictions, cold re-prefill); the prefix-aware
  window groups each family into the same admission wave so its prefix is
  reused while resident; the slo policy admits and packs the interactive
  class first.  Reports per policy: tokens/s, prefix tokens reused,
  evictions, packed tokens, and p50/p99 interactive token latency (mean
  wall time per emitted token since submit, per interactive request — the
  queue-jump metric).  CI gates prefix-aware ≥ fifo tokens/s and slo p50
  interactive latency ≤ fifo.

The JSON payload also records ``tuned_serving_config`` — the single
(token_budget, prefill_chunk, page_size, kv_dtype, scheduler) point that
``core.autotune.select_serve_defaults`` picks from the analytic roofline
sweep ("set it once system-wide", memory representation and scheduling
policy included).

  PYTHONPATH=src python benchmarks/serve_sweep.py [--arch qwen2-1.5b]
      [--users 4 16] [--page-sizes 8 32] [--max-tokens 8] [--no-baseline]
      [--smoke] [--json BENCH_serve.json]

CSV: name,tokens_per_s,derived  (derived = ×-over-seed / ×-over-chunked /
%-of-bound / latency ratio / prefix hit rate).  ``--json`` additionally
writes the rows + latency + prefix-scenario results machine-readably (the
perf trajectory lives in BENCH_serve.json at the repo root).
"""
import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.roofline import mixed_bound
from repro.models import model as M
from repro.serve.chaos import FaultInjector
from repro.serve.engine import ServeEngine, kv_page_bytes
from repro.serve.errors import Cancelled, DeadlineExceeded
from repro.serve.reference import ReferenceEngine

# mixed-length mix: short chat turns + a few long-context stragglers
# (fractions of cache budget available for the prompt)
MIX = (0.15, 0.7, 0.3, 0.15, 0.5, 0.9, 0.2, 0.4)


def _traffic(cfg, n_users: int, prompt_budget: int, max_tokens: int, seed=0):
    rng = np.random.RandomState(seed)
    prompts = []
    for i in range(n_users):
        L = max(4, int(MIX[i % len(MIX)] * prompt_budget))
        prompts.append(rng.randint(0, cfg.vocab_size, L))
    return prompts


def _run(engine, prompts, max_tokens: int):
    uids = [engine.submit(p, max_tokens=max_tokens) for p in prompts]
    t0 = time.perf_counter()
    results = engine.run()
    dt = time.perf_counter() - t0
    n_tok = sum(len(results[u]) for u in uids)
    assert all(len(results[u]) == max_tokens for u in uids)
    return n_tok / dt, results


def _p50_token_gap_ms(eng, skip: int = 0,
                      under_prefill_only: bool = False) -> float:
    """p50 wall-time gap between consecutive tokens of one request.

    ``under_prefill_only`` counts only gaps spanning >= 1 tick with
    outstanding prefill work (decode latency UNDER CONCURRENT PREFILL — the
    head-of-line metric); ``skip`` drops log entries from a warmup run on
    the same engine (the kv-dtype A/B warms in place)."""
    last = {}
    gaps = []
    for uid, tick, t in eng.token_log[skip:]:
        if uid in last:
            t0, tick0 = last[uid]
            if (not under_prefill_only
                    or any(hp for hp, _ in eng.tick_log[tick0 + 1:tick + 1])):
                gaps.append(t - t0)
        last[uid] = (t, tick)
    return float(np.median(gaps) * 1e3) if gaps else float("nan")


def latency_scenario(cfg, params, *, cache_len: int, warm: bool = True):
    """Chat + document stream: 2 short chats decode continuously while long
    prompts churn through the other slots.  Returns per-engine p50 decode
    latency (ms) under concurrent prefill, plus tokens/s on the workload."""
    chat_len, chat_toks = 8, 24
    doc_len, doc_toks, n_docs = int(cache_len * 0.85), 2, 6
    rng = np.random.RandomState(11)
    chats = [rng.randint(0, cfg.vocab_size, chat_len) for _ in range(2)]
    docs = [rng.randint(0, cfg.vocab_size, doc_len) for _ in range(n_docs)]

    out = {}
    for mode in ("chunked", "ragged"):
        def make():
            return ServeEngine(params, cfg, batch_size=4, cache_len=cache_len,
                               page_size=16, prefill_chunk=32,
                               token_budget=128, ragged=(mode == "ragged"))

        def drive(eng):
            uids = ([eng.submit(p, max_tokens=chat_toks) for p in chats]
                    + [eng.submit(p, max_tokens=doc_toks) for p in docs])
            t0 = time.perf_counter()
            results = eng.run()
            dt = time.perf_counter() - t0
            return sum(len(results[u]) for u in uids) / dt

        if warm:
            drive(make())
        eng = make()
        tps = drive(eng)
        out[mode] = {"p50_decode_ms_under_prefill": _p50_token_gap_ms(
                         eng, under_prefill_only=True),
                     "tokens_per_s": tps,
                     "ticks": eng.stats["ticks"]}
    return out


def prefix_scenario(cfg, params, *, cache_len: int, n_requests: int = 12,
                    rate: float = 1.5, max_tokens: int = 4, seed: int = 13,
                    check_reference: bool = True):
    """Shared-system-prompt serving under continuous per-tick arrivals.

    ``n_requests`` requests — one long shared system prompt plus a short
    unique user suffix each — arrive with exponential inter-arrival gaps
    (a Poisson process at ``rate`` requests/tick), submitted mid-flight
    through ``ServeEngine.tick``.  Each engine is driven twice: the first
    pass compiles and (for prefix-on) populates the cache, the second is
    the measured warm run — the steady state of a long-running server.

    Returns {"prefix-on": {...}, "prefix-off": {...}, "speedup",
    "token_identical"} with per-mode tokens/s and cache counters.
    """
    rng = np.random.RandomState(seed)
    sys_prompt = rng.randint(0, cfg.vocab_size, int(cache_len * 0.75))
    prompts = [np.concatenate([sys_prompt,
                               rng.randint(0, cfg.vocab_size,
                                           rng.randint(3, 9))])
               for _ in range(n_requests)]
    arrive_tick = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, size=n_requests))).astype(int)

    out = {}
    outputs = {}
    for mode in ("prefix-off", "prefix-on"):
        eng = ServeEngine(params, cfg, batch_size=4, cache_len=cache_len,
                          page_size=16, prefill_chunk=32, token_budget=128,
                          prefix_cache=(mode == "prefix-on"))

        def drive():
            uids, done, i, tick = [], {}, 0, 0
            t0 = time.perf_counter()
            while i < n_requests or not eng.idle:
                while i < n_requests and arrive_tick[i] <= tick:
                    uids.append(eng.submit(prompts[i],
                                           max_tokens=max_tokens))
                    i += 1
                done.update(eng.tick())
                tick += 1
                assert tick < 100_000, "prefix scenario failed to drain"
            dt = time.perf_counter() - t0
            n_tok = sum(len(done[u]) for u in uids)
            assert all(len(done[u]) == max_tokens for u in uids)
            return n_tok / dt, [done[u] for u in uids]

        drive()  # cold: compile + populate the prefix cache
        before = dict(eng.stats)
        tps, outputs[mode] = drive()  # measured warm run
        adm = eng.stats["admissions"] - before["admissions"]
        hits = eng.stats["prefix_hits"] - before["prefix_hits"]
        out[mode] = {
            "tokens_per_s": tps,
            "prefix_hit_rate": hits / max(adm, 1),
            "tokens_reused": (eng.stats["prefix_tokens_reused"]
                              - before["prefix_tokens_reused"]),
            "cow_copies": eng.stats["cow_copies"] - before["cow_copies"],
            "evictions": eng.stats["evictions"] - before["evictions"],
            "cached_pages": eng.cached_pages,
            "ticks": eng.stats["ticks"] - before["ticks"],
            "traces": eng.stats["traces"],
        }
    identical = outputs["prefix-on"] == outputs["prefix-off"]
    if check_reference:  # greedy identity against the seed engine, solo
        ref = ReferenceEngine(params, cfg, batch_size=1, cache_len=cache_len)
        ref_uids = [ref.submit(p, max_tokens=max_tokens) for p in prompts]
        want = ref.run(max_ticks=8192)
        identical &= outputs["prefix-on"] == [want[u] for u in ref_uids]
    return {**out,
            "speedup": (out["prefix-on"]["tokens_per_s"]
                        / out["prefix-off"]["tokens_per_s"]),
            "token_identical": bool(identical)}


def scheduler_ab_scenario(cfg, params, *, cache_len: int = 256,
                          n_families: int = 3, family_size: int = 4,
                          n_chats: int = 6, rate: float = 1.2,
                          seed: int = 19, warm: bool = True):
    """fifo vs prefix-aware vs slo on mixed shared-prefix Poisson traffic.

    Traffic: ``n_families`` document families — one long shared prefix (10
    full pages) plus a short unique suffix per request, batch class
    (priority 0, ``max_tokens=4``) — interleaved round-robin so consecutive
    arrivals belong to DIFFERENT families, plus ``n_chats`` interactive
    chats (6-token prompts, priority 1, ``max_tokens=3``) spread through
    the stream.  Arrivals are Poisson (``rate`` requests/tick) driven
    through ``ServeEngine.tick``.  The page pool is sized so roughly half
    the family prefixes fit at once: under FIFO the interleaved families
    evict each other's cached prefix before the next sibling arrives
    (cold re-prefill every time), the prefix-aware window groups a family
    into consecutive admissions so its prefix is reused while resident,
    and slo admits/packs the interactive class first.

    Interactive token latency is the queue-jump metric: per interactive
    request, (last token wall time - submit wall time) / tokens emitted —
    time-to-first-token and inter-token gaps folded into one number that a
    policy can only improve by actually admitting the chat sooner.

    Returns {"fifo": {...}, "prefix-aware": {...}, "slo": {...},
    "prefix_aware_speedup", "slo_p50_latency_ratio", "token_identical"}.
    """
    rng = np.random.RandomState(seed)
    page = 16
    prefix_pages = 10
    fams = [rng.randint(0, cfg.vocab_size, prefix_pages * page)
            for _ in range(n_families)]
    docs = [(f, np.concatenate([fams[f],
                                rng.randint(0, cfg.vocab_size,
                                            rng.randint(5, 9))]))
            for _ in range(family_size) for f in range(n_families)]
    chats = [rng.randint(0, cfg.vocab_size, 6) for _ in range(n_chats)]
    # interleave: after every len(fams) docs (one per family), one chat
    stream = []
    di = ci = 0
    while di < len(docs) or ci < len(chats):
        for _ in range(n_families):
            if di < len(docs):
                stream.append(("doc", docs[di][1]))
                di += 1
        if ci < len(chats):
            stream.append(("chat", chats[ci]))
            ci += 1
    arrive_tick = np.floor(np.cumsum(
        rng.exponential(1.0 / rate, size=len(stream)))).astype(int)
    # pool: one live wave (2 slots × 11-page doc footprint) plus ~ONE cached
    # family prefix — too small for every family to stay resident
    max_pages = 2 * (prefix_pages + 1) + 2

    out = {}
    outputs = {}
    for sched in ("fifo", "prefix-aware", "slo"):
        eng = ServeEngine(params, cfg, batch_size=2, cache_len=cache_len,
                          page_size=page, prefill_chunk=32, token_budget=128,
                          max_pages=max_pages, scheduler=sched)
        if warm:  # compile outside the measurement, then forget the pages
            eng.submit(rng.randint(0, cfg.vocab_size, 20), max_tokens=2)
            eng.run()
            eng.drop_prefix_cache()
        before = dict(eng.stats)
        skip = len(eng.token_log)
        submit_t = {}
        kinds = {}
        done = {}
        uids = []
        i, tick = 0, 0
        t0 = time.perf_counter()
        while i < len(stream) or not eng.idle:
            while i < len(stream) and arrive_tick[i] <= tick:
                kind, prompt = stream[i]
                h = eng.submit(prompt,
                               max_tokens=3 if kind == "chat" else 4,
                               priority=1 if kind == "chat" else 0)
                submit_t[int(h)] = time.perf_counter()
                kinds[int(h)] = kind
                uids.append(int(h))
                i += 1
            done.update(eng.tick())
            tick += 1
            assert tick < 100_000, "scheduler scenario failed to drain"
        dt = time.perf_counter() - t0
        n_tok = sum(len(done[u]) for u in uids)
        # per-interactive-request mean wall time per emitted token, from
        # submit (admission wait + prefill + decode gaps in one number)
        last_t = {}
        n_seen = {}
        for uid, _, t in eng.token_log[skip:]:
            last_t[uid] = t
            n_seen[uid] = n_seen.get(uid, 0) + 1
        lat = [(last_t[u] - submit_t[u]) / n_seen[u] * 1e3
               for u in uids if kinds[u] == "chat"]
        outputs[sched] = [done[u] for u in uids]
        out[sched] = {
            "tokens_per_s": n_tok / dt,
            "p50_interactive_ms": float(np.percentile(lat, 50)),
            "p99_interactive_ms": float(np.percentile(lat, 99)),
            "packed_tokens": eng.stats["packed_tokens"]
                             - before["packed_tokens"],
            "prefix_tokens_reused": eng.stats["prefix_tokens_reused"]
                                    - before["prefix_tokens_reused"],
            "evictions": eng.stats["evictions"] - before["evictions"],
            "prefix_hits": eng.stats["prefix_hits"] - before["prefix_hits"],
            "ticks": eng.stats["ticks"] - before["ticks"],
            "traces": eng.stats["traces"],
        }
    # greedy outputs are schedule-invariant: a request's tokens depend only
    # on its prompt (prefix reuse is exact), never on admission order
    identical = (outputs["fifo"] == outputs["prefix-aware"]
                 == outputs["slo"])
    return {**out,
            "prefix_aware_speedup": (out["prefix-aware"]["tokens_per_s"]
                                     / out["fifo"]["tokens_per_s"]),
            "slo_p50_latency_ratio": (out["slo"]["p50_interactive_ms"]
                                      / out["fifo"]["p50_interactive_ms"]),
            "token_identical": bool(identical)}


def tiered_kv_scenario(cfg, params, *, page_size: int = 8,
                       n_families: int = 3, prefix_pages: int = 6,
                       max_tokens: int = 4, seed: int = 29,
                       warm: bool = True):
    """Tiered KV cache A/B — the paper's cache-vs-flat experiment at
    serving time.

    Traffic: ``n_families`` prompts of ``prefix_pages`` full pages each —
    a prefix working set deliberately LARGER than the device pool — driven
    twice through each arm (cold populate, then the measured warm replay).
    The drop-on-evict arm (``host_pages=0``) loses every prefix to
    allocation pressure before its replay arrives and re-prefills from
    scratch; the tiered arm (``host_pages``>0) demoted those pages to host
    RAM, so every replay is a HOST hit promoted back — only the decode
    ticks remain.

    Reports per arm: replay tokens/s, the admission hit split
    (device / host / miss), pages promoted, tier traffic counters; plus
    ``speedup`` (tiered over drop-on-evict replay tokens/s),
    ``host_hit_rate`` on the tiered replay, and ``token_identical`` across
    arms AND waves (tiering moves bytes, never changes them)."""
    rng = np.random.RandomState(seed)
    prompts = [rng.randint(0, cfg.vocab_size, prefix_pages * page_size)
               for _ in range(n_families)]
    footprint = prefix_pages + -(-max_tokens // page_size)
    # device pool: ONE request's footprint — far below the n_families *
    # prefix_pages working set, so every admission evicts (or demotes) the
    # previous family's whole prefix and an untiered replay always misses
    max_pages = footprint
    cache_len = (prefix_pages + 1) * page_size

    out = {}
    outputs = {}
    for mode, host in (("drop-on-evict", 0),
                       ("host-tier", 2 * n_families * prefix_pages)):
        eng = ServeEngine(params, cfg, batch_size=1, cache_len=cache_len,
                          page_size=page_size, prefill_chunk=page_size,
                          token_budget=32, max_pages=max_pages,
                          host_pages=host)

        def drive():
            uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
            t0 = time.perf_counter()
            results = eng.run()
            dt = time.perf_counter() - t0
            n_tok = sum(len(results[u]) for u in uids)
            assert all(len(results[u]) == max_tokens for u in uids)
            return n_tok / dt, [results[u] for u in uids]

        if warm:  # compile every program (movers included), then forget
            drive()
            drive()
            eng.drop_prefix_cache()
        _, cold_out = drive()  # populate: cold prefill, pressure demotes
        before = dict(eng.stats)
        tps, replay_out = drive()  # measured warm replay
        adm = eng.stats["admissions"] - before["admissions"]
        hits = eng.stats["prefix_hits"] - before["prefix_hits"]
        host_hits = eng.stats["host_hits"] - before["host_hits"]
        outputs[mode] = cold_out + replay_out
        delta = {k: eng.stats[k] - before[k]
                 for k in ("host_pages_promoted", "demotions",
                           "host_evictions", "evictions", "ticks")}
        # replay is a steady state (each wave re-demotes what it promoted,
        # or re-prefills what it dropped): best-of-3 damps wall-clock noise
        for _ in range(2):
            t2, r2 = drive()
            assert r2 == replay_out
            tps = max(tps, t2)
        out[mode] = {
            "tokens_per_s": tps,
            "host_pool_pages": host,
            "replay_admissions": adm,
            "hit_split": {"device": hits - host_hits, "host": host_hits,
                          "miss": adm - hits},
            "host_hit_rate": host_hits / max(adm, 1),
            "pages_promoted": delta["host_pages_promoted"],
            "demotions": delta["demotions"],
            "host_evictions": delta["host_evictions"],
            "evictions": delta["evictions"],
            "ticks": delta["ticks"],
            "traces": eng.stats["traces"],
        }
    identical = (outputs["host-tier"] == outputs["drop-on-evict"]
                 and outputs["host-tier"][:n_families]
                 == outputs["host-tier"][n_families:])
    return {**out,
            "speedup": (out["host-tier"]["tokens_per_s"]
                        / out["drop-on-evict"]["tokens_per_s"]),
            "host_hit_rate": out["host-tier"]["host_hit_rate"],
            "token_identical": bool(identical)}


def speculative_scenario(cfg, params, *, batch_size: int = 4,
                         page_size: int = 8, spec_k: int = 6,
                         pattern_len: int = 6, reps: int = 8,
                         max_tokens: int = 48, seed: int = 31,
                         warm: bool = True):
    """Speculative decoding A/B on the repetitive code/doc-completion
    workload — the prompt-lookup drafter's home turf.

    Traffic: ``batch_size`` prompts, each a short token pattern tiled
    ``reps`` times (the structure of boilerplate code or templated docs).
    A greedy model decoding such a prompt settles into a loop the n-gram
    drafter predicts almost perfectly, so the spec-on arm packs ``spec_k``
    draft tokens per decoding slot into the SAME (T,) budget and accepts
    most of them — more than one emitted token per slot-tick through one
    forward pass per tick, with zero extra traces.

    Reports per arm: tokens/s (best-of-3 warm), ticks, traces, and for the
    spec arm the draft ledger (drafted/accepted/rejected/rollbacks) plus
    ``accepted_per_tick`` — mean emitted tokens per (request, tick) pair
    computed from the measured segment of ``token_log`` (the >1 gate);
    ``speedup`` (spec-on over spec-off tokens/s), ``token_identical``
    (greedy transcripts must match exactly — verification is exact), and
    ``page_leak_free`` after a cancel-mid-draft wave (half the requests
    cancelled while draft chains are in flight, then a full drain)."""
    rng = np.random.RandomState(seed)
    prompts = [np.tile(rng.randint(0, cfg.vocab_size, pattern_len), reps)
               for _ in range(batch_size)]
    prompt_len = pattern_len * reps
    cache_len = prompt_len + max_tokens + 2 * page_size

    out = {}
    outputs = {}
    for mode, k in (("spec-off", 0), ("spec-on", spec_k)):
        eng = ServeEngine(params, cfg, batch_size=batch_size,
                          cache_len=cache_len, page_size=page_size,
                          prefill_chunk=16,
                          token_budget=batch_size * (1 + spec_k) + 16,
                          spec_k=k)

        def drive():
            uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
            log0 = len(eng.token_log)
            t0 = time.perf_counter()
            results = eng.run()
            dt = time.perf_counter() - t0
            n_tok = sum(len(results[u]) for u in uids)
            assert all(len(results[u]) == max_tokens for u in uids)
            slot_ticks = {(uid, tick)
                          for uid, tick, _ in eng.token_log[log0:]}
            return (n_tok / dt, [results[u] for u in uids],
                    n_tok / max(len(slot_ticks), 1))
        if warm:  # compile every program (rollback included), then re-time
            drive()
        before = dict(eng.stats)
        tps, toks, acc_tick = drive()
        delta = {s: eng.stats[s] - before[s]
                 for s in ("ticks", "spec_drafted", "spec_accepted",
                           "spec_rejected", "spec_rollbacks")}
        outputs[mode] = toks
        for _ in range(2):  # best-of-3 damps wall-clock noise
            t2, r2, _ = drive()
            assert r2 == toks
            tps = max(tps, t2)
        # cancel-mid-draft wave: half the requests die while draft chains
        # are in flight; the drain must hand every page back
        handles = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
        for _ in range(3):
            eng.tick()
        for h in handles[::2]:
            h.cancel()
        eng.run()
        leak_free = bool((eng._ref == 0).all()
                         and eng.reclaimable_pages == eng.n_pages)
        out[mode] = {
            "tokens_per_s": tps,
            "spec_k": k,
            "ticks": delta["ticks"],
            "accepted_per_tick": acc_tick,
            "drafted": delta["spec_drafted"],
            "accepted": delta["spec_accepted"],
            "rejected": delta["spec_rejected"],
            "rollbacks": delta["spec_rollbacks"],
            "page_leak_free": leak_free,
            "traces": eng.stats["traces"],
        }
    return {**out,
            "speedup": (out["spec-on"]["tokens_per_s"]
                        / out["spec-off"]["tokens_per_s"]),
            "accepted_per_tick": out["spec-on"]["accepted_per_tick"],
            "token_identical": bool(outputs["spec-on"]
                                    == outputs["spec-off"]),
            "page_leak_free": bool(out["spec-on"]["page_leak_free"]
                                   and out["spec-off"]["page_leak_free"])}


def preemption_scenario(cfg, params, *, page_size: int = 8,
                        n_hogs: int = 2, hog_tokens: int = 48,
                        n_chats: int = 6, chat_tokens: int = 4,
                        tight_deadline: int = 30, loose_deadline: int = 80,
                        seed: int = 37, warm: bool = True,
                        chaos: bool = True):
    """Preemption A/B — an overload wave through an undersized pool.

    Traffic: ``n_hogs`` batch requests (priority 0, long ``hog_tokens``
    decode) sized so their footprints fill the device pool EXACTLY, then an
    interactive wave (priority 1, ``deadline_ticks=`` alternating tight /
    loose) arriving while both slots decode hogs.  The stall arm
    (``preempt=False``) can only queue the chats behind the hogs: every
    tight-deadline chat expires un-served, the loose ones queue-jump only
    once a hog finishes.  The preempt arm parks a hog's private KV to the
    host tier (PR 7 movers), serves the chat inside its deadline, then
    promotes the hog back and finishes it — same transcripts, tokens moved
    not changed.

    Goodput is the SLO metric: interactive tokens delivered WITHIN their
    deadline per second (batch tokens are best-effort and reported in
    ``tokens_per_s``).  Gates: completed transcripts token-identical to an
    unconstrained (big-pool, no-deadline) run in every arm, zero leaked
    pages on both tiers, ``traces == 1`` through preempt/resume cycles,
    preempt goodput >= 1.2x stall, p50 interactive latency no worse than
    the stall arm's.  ``chaos=True`` adds a fault-injected sub-run
    (``serve.chaos.FaultInjector``: alloc failures, random cancels, host
    eviction storms, stalled ticks) holding the same no-leak + identity
    line off the happy path.  ``--preempt-only`` runs just this scenario
    (the CI chaos-smoke job)."""
    rng = np.random.RandomState(seed)
    hog_prompts = [rng.randint(0, cfg.vocab_size, 3 * page_size)
                   for _ in range(n_hogs)]
    chat_prompts = [rng.randint(0, cfg.vocab_size, 6)
                    for _ in range(n_chats)]
    hog_fp = 3 + -(-hog_tokens // page_size)
    # pool: exactly the hogs' footprints — a chat can only enter by
    # preemption (or by waiting a whole hog out)
    max_pages = n_hogs * hog_fp
    cache_len = 3 * page_size + hog_tokens + page_size
    deadlines = [tight_deadline if j % 2 == 0 else loose_deadline
                 for j in range(n_chats)]
    stream = ([(0, "hog", i) for i in range(n_hogs)]
              + [(4 + j, "chat", j) for j in range(n_chats)])

    def make_engine(preempt, fault_injector=None, big=False):
        return ServeEngine(params, cfg, batch_size=2, cache_len=cache_len,
                           page_size=page_size, prefill_chunk=3 * page_size,
                           token_budget=3 * page_size + 8,
                           max_pages=4 * max_pages if big else max_pages,
                           host_pages=2 * max_pages, scheduler="slo",
                           preempt=preempt, fault_injector=fault_injector)

    def drive(eng, with_deadlines=True):
        handles, submit_t = {}, {}
        i, tick = 0, 0
        t0 = time.perf_counter()
        while i < len(stream) or not eng.idle:
            while i < len(stream) and stream[i][0] <= tick:
                _, kind, j = stream[i]
                if kind == "hog":
                    h = eng.submit(hog_prompts[j], max_tokens=hog_tokens)
                else:
                    h = eng.submit(chat_prompts[j], max_tokens=chat_tokens,
                                   priority=1,
                                   deadline_ticks=(deadlines[j]
                                                   if with_deadlines
                                                   else None))
                handles[(kind, j)] = h
                submit_t[(kind, j)] = time.perf_counter()
                i += 1
            eng.tick()
            tick += 1
            assert tick < 100_000, "preemption scenario failed to drain"
        return time.perf_counter() - t0, handles, submit_t

    def completed_of(handles):
        return {k: list(h.request.out_tokens) for k, h in handles.items()
                if h.request.done and h.request.error is None
                and not h.request.cancelled}

    def leak_free(eng):
        pool = eng.pool
        return bool((eng._ref == 0).all()
                    and eng.reclaimable_pages == eng.n_pages
                    and pool.parked_pages == 0
                    and len(pool._host_free) + pool.host_cached_pages
                    == pool.host_pages
                    and set(eng._host_store) == set(pool._host_node))

    # unconstrained reference transcripts: big pool, no deadlines, no
    # pressure — what every request SHOULD say whenever it completes
    ref = make_engine(False, big=True)
    uids = ([ref.submit(p, max_tokens=hog_tokens) for p in hog_prompts]
            + [ref.submit(p, max_tokens=chat_tokens, priority=1)
               for p in chat_prompts])
    res = ref.run()
    expect = {("hog", i): res[uids[i]] for i in range(n_hogs)}
    expect.update({("chat", j): res[uids[n_hogs + j]]
                   for j in range(n_chats)})

    out = {}
    for mode, preempt in (("stall", False), ("preempt", True)):
        eng = make_engine(preempt)
        if warm:  # compile every program (park/unpark movers included)
            drive(eng)
            eng.drop_prefix_cache()
        before = dict(eng.stats)
        skip = len(eng.token_log)
        dt, handles, submit_t = drive(eng)
        completed = completed_of(handles)
        # queue-jump metric over COMPLETED chats only (expired ones never
        # produced a served token): mean wall time per token since submit
        last_t, n_seen = {}, {}
        for uid, _, t in eng.token_log[skip:]:
            last_t[uid] = t
            n_seen[uid] = n_seen.get(uid, 0) + 1
        lat = [(last_t[int(h)] - submit_t[k]) / n_seen[int(h)] * 1e3
               for k, h in handles.items()
               if k[0] == "chat" and k in completed and int(h) in last_t]
        delta = {s: eng.stats[s] - before[s]
                 for s in ("ticks", "preemptions", "resumes",
                           "resume_park_hits", "resume_reprefills",
                           "preempt_pages_parked", "deadline_expired")}
        out[mode] = {
            "goodput_tokens_per_s": sum(
                len(v) for k, v in completed.items()
                if k[0] == "chat") / dt,
            "tokens_per_s": sum(len(v) for v in completed.values()) / dt,
            "interactive_completed": sum(1 for k in completed
                                         if k[0] == "chat"),
            "interactive_expired": sum(
                1 for h in handles.values()
                if isinstance(h.request.error, DeadlineExceeded)),
            "p50_interactive_ms": (float(np.percentile(lat, 50))
                                   if lat else float("nan")),
            **delta,
            "traces": eng.stats["traces"],
            "token_identical": bool(all(completed[k] == expect[k]
                                        for k in completed)),
            "page_leak_free": leak_free(eng),
        }

    result = {**out,
              "goodput_ratio": (out["preempt"]["goodput_tokens_per_s"]
                                / max(out["stall"]["goodput_tokens_per_s"],
                                      1e-9)),
              "p50_interactive_ratio": (out["preempt"]["p50_interactive_ms"]
                                        / out["stall"]["p50_interactive_ms"]),
              "token_identical": bool(out["preempt"]["token_identical"]
                                      and out["stall"]["token_identical"]),
              "page_leak_free": bool(out["preempt"]["page_leak_free"]
                                     and out["stall"]["page_leak_free"])}
    if chaos:
        fi = FaultInjector(seed=5, p_alloc_fail=0.15, p_cancel=0.05,
                           p_evict_storm=0.1, p_stall=0.1)
        eng = make_engine(True, fault_injector=fi)
        before = dict(eng.stats)
        _, handles, _ = drive(eng)
        completed = completed_of(handles)
        result["chaos"] = {
            "completed": len(completed),
            "cancelled": sum(isinstance(h.request.error, Cancelled)
                             for h in handles.values()),
            "expired": sum(isinstance(h.request.error, DeadlineExceeded)
                           for h in handles.values()),
            "faults_injected": len(fi.log),
            **{s: eng.stats[s] - before[s]
               for s in ("chaos_alloc_fails", "chaos_cancels",
                         "chaos_evict_storms", "chaos_stalled_ticks",
                         "preemptions", "resumes")},
            "traces": eng.stats["traces"],
            "token_identical": bool(all(completed[k] == expect[k]
                                        for k in completed)),
            "page_leak_free": leak_free(eng),
        }
        result["token_identical"] = bool(
            result["token_identical"]
            and result["chaos"]["token_identical"])
        result["page_leak_free"] = bool(
            result["page_leak_free"]
            and result["chaos"]["page_leak_free"])
    return result


def _spec_rows(arch, spec):
    rows = []
    for mode in ("spec-off", "spec-on"):
        r = spec[mode]
        rows.append((f"serve/{arch}/speculative/{mode}", r["tokens_per_s"],
                     f"spec_k={r['spec_k']},ticks={r['ticks']},"
                     f"accepted_per_tick={r['accepted_per_tick']:.2f},"
                     f"accepted={r['accepted']},rejected={r['rejected']}"))
    rows.append((f"serve/{arch}/speculative/speedup", spec["speedup"],
                 f"x-over-spec-off,"
                 f"accepted_per_tick={spec['accepted_per_tick']:.2f},"
                 f"token_identical={str(spec['token_identical']).lower()},"
                 f"page_leak_free={str(spec['page_leak_free']).lower()}"))
    return rows


def _tiered_rows(arch, tiered):
    rows = []
    for mode in ("drop-on-evict", "host-tier"):
        r = tiered[mode]
        split = r["hit_split"]
        rows.append((f"serve/{arch}/tiered/{mode}", r["tokens_per_s"],
                     f"host_pool_pages={r['host_pool_pages']},"
                     f"hit_split=d{split['device']}/h{split['host']}"
                     f"/m{split['miss']},promoted={r['pages_promoted']}"))
    rows.append((f"serve/{arch}/tiered/speedup", tiered["speedup"],
                 f"x-over-drop-on-evict,"
                 f"host_hit_rate={tiered['host_hit_rate']:.2f},"
                 "token_identical="
                 + str(tiered["token_identical"]).lower()))
    return rows


def _preempt_rows(arch, pre):
    rows = []
    for mode in ("stall", "preempt"):
        r = pre[mode]
        rows.append((f"serve/{arch}/preemption/{mode}",
                     r["goodput_tokens_per_s"],
                     f"interactive_completed={r['interactive_completed']},"
                     f"expired={r['interactive_expired']},"
                     f"preemptions={r['preemptions']},"
                     f"resumes={r['resumes']},"
                     f"p50_interactive_ms={r['p50_interactive_ms']:.1f}"))
    ch = pre.get("chaos")
    rows.append((f"serve/{arch}/preemption/goodput_ratio",
                 pre["goodput_ratio"],
                 f"x-over-stall,"
                 f"token_identical={str(pre['token_identical']).lower()},"
                 f"page_leak_free={str(pre['page_leak_free']).lower()},"
                 f"chaos_faults={ch['faults_injected'] if ch else 0}"))
    return rows


def kv_ab_scenario(cfg, params, *, cache_len: int = 64, batch_size: int = 8,
                   page_size: int = 8, seed: int = 17, warm: bool = True):
    """fp32-vs-int8 paged-pool A/B at a FIXED page-pool byte budget.

    Both arms serve identical decode-heavy traffic (short prompts, long
    generations — the regime where per-token KV page reads dominate) with
    the same pool BYTES: the fp32 arm gets pages for ~2 in-flight requests,
    the int8 arm gets however many pages the same bytes buy (~2-4× more,
    scale rows included).  More resident pages means more concurrently
    decoding slots per tick, so int8 throughput beats fp32 at equal bytes —
    the serving analogue of the paper's result that fitting the working set
    in fast memory, not adding compute, is what moves the bound.

    Returns per-grid-point rows {"users", "max_tokens", "fp32": {...},
    "int8": {...}, "top1_agreement", "speedup"} plus a prefix-on-int8
    warm-path check (cached int8 pages must replay token-identically).
    """
    rng = np.random.RandomState(seed)
    # decode-heavy grid: many users, short prompts, generations dominate
    grid = [(batch_size, 24), (batch_size + 2, 16)]
    max_prompt = 16  # prompt lengths drawn from [8, max_prompt]
    # byte budget = pages for ~2 in-flight WORST-CASE requests at fp32: the
    # fp32 arm is page-starved (the premise of the A/B), the int8 arm gets
    # the same bytes' worth of pages
    footprint = -(-(max_prompt + max(mt for _, mt in grid)) // page_size)
    fp32_pages = 2 * footprint
    budget_bytes = fp32_pages * kv_page_bytes(cfg, page_size, "float32")
    int8_pages = budget_bytes // max(
        kv_page_bytes(cfg, page_size, "int8"), 1)

    def run(kv_dtype, n_pages, prompts, max_tokens):
        eng = ServeEngine(params, cfg, batch_size=batch_size,
                          cache_len=cache_len, page_size=page_size,
                          prefill_chunk=16, token_budget=max(32, batch_size),
                          prefix_cache=False, max_pages=n_pages,
                          kv_dtype=kv_dtype)
        if warm:  # jit caches are per-engine-instance: warm THIS instance
            _run(eng, prompts, max_tokens)
        skip = len(eng.token_log)
        tps, results = _run(eng, prompts, max_tokens)
        return eng, tps, results, skip

    points = []
    for n_users, max_tokens in grid:
        prompts = [rng.randint(0, cfg.vocab_size, int(L))
                   for L in rng.randint(8, max_prompt + 1, size=n_users)]
        point = {"users": n_users, "max_tokens": max_tokens}
        outs = {}
        for kvd, n_pages in (("float32", fp32_pages), ("int8", int8_pages)):
            eng, tps, results, skip = run(kvd, n_pages, prompts, max_tokens)
            outs[kvd] = [tok for u in sorted(results) for tok in results[u]]
            point[kvd if kvd == "int8" else "fp32"] = {
                "tokens_per_s": tps,
                "p50_decode_gap_ms": _p50_token_gap_ms(eng, skip=skip),
                "max_resident_pages": eng.n_pages,
                "pages_in_use_peak": eng.stats["pages_in_use_peak"],
                "kv_bytes_per_token": eng.stats["kv_bytes_per_token"],
                "kv_pool_bytes": eng.stats["kv_pool_bytes"],
            }
        n_match = sum(a == b for a, b in zip(outs["float32"], outs["int8"]))
        point["top1_agreement"] = n_match / max(len(outs["float32"]), 1)
        point["speedup"] = (point["int8"]["tokens_per_s"]
                            / point["fp32"]["tokens_per_s"])
        points.append(point)

    # warm-path identity on the int8 pool: a prefix hit maps cached int8
    # pages + scale rows into the new slot — byte-identical replay of the
    # cold path (quantize-at-write), so outputs must match exactly
    shared = rng.randint(0, cfg.vocab_size, 4 * page_size)
    warm_prompts = [np.concatenate([shared, rng.randint(0, cfg.vocab_size, 5)])
                    for _ in range(2)]
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=cache_len,
                      page_size=page_size, prefill_chunk=16, token_budget=32,
                      kv_dtype="int8")
    u_cold = [eng.submit(p, max_tokens=4) for p in warm_prompts]
    cold = eng.run()
    u_warm = [eng.submit(p, max_tokens=4) for p in warm_prompts]
    warm_r = eng.run()
    prefix = {
        "prefix_hits": eng.stats["prefix_hits"],
        "tokens_reused": eng.stats["prefix_tokens_reused"],
        "warm_identical": ([cold[u] for u in u_cold]
                           == [warm_r[u] for u in u_warm]),
    }
    return {
        "byte_budget": int(budget_bytes),
        "pages": {"float32": int(fp32_pages), "int8": int(int8_pages)},
        "points": points,
        "min_top1_agreement": min(p["top1_agreement"] for p in points),
        "prefix_int8": prefix,
    }


# Subprocess driver for one device count of the sharded-serve scenario:
# decode-heavy traffic (short prompts, long generations — the KV-dominated
# regime KV-head TP targets) through a meshed engine on N forked fake
# devices.  Reports measured wall tokens/s, the HLO-walked per-device cost
# of the compiled ragged step, and the projected tokens/s those costs give
# on the target part (repo convention — see core/roofline.py: this
# container is CPU-only and single-core, so cross-device-count speedups are
# derived from compiled artifacts, not wall time), plus the transcript for
# the token-identity check.
_SHARDED_DRIVER = """
import json, sys
import jax, numpy as np
from repro.configs import get_config
from repro.core import hlo_cost
from repro.core.roofline import V5E
from repro.models import model as M
from repro.serve.engine import ServeEngine

n_dev, arch, batch, cache_len, max_tokens = (
    int(sys.argv[1]), sys.argv[2], int(sys.argv[3]), int(sys.argv[4]),
    int(sys.argv[5]))
cfg = get_config(arch, smoke=True)
params = M.init_params(jax.random.PRNGKey(0), cfg)
kw = dict(batch_size=batch, cache_len=cache_len, page_size=16,
          prefill_chunk=16, token_budget=max(32, batch))
if n_dev > 1:
    from repro.launch.mesh import make_mesh
    kw["mesh"] = make_mesh((n_dev,), ("model",))
eng = ServeEngine(params, cfg, **kw)
rng = np.random.RandomState(23)
prompts = [rng.randint(0, cfg.vocab_size, int(L))
           for L in rng.randint(6, 14, size=batch + 2)]

import time
uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
eng.run()  # warm: compile outside the measurement
uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
t0 = time.perf_counter()
results = eng.run()
dt = time.perf_counter() - t0
n_tok = sum(len(results[u]) for u in uids)

# per-device cost of the ONE compiled ragged step, walked loop-aware from
# its post-SPMD HLO; projected throughput = decode tokens per tick over the
# per-device roofline time on the target hw
T, B = eng.budget, eng.B
pack = (np.zeros(T, np.int32), np.zeros(T, np.int32), np.zeros(T, np.int32),
        np.zeros(T, np.int32), np.zeros(T, bool), np.zeros(B, np.int32))
with eng._ctx():
    lowered = eng._ragged_step.lower(eng.params, eng._state, *pack)
walked = hlo_cost.analyze(lowered.compile().as_text())
tick_s = max(walked["flops"] / V5E.peak_flops,
             walked["traffic_bytes"] / V5E.hbm_bw)
print("RESULT " + json.dumps({
    "n_devices": n_dev,
    "measured_tokens_per_s": n_tok / dt,
    "per_device_flops": walked["flops"],
    "per_device_bytes": walked["traffic_bytes"],
    "projected_tokens_per_s": B / tick_s,
    "kv_shards": eng.stats["kv_shards"],
    "transcript": sorted((int(k), list(v)) for k, v in results.items()),
}))
"""


def sharded_serve_scenario(arch: str = "qwen1.5-4b", device_counts=(1, 2, 4),
                           batch: int = 4, cache_len: int = 256,
                           max_tokens: int = 24, timeout: int = 1200):
    """KV-head tensor-parallel serving across forked device counts.

    Each device count runs in its own subprocess (scrubbed env +
    ``--xla_force_host_platform_device_count=N`` — the parent process must
    keep one device).  ``projected_speedup`` compares the HLO-walked
    per-device roofline projection of the compiled ragged step at N devices
    vs 1 — the CI gate (>= 1.5x at 4) — because this single-core container
    cannot show wall-clock parallel speedup; measured wall tokens/s ride
    along for honesty.  ``token_identical`` asserts the engine contract:
    identical transcripts at every device count.  qwen1.5-4b smoke is the
    default arch (its kvH = 4 shards 4 ways; qwen2-1.5b's kvH = 2 cannot).
    """
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    per = {}
    for n in device_counts:
        env = {k: v for k, v in os.environ.items()
               if not k.startswith(("XLA_", "JAX_", "LIBTPU", "TPU_"))}
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-c", _SHARDED_DRIVER, str(n), arch, str(batch),
             str(cache_len), str(max_tokens)],
            capture_output=True, text=True, timeout=timeout, env=env)
        if proc.returncode != 0:
            raise RuntimeError(f"sharded serve subprocess (n={n}) failed:\n"
                               f"{proc.stdout}\n{proc.stderr}")
        line = [l for l in proc.stdout.splitlines()
                if l.startswith("RESULT ")][-1]
        per[n] = json.loads(line[len("RESULT "):])
    base = per[device_counts[0]]
    identical = all(r["transcript"] == base["transcript"]
                    for r in per.values())
    top = per[max(device_counts)]
    return {
        "arch": arch,
        "device_counts": list(device_counts),
        "per_device_count": {str(n): {k: v for k, v in r.items()
                                      if k != "transcript"}
                             for n, r in per.items()},
        "projected_speedup": (top["projected_tokens_per_s"]
                              / base["projected_tokens_per_s"]),
        "measured_speedup": (top["measured_tokens_per_s"]
                             / base["measured_tokens_per_s"]),
        "token_identical": bool(identical),
    }


def sweep(arch: str, users, page_sizes, max_tokens: int, cache_len: int,
          baseline: bool = True, warm: bool = True):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    for n_users in users:
        prompts = _traffic(cfg, n_users, cache_len - max_tokens, max_tokens)
        batch = min(n_users, 8)
        ref_tps = None
        if baseline:
            ref = ReferenceEngine(params, cfg, batch_size=batch,
                                  cache_len=cache_len)
            if warm:  # jit caches are per-engine-instance: warm then re-time
                _run(ref, prompts, max_tokens)
            ref_tps, _ = _run(ref, prompts, max_tokens)
            rows.append((f"serve/{arch}/seed/users={n_users}", ref_tps, ""))
        for ps in page_sizes:
            mean_ctx = int(np.mean([len(p) for p in prompts]) + max_tokens)
            # the bound's blend mirrors what the engine can actually pack in
            # one tick: batch decode tokens + prefill up to the 128 budget
            bound = mixed_bound(cfg, n_decode=batch,
                                n_prefill=min(32 * batch, 128 - batch),
                                context_len=mean_ctx,
                                page_size=ps)["tokens_per_s"]
            tps = {}
            for mode in ("chunked", "ragged"):
                eng_kw = dict(batch_size=batch, cache_len=cache_len,
                              page_size=ps, prefill_chunk=32,
                              token_budget=128, ragged=(mode == "ragged"))
                if warm:  # compile outside the timed run
                    _run(ServeEngine(params, cfg, **eng_kw), prompts,
                         max_tokens)
                tps[mode], _ = _run(ServeEngine(params, cfg, **eng_kw),
                                    prompts, max_tokens)
            derived = (f"{tps['chunked'] / ref_tps:.1f}x-over-seed"
                       if ref_tps else "")
            rows.append((f"serve/{arch}/chunked/users={n_users}/page={ps}",
                         tps["chunked"], derived))
            derived = f"{tps['ragged'] / tps['chunked']:.2f}x-over-chunked"
            if ref_tps:
                derived += f",{tps['ragged'] / ref_tps:.1f}x-over-seed"
            derived += f",{tps['ragged'] / bound:.2e}-of-bound"
            rows.append((f"serve/{arch}/ragged/users={n_users}/page={ps}",
                         tps["ragged"], derived))
    lat = latency_scenario(cfg, params, cache_len=max(cache_len, 256),
                           warm=warm)
    for mode in ("chunked", "ragged"):
        rows.append((f"serve/{arch}/latency/{mode}",
                     lat[mode]["tokens_per_s"],
                     f"p50_decode_ms={lat[mode]['p50_decode_ms_under_prefill']:.1f}"))
    ratio = (lat["chunked"]["p50_decode_ms_under_prefill"]
             / lat["ragged"]["p50_decode_ms_under_prefill"])
    rows.append((f"serve/{arch}/latency/p50-improvement", ratio,
                 "x-lower-p50-decode-under-prefill"))
    pre = prefix_scenario(cfg, params, cache_len=max(cache_len, 256))
    for mode in ("prefix-off", "prefix-on"):
        r = pre[mode]
        rows.append((f"serve/{arch}/prefix/{mode}", r["tokens_per_s"],
                     f"prefix_hit_rate={r['prefix_hit_rate']:.2f},"
                     f"tokens_reused={r['tokens_reused']}"))
    rows.append((f"serve/{arch}/prefix/speedup", pre["speedup"],
                 "x-over-no-sharing,token_identical="
                 + str(pre["token_identical"]).lower()))
    sched_ab = scheduler_ab_scenario(cfg, params,
                                     cache_len=max(cache_len, 256),
                                     warm=warm)
    for sched in ("fifo", "prefix-aware", "slo"):
        r = sched_ab[sched]
        rows.append((f"serve/{arch}/scheduler/{sched}", r["tokens_per_s"],
                     f"p50_interactive_ms={r['p50_interactive_ms']:.1f},"
                     f"reused={r['prefix_tokens_reused']},"
                     f"evictions={r['evictions']}"))
    rows.append((f"serve/{arch}/scheduler/prefix-aware-speedup",
                 sched_ab["prefix_aware_speedup"],
                 "x-over-fifo-tokens-per-s,token_identical="
                 + str(sched_ab["token_identical"]).lower()))
    rows.append((f"serve/{arch}/scheduler/slo-p50-ratio",
                 sched_ab["slo_p50_latency_ratio"],
                 "x-fifo-p50-interactive-latency"))
    tiered = tiered_kv_scenario(cfg, params, warm=warm)
    rows += _tiered_rows(arch, tiered)
    spec = speculative_scenario(cfg, params, warm=warm)
    rows += _spec_rows(arch, spec)
    kv_ab = kv_ab_scenario(cfg, params, warm=warm)
    for p in kv_ab["points"]:
        for arm in ("fp32", "int8"):
            rows.append((
                f"serve/{arch}/kv-ab/{arm}/users={p['users']}"
                f"/max_tokens={p['max_tokens']}",
                p[arm]["tokens_per_s"],
                f"pages={p[arm]['max_resident_pages']},"
                f"p50_decode_gap_ms={p[arm]['p50_decode_gap_ms']:.1f}"))
        rows.append((
            f"serve/{arch}/kv-ab/speedup/users={p['users']}"
            f"/max_tokens={p['max_tokens']}", p["speedup"],
            f"x-int8-over-fp32-at-equal-bytes,"
            f"top1_agreement={p['top1_agreement']:.3f}"))
    return rows, lat, pre, kv_ab, sched_ab, tiered, spec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--users", type=int, nargs="+", default=[4, 16])
    ap.add_argument("--page-sizes", type=int, nargs="+", default=[8, 32])
    ap.add_argument("--max-tokens", type=int, default=8)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--no-baseline", action="store_true")
    ap.add_argument("--cold", action="store_true",
                    help="include compile time in the measurement")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI grid (one user count, one page size)")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the KV-head tensor-parallel scenario "
                         "(forked device counts 1/2/4 on qwen1.5-4b smoke)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="skip the single-device sweep; run only the "
                         "sharded scenario (implies --sharded)")
    ap.add_argument("--tiered-only", action="store_true",
                    help="skip the main sweep; run only the tiered KV "
                         "cache A/B (drop-on-evict vs host-tier replay)")
    ap.add_argument("--spec-only", action="store_true",
                    help="skip the main sweep; run only the speculative "
                         "decoding A/B (spec-off vs spec-on on the "
                         "repetitive completion workload)")
    ap.add_argument("--preempt-only", action="store_true",
                    help="skip the main sweep; run only the preemption "
                         "A/B (preempt vs admission-stall under an "
                         "overload wave) plus the chaos sub-run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows + latency results as JSON")
    args = ap.parse_args(argv)
    if args.smoke:
        args.users, args.page_sizes, args.max_tokens = [4], [8], 4
    if args.sharded_only:
        args.sharded = True
    rows, lat, pre, kv_ab, sched_ab, tiered, spec = (
        [], None, None, None, None, None, None)
    preemption = None
    if args.tiered_only:
        cfg = get_config(args.arch, smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        tiered = tiered_kv_scenario(cfg, params, warm=not args.cold)
        rows = _tiered_rows(args.arch, tiered)
    elif args.spec_only:
        cfg = get_config(args.arch, smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        spec = speculative_scenario(cfg, params, warm=not args.cold)
        rows = _spec_rows(args.arch, spec)
    elif args.preempt_only:
        cfg = get_config(args.arch, smoke=True)
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        preemption = preemption_scenario(cfg, params, warm=not args.cold)
        rows = _preempt_rows(args.arch, preemption)
    elif not args.sharded_only:
        rows, lat, pre, kv_ab, sched_ab, tiered, spec = sweep(
            args.arch, args.users, args.page_sizes, args.max_tokens,
            args.cache_len, baseline=not args.no_baseline, warm=not args.cold)
    sharded = None
    if args.sharded:
        sharded = sharded_serve_scenario()
        for n, r in sharded["per_device_count"].items():
            rows.append((
                f"serve/{sharded['arch']}/sharded/n_devices={n}",
                r["measured_tokens_per_s"],
                f"projected_tokens_per_s={r['projected_tokens_per_s']:.1f},"
                f"kv_shards={r['kv_shards']}"))
        rows.append((
            f"serve/{sharded['arch']}/sharded/projected_speedup"
            f"/{max(sharded['device_counts'])}x-devices",
            sharded["projected_speedup"],
            f"x-roofline-projected,"
            f"token_identical={sharded['token_identical']}"))
    print("name,tokens_per_s,derived")
    for name, tps, derived in rows:
        print(f"{name},{tps:.1f},{derived}", flush=True)
    if args.json:
        from repro.core.autotune import select_serve_defaults

        payload = {
            "arch": args.arch,
            "grid": {"users": args.users, "page_sizes": args.page_sizes,
                     "max_tokens": args.max_tokens,
                     "cache_len": args.cache_len},
            "rows": [{"name": n, "tokens_per_s": t, "derived": d}
                     for n, t, d in rows],
            "latency_under_concurrent_prefill": lat,
            "prefix_scenario": pre,
            "kv_dtype_ab": kv_ab,
            "scheduler_ab": sched_ab,
            "tiered_kv": tiered,
            "speculative": spec,
            "preemption": preemption,
            # host_pool_pages axis prices the tiered point's promotion
            # traffic against untiered re-prefill; the spec_ks axis prices
            # draft-token goodput on the repetitive decode point
            "tuned_serving_config": select_serve_defaults(
                args.arch, smoke=True, host_pool_pages=(0, 64),
                spec_ks=(0, 4))["best"],
        }
        if sharded is not None:
            payload["sharded_serve"] = sharded
            # the TP axis of the tuner, recorded next to the measured scenario
            payload["tuned_serving_config_tp"] = select_serve_defaults(
                sharded["arch"], smoke=True,
                device_counts=tuple(sharded["device_counts"]))["best"]
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")


if __name__ == "__main__":
    main()
