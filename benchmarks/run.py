"""Benchmark harness — one module per paper table/figure.

  fig4_engine_sweep  — Matlab sweep   ≙ XLA engine, Nproc sweep @ const mem
  fig5_engine_sweep  — Octave sweep   ≙ Pallas engine, same protocol
  memory_modes       — 15 MCDRAM/NUMA configs ≙ BlockSpec×accum grid
  pinning            — Fig.3 taskset  ≙ torus placement hop costs

Prints ``name,us_per_call,derived`` CSV.  The derived TPU-pod sweep table
(fig4 derived rows) is read from runs/sweep/results.json — generate it with
``python -m repro.launch.sweep --quick`` (kept out of this process so the
benchmarks see exactly one real device).
"""
from benchmarks import fig4_engine_sweep, fig5_engine_sweep, memory_modes, pinning


def main() -> None:
    print("name,us_per_call,derived")
    for mod in (fig4_engine_sweep, fig5_engine_sweep, memory_modes, pinning):
        for name, us, derived in mod.rows():
            print(f"{name},{us:.1f},{derived}", flush=True)


if __name__ == "__main__":
    main()
