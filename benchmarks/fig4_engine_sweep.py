"""Fig. 4 analogue — XLA-engine matmul over the Nproc sweep at constant
total memory (N = N0/√Nproc), measured wall-clock on this host + the
derived TPU-pod roofline sweep (runs/sweep/results.json if present).

CSV: name,us_per_call,derived   (derived = GFLOP/s measured here, or the
pod-level fraction-of-peak for derived rows)
"""
import json
from pathlib import Path

from repro.core.sweep import measured_gflops

ENGINE = "xla"
N0 = 1536
NPROCS = (1, 2, 4, 8, 16)


def rows():
    out = []
    for nproc in NPROCS:
        r = measured_gflops(ENGINE, nproc, n0=N0)
        out.append((f"fig4/{ENGINE}/measured/nproc={nproc}/N={r['N']}",
                    r["us_per_call"], f"{r['gflops']:.1f}GF/s"))
    sweep = Path("runs/sweep/results.json")
    if sweep.exists():
        for r in json.loads(sweep.read_text()):
            if r["memory"] == "cache":
                out.append((
                    f"fig4/derived/{r['placement']}-{r['memory']}/"
                    f"{r['nproc']}x{r['nthread']}",
                    0.0, f"{r['peak_fraction']:.1%}-of-peak"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
