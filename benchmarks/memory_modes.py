"""Memory-mode table — the 15-configuration MCDRAM/NUMA sweep analogue:
Pallas matmul BlockSpec tilings × accumulation policies.  Measured
wall-clock (interpret mode) at a small shape + derived VMEM working set and
arithmetic intensity per configuration (what governs the real TPU choice).

CSV: name,us_per_call,derived
"""
import time

import jax
import jax.numpy as jnp

from repro.core.memory_modes import tiling_grid
from repro.kernels import ops

M = K = N = 512


def rows():
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), jnp.float32)
    out = []
    for mode in tiling_grid():
        bm, bk, bn = (min(mode.block[0], M), min(mode.block[1], K),
                      min(mode.block[2], N))
        accum = "vmem" if mode.k_splits == 1 else "hbm"
        f = lambda: ops.matmul(a, b, block=(bm, bk, bn), accum=accum)
        jax.block_until_ready(f())
        t0 = time.perf_counter()
        jax.block_until_ready(f())
        us = (time.perf_counter() - t0) * 1e6
        # derived: VMEM working set + arithmetic intensity of one grid step
        flops = 2 * bm * bn * bk
        hbm = (bm * bk + bk * bn) * 2 + (bm * bn * 4 if accum == "hbm" else 0)
        out.append((f"memmode/{mode.name}", us,
                    f"vmem={mode.vmem_bytes()/2**20:.1f}MiB"
                    f";AI={flops/max(hbm,1):.0f}flop/B"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
