"""Fig. 5 analogue — the SECOND engine (Pallas kernel ≙ GNU Octave vs
Matlab): same constant-memory Nproc sweep through the Pallas matmul
(interpret mode on CPU; MXU-tiled on TPU).

CSV: name,us_per_call,derived
"""
from repro.core.sweep import measured_gflops

ENGINE = "pallas"
N0 = 512
NPROCS = (1, 2, 4)


def rows():
    out = []
    for nproc in NPROCS:
        r = measured_gflops(ENGINE, nproc, n0=N0, reps=1)
        out.append((f"fig5/{ENGINE}/measured/nproc={nproc}/N={r['N']}",
                    r["us_per_call"], f"{r['gflops']:.2f}GF/s"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
