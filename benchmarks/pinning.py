"""Fig. 3 analogue — taskset pinning: topology-aware vs naive device order
on the 16×16 ICI torus, scored as ring-hop cost per collective step and the
implied wire-time multiplier for the per-layer TP all-reduce of glm4-9b
train_4k (the most collective-sensitive dense cell).

CSV: name,us_per_call,derived   (us_per_call = derived collective wire time
for one glm4 train step's 'model'-axis collectives)
"""
from repro.core.affinity import (collective_slowdown, naive_placement,
                                 pinned_placement)
from repro.core.roofline import V5E

GLM4_COLL_BYTES = 13.3 * 50e9  # collective_s × link_bw from the dry-run


def rows():
    out = []
    for p in (pinned_placement(), naive_placement()):
        mult = collective_slowdown(p, "model")
        wire_s = GLM4_COLL_BYTES / V5E.ici_bw * mult
        out.append((f"pinning/{p.name}", wire_s * 1e6,
                    f"model-ring={p.axis_ring_cost['model']:.2f}hops"
                    f";data-ring={p.axis_ring_cost['data']:.2f}hops"
                    f";slowdown={mult:.2f}x"))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
