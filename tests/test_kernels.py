"""Per-kernel allclose vs the pure-jnp oracles, over shape/dtype sweeps,
plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dt):
    return dict(rtol=2e-2, atol=2e-2) if dt == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# matmul


@pytest.mark.parametrize("shape", [(64, 64, 64), (128, 96, 32), (100, 130, 70),
                                   (256, 512, 128), (32, 1024, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("accum", ["vmem", "hbm"])
def test_matmul_allclose(shape, dtype, accum):
    M, K, N = shape
    a = jax.random.normal(KEY, (M, K), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    got = ops.matmul(a, b, block=(32, 64, 32), accum=accum)
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("block", [(16, 16, 16), (32, 64, 32), (128, 128, 128)])
def test_matmul_block_invariance(block):
    a = jax.random.normal(KEY, (96, 160), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (160, 64), jnp.float32)
    got = ops.matmul(a, b, block=block)
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), rtol=2e-4, atol=2e-4)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(8, 64), k=st.integers(8, 96), n=st.integers(8, 48))
def test_matmul_linearity(m, k, n):
    """Property: matmul(a, b1 + b2) == matmul(a, b1) + matmul(a, b2)."""
    a = jax.random.normal(KEY, (m, k), jnp.float32)
    b1 = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.float32)
    b2 = jax.random.normal(jax.random.PRNGKey(2), (k, n), jnp.float32)
    lhs = ops.matmul(a, b1 + b2, block=(16, 16, 16))
    rhs = ops.matmul(a, b1, block=(16, 16, 16)) + ops.matmul(a, b2, block=(16, 16, 16))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# flash attention


@pytest.mark.parametrize("S,hd,G,kvH", [(64, 16, 1, 2), (64, 32, 4, 2),
                                        (128, 16, 2, 3)])
@pytest.mark.parametrize("window", [None, 16])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_allclose(S, hd, G, kvH, window, dtype):
    B = 2
    q = jax.random.normal(KEY, (B * kvH * G, S, hd), dtype)
    k = jax.random.normal(jax.random.PRNGKey(2), (B * kvH, S, hd), dtype)
    v = jax.random.normal(jax.random.PRNGKey(3), (B * kvH, S, hd), dtype)
    got = ops.flash_attention(q, k, v, bq=32, bk=32, window=window)
    want = ref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_flash_block_invariance():
    q = jax.random.normal(KEY, (4, 128, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 128, 16), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 128, 16), jnp.float32)
    o1 = ops.flash_attention(q, k, v, bq=16, bk=64)
    o2 = ops.flash_attention(q, k, v, bq=128, bk=16)
    np.testing.assert_allclose(o1, o2, rtol=2e-5, atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(sq=st.sampled_from([32, 64]), hd=st.sampled_from([8, 16]),
       g=st.integers(1, 3))
def test_flash_causality(sq, hd, g):
    """Property: output at position t is unaffected by future K/V."""
    q = jax.random.normal(KEY, (g, sq, hd), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, sq, hd), jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (1, sq, hd), jnp.float32)
    o1 = ops.flash_attention(q, k, v, bq=16, bk=16)
    t = sq // 2
    k2 = k.at[:, t + 1:].set(99.0)
    v2 = v.at[:, t + 1:].set(-99.0)
    o2 = ops.flash_attention(q, k2, v2, bq=16, bk=16)
    np.testing.assert_allclose(o1[:, : t + 1], o2[:, : t + 1],
                               rtol=1e-5, atol=1e-5)


def _paged_decode_ref(q, kp, vp, ptab, lens):
    """jnp oracle: gather the block table, mask by fill count, softmax."""
    B, kvH, G, hd = q.shape
    pps, page = ptab.shape[1], kp.shape[1]
    k = jnp.take(kp, ptab, axis=0, mode="clip").reshape(B, pps * page, kvH, hd)
    v = jnp.take(vp, ptab, axis=0, mode="clip").reshape(B, pps * page, kvH, hd)
    s = jnp.einsum("bkgd,btkd->bkgt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * hd ** -0.5
    mask = jnp.arange(pps * page)[None] < lens[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, -1)
    return jnp.einsum("bkgt,btkd->bkgd", p, v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("page,pps", [(8, 4), (16, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_flash_decode_allclose(page, pps, dtype):
    """Block-table indirection + partial-page masking vs the gather oracle,
    including unmapped sentinel pages and an empty slot."""
    B, kvH, G, hd = 3, 2, 4, 16
    npages = B * pps
    q = jax.random.normal(KEY, (B, kvH, G, hd), dtype)
    kp = jax.random.normal(jax.random.PRNGKey(1), (npages, page, kvH, hd), dtype)
    vp = jax.random.normal(jax.random.PRNGKey(2), (npages, page, kvH, hd), dtype)
    rng = np.random.RandomState(0)
    perm = rng.permutation(npages)
    ptab = np.full((B, pps), npages, np.int32)  # sentinel = unmapped
    lens = np.asarray([pps * page, 1 + page // 2, 0], np.int32)
    for b in range(B):
        used = -(-int(lens[b]) // page)
        ptab[b, :used] = perm[b * pps:b * pps + used]
    got = ops.paged_flash_decode(q, kp, vp, jnp.asarray(ptab),
                                 jnp.asarray(lens))
    want = _paged_decode_ref(q, kp, vp, jnp.asarray(ptab), jnp.asarray(lens))
    # empty slot: kernel yields zeros, oracle yields a uniform average —
    # both are "no valid keys"; compare active slots only
    np.testing.assert_allclose(np.asarray(got[:2], np.float32),
                               np.asarray(want[:2], np.float32), **_tol(dtype))
    assert not bool(jnp.isnan(got).any())


@pytest.mark.parametrize("page,pps", [(8, 4), (16, 2)])
def test_ragged_paged_flash_allclose(page, pps):
    """Ragged query packs: per-token slot -> block-table -> pool-row double
    indirection, per-token visible-length masking (intra-pack causality),
    and invalid (lens == 0) tokens yielding zeros."""
    B, kvH, G, hd = 3, 2, 4, 16
    T = 11
    npages = B * pps
    kp = jax.random.normal(jax.random.PRNGKey(1), (npages, page, kvH, hd))
    vp = jax.random.normal(jax.random.PRNGKey(2), (npages, page, kvH, hd))
    q = jax.random.normal(KEY, (T, kvH, G, hd))
    rng = np.random.RandomState(3)
    perm = rng.permutation(npages)
    ptab = np.full((B, pps), npages, np.int32)
    fills = [pps * page, page + 1, 3]  # per-slot written prefix
    for b in range(B):
        used = -(-fills[b] // page)
        ptab[b, :used] = perm[b * pps:b * pps + used]
    # a mixed pack: several tokens per slot at increasing positions, plus
    # one invalid token (lens 0)
    slot = np.asarray([0, 0, 1, 2, 0, 1, 2, 0, 1, 0, 2], np.int32)
    lens = np.zeros(T, np.int32)
    cursor = {b: 1 for b in range(B)}
    for t in range(T - 1):
        b = int(slot[t])
        lens[t] = min(cursor[b], fills[b])
        cursor[b] += rng.randint(1, 4)
    lens[T - 1] = 0  # invalid pack tail

    got = ops.ragged_paged_flash(q, kp, vp, jnp.asarray(ptab),
                                 jnp.asarray(slot), jnp.asarray(lens))
    # oracle: gather each token's slot context, mask by its visible length
    k = jnp.take(kp, jnp.asarray(ptab), axis=0,
                 mode="clip").reshape(B, pps * page, kvH, hd)[slot]
    v = jnp.take(vp, jnp.asarray(ptab), axis=0,
                 mode="clip").reshape(B, pps * page, kvH, hd)[slot]
    s = jnp.einsum("tkgd,tskd->tkgs", q, k) * hd ** -0.5
    mask = jnp.arange(pps * page)[None] < jnp.asarray(lens)[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    want = jnp.einsum("tkgs,tskd->tkgd", jax.nn.softmax(s, -1), v)
    valid = lens > 0
    np.testing.assert_allclose(np.asarray(got[valid]),
                               np.asarray(want[valid]),
                               **_tol(jnp.float32))
    np.testing.assert_allclose(np.asarray(got[~valid]), 0.0, atol=1e-6)
    assert not bool(jnp.isnan(got).any())


@pytest.mark.parametrize("kernel", ["paged", "ragged"])
def test_flash_kernels_fused_dequant_match_fp32_pool(kernel):
    """int8 pools: the fused in-VMEM dequant (int8 tile × scale row inside
    the online-softmax loop) must match running the same kernel on the
    dequantized fp32 pool to float tolerance — quantization changes WHERE
    the bytes expand, not the math."""
    B, kvH, G, hd, page, pps = 2, 2, 4, 16, 8, 3
    npages = B * pps
    kp_f = jax.random.normal(jax.random.PRNGKey(1), (npages, page, kvH, hd))
    vp_f = jax.random.normal(jax.random.PRNGKey(2), (npages, page, kvH, hd))
    kp8, ks = ops.quantize_kv(kp_f)
    vp8, vs = ops.quantize_kv(vp_f)
    kp_dq = ops.dequantize_kv(kp8, ks)
    vp_dq = ops.dequantize_kv(vp8, vs)
    ptab = jnp.asarray(np.arange(npages).reshape(B, pps), jnp.int32)
    if kernel == "paged":
        q = jax.random.normal(KEY, (B, kvH, G, hd))
        lens = jnp.asarray([pps * page, page + 3], jnp.int32)
        got = ops.paged_flash_decode(q, kp8, vp8, ptab, lens, ks=ks, vs=vs)
        want = ops.paged_flash_decode(q, kp_dq, vp_dq, ptab, lens)
    else:
        T = 5
        q = jax.random.normal(KEY, (T, kvH, G, hd))
        slot = jnp.asarray([0, 1, 0, 1, 0], jnp.int32)
        lens = jnp.asarray([1, page, pps * page, 0, page + 2], jnp.int32)
        got = ops.ragged_paged_flash(q, kp8, vp8, ptab, slot, lens,
                                     ks=ks, vs=vs)
        want = ops.ragged_paged_flash(q, kp_dq, vp_dq, ptab, slot, lens)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm


@pytest.mark.parametrize("shape", [(4, 37, 96), (1, 128), (3, 5, 7, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_allclose(shape, dtype):
    x = jax.random.normal(KEY, shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (shape[-1],), jnp.float32)
    got = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(0.1, 100.0), rows=st.integers(1, 8))
def test_rmsnorm_scale_invariance(scale, rows):
    """Property: rmsnorm(αx) == rmsnorm(x) for α > 0."""
    x = jax.random.normal(KEY, (rows, 64), jnp.float32)
    s = jnp.ones((64,))
    np.testing.assert_allclose(ops.rmsnorm(x * scale, s), ops.rmsnorm(x, s),
                               rtol=1e-3, atol=1e-4)
