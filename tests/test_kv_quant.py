"""int8 quantized paged KV cache: quantize/dequant round-trip bounds, the
engine-level fp32-vs-int8 greedy top-1 agreement (the headline acceptance
bar), flash-kernel fused-dequant parity, warm-prefix/COW correctness on
quantized pools (scales travel with their pages), byte-budget pool sizing
(~2x or better resident pages), hot-loop buffer donation (no-copy pool
updates, asserted by pointer identity), dtype-aware roofline bytes, and the
backend-aware Pallas ``interpret`` default."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.kernels import ops as kops
from repro.models import model as M
from repro.serve.engine import ServeEngine, kv_bytes_per_token, kv_page_bytes

KEY = jax.random.PRNGKey(0)
CACHE = 64


@pytest.fixture(scope="module")
def qwen():
    # float32 activations keep greedy argmax stable across batching layouts;
    # the KV pool dtype is the engine knob under test
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    return cfg, M.init_params(KEY, cfg)


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, L) for L in lens]


def _serve(cfg, params, prompts, max_tokens=4, **kw):
    kw.setdefault("batch_size", 3)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 32)
    eng = ServeEngine(params, cfg, **kw)
    uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
    return eng, uids, eng.run()


# ---------------------------------------------------------------------------
# Quantize/dequant primitives


def test_quantize_roundtrip_error_bound():
    """Per-row symmetric int8: reconstruction error of every element is at
    most half a quantization step (absmax/254), across magnitudes from
    subnormal-ish rows to large ones."""
    rng = np.random.RandomState(0)
    for scale_mag in (1e-4, 1.0, 300.0):
        x = jnp.asarray(rng.randn(6, 4, 32) * scale_mag, jnp.float32)
        q, s = kops.quantize_kv(x)
        assert q.dtype == jnp.int8 and s.shape == x.shape[:-1]
        back = kops.dequantize_kv(q, s)
        absmax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        bound = absmax / 254.0 + 1e-12
        assert bool(jnp.all(jnp.abs(back - x) <= bound)), (
            float(jnp.max(jnp.abs(back - x))), float(jnp.max(bound)))


def test_quantize_zero_rows_roundtrip_to_zero():
    q, s = kops.quantize_kv(jnp.zeros((3, 2, 16)))
    assert bool(jnp.all(q == 0))
    assert bool(jnp.all(kops.dequantize_kv(q, s) == 0.0))


def test_quantize_preserves_row_absmax_sign_and_extremes():
    """The absmax element of every row maps to exactly +/-127 (symmetric
    scaling uses the full int8 range)."""
    x = jnp.asarray(np.random.RandomState(1).randn(8, 2, 16), jnp.float32)
    q, _ = kops.quantize_kv(x)
    assert int(jnp.max(jnp.abs(q))) == 127


def test_copy_pages_carries_scale_rows():
    """COW on an int8 pool must copy a page's scale row with its values —
    `copy_pages` with the scale-pool axis duplicates rows exactly."""
    rng = np.random.RandomState(2)
    ks = jnp.asarray(rng.rand(6, 4, 2), jnp.float32)  # (n_pages, page, kvH)
    src = jnp.asarray([1, 6], jnp.int32)  # second pair = sentinel no-op
    dst = jnp.asarray([3, 6], jnp.int32)
    out = kops.copy_pages(ks, src, dst, axis=ks.ndim - 3)
    assert bool(jnp.all(out[3] == ks[1]))
    assert bool(jnp.all(out[:3] == ks[:3])) and bool(jnp.all(out[4:] == ks[4:]))


# ---------------------------------------------------------------------------
# Engine-level acceptance: agreement, parity, warm paths


def test_int8_engine_top1_agreement_with_fp32(qwen):
    """The headline bar: greedy int8 serving agrees with fp32 on >= 99% of
    emitted tokens over the smoke-sweep style prompt mix."""
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11, 26, 8, 14, 33, 7], seed=90)

    def run(kvd):
        _, uids, got = _serve(cfg, params, prompts, max_tokens=6,
                              batch_size=3, kv_dtype=kvd)
        return [t for u in uids for t in got[u]]

    fp32, int8 = run(None), run("int8")
    agree = np.mean([a == b for a, b in zip(fp32, int8)])
    assert agree >= 0.99, f"top-1 agreement {agree:.3f} < 0.99"


def test_int8_flash_kernel_matches_jnp_path(qwen):
    """The fused-dequant Pallas kernels and the jnp dequant oracle read the
    SAME representation: token-identical outputs."""
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11], seed=91)
    _, u1, r1 = _serve(cfg, params, prompts, batch_size=2, kv_dtype="int8")
    _, u2, r2 = _serve(cfg, params, prompts, batch_size=2, kv_dtype="int8",
                       flash_decode=True)
    assert [r1[u] for u in u1] == [r2[u] for u in u2]


def test_bfloat16_pool_serves(qwen):
    """The middle kv_dtype: a bf16 pool (half the bytes, no scales) serves
    the same traffic end to end."""
    cfg, params = qwen
    prompts = _prompts(cfg, [9, 17], seed=92)
    eng, uids, got = _serve(cfg, params, prompts, kv_dtype="bfloat16")
    assert all(len(got[u]) == 4 for u in uids)
    assert eng.stats["kv_dtype"] == "bfloat16"
    assert eng.stats["kv_bytes_per_token"] * 2 == kv_bytes_per_token(
        cfg, "float32")


def test_int8_warm_prefix_token_identical_to_cold(qwen):
    """Prefix hits on an int8 pool replay the quantized pages byte-for-byte
    (quantize-at-write): warm outputs == cold outputs, with hits."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [32], seed=93)
    prompts = [np.concatenate([shared, s])
               for s in _prompts(cfg, [5, 7], seed=94)]
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32,
                      kv_dtype="int8")
    u1 = [eng.submit(p, max_tokens=4) for p in prompts]
    cold = eng.run()
    u2 = [eng.submit(p, max_tokens=4) for p in prompts]
    warm = eng.run()
    assert [cold[u] for u in u1] == [warm[u] for u in u2]
    assert eng.stats["prefix_hits"] >= 2
    assert eng.stats["prefix_tokens_reused"] >= 2 * 32
    assert eng.stats["traces"] == 1  # quantization lives inside the one trace


@settings(max_examples=6, deadline=None)
@given(share=st.sampled_from([3, 9, 16, 21, 27]),
       page=st.sampled_from([4, 8]))
def test_cow_divergence_int8_copies_scales_never_perturbs_sibling(
        qwen, share, page):
    """Property: COW on an int8 pool duplicates values AND scale rows, so a
    diverging request (a) matches a cold-pool int8 run of itself and (b) the
    shared sibling re-served afterwards is bit-identical to its own cold
    output — the divergent write never leaked into shared pages or their
    scales."""
    cfg, params = qwen
    rng = np.random.RandomState(95)
    a = rng.randint(0, cfg.vocab_size, 28)
    b = a.copy()
    b[share:] = (b[share:] + 1 + rng.randint(0, 100)) % cfg.vocab_size

    def cold_solo(p):
        _, [u], got = _serve(cfg, params, [p], batch_size=1, page_size=page,
                             prefill_chunk=8, token_budget=16,
                             kv_dtype="int8")
        return got[u]

    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=page, prefill_chunk=8, token_budget=16,
                      kv_dtype="int8")
    ua = eng.submit(a, max_tokens=4)
    ra = eng.run()
    ub = eng.submit(b, max_tokens=4)
    rb = eng.run()
    ua2 = eng.submit(a, max_tokens=4)  # sibling again, warm, post-COW
    ra2 = eng.run()
    assert ra[ua] == cold_solo(a)
    assert rb[ub] == cold_solo(b)
    assert ra2[ua2] == ra[ua]
    reusable = min(share, (len(a) // page) * page)
    assert eng.stats["cow_copies"] >= (1 if reusable % page else 0)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


# ---------------------------------------------------------------------------
# Byte-budget pool sizing / stats


def test_int8_doubles_resident_pages_in_same_byte_budget(qwen):
    """The working-set claim: at the default (byte-denominated) page budget
    an int8 pool holds >= 2x the pages of the fp32 pool, and the per-token
    KV bytes drop by >= 2x (values + scales accounted)."""
    cfg, params = qwen
    e32 = ServeEngine(params, cfg, batch_size=3, cache_len=CACHE, page_size=8)
    e8 = ServeEngine(params, cfg, batch_size=3, cache_len=CACHE, page_size=8,
                     kv_dtype="int8")
    assert e8.n_pages >= 2 * e32.n_pages
    assert e32.stats["kv_bytes_per_token"] >= 2 * e8.stats["kv_bytes_per_token"]
    # same byte budget: the int8 pool's total footprint never exceeds fp32's
    assert e8.stats["kv_pool_bytes"] <= e32.stats["kv_pool_bytes"]
    assert e8.stats["kv_dtype"] == "int8"
    # helper consistency: page bytes scale linearly in page_size
    assert kv_page_bytes(cfg, 8, "int8") == 8 * kv_page_bytes(cfg, 1, "int8")


def test_int8_admits_more_concurrent_requests_at_equal_bytes(qwen):
    """At a pool byte budget that throttles fp32 to ~1 in-flight request,
    the int8 pool (same bytes) serves the wave with strictly more slots
    concurrently busy — the admission-throughput half of the claim."""
    cfg, params = qwen
    prompts = _prompts(cfg, [12] * 4, seed=96)
    fp32_pages = -(-(12 + 8) // 8) + 1  # one request + one page of slack
    bytes_budget = fp32_pages * kv_page_bytes(cfg, 8, "float32")
    int8_pages = bytes_budget // kv_page_bytes(cfg, 8, "int8")
    assert int8_pages >= 2 * fp32_pages

    def peak_busy(kvd, pages):
        eng = ServeEngine(params, cfg, batch_size=4, cache_len=CACHE,
                          page_size=8, prefill_chunk=16, token_budget=32,
                          max_pages=int(pages), kv_dtype=kvd,
                          prefix_cache=False)
        uids = [eng.submit(p, max_tokens=8) for p in prompts]
        peak = 0
        while not eng.idle:
            eng.tick()
            peak = max(peak, sum(s is not None for s in eng.slots))
        return peak

    assert peak_busy("int8", int8_pages) > peak_busy(None, fp32_pages)


def test_invalid_kv_dtype_rejected(qwen):
    cfg, params = qwen
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, batch_size=2, cache_len=32, page_size=8,
                    kv_dtype="int4")


# ---------------------------------------------------------------------------
# Hot-loop buffer donation (no-copy pool updates)


def test_ragged_step_donates_pools_in_place(qwen):
    """The serve step is jit'd with the state donated: on backends that
    support donation the page pools (and int8 scale pools) are updated IN
    PLACE — the output state's buffers are the input state's buffers, so the
    hot loop never copies the pool.  The pointer-identity check is the
    shared ``analysis.contracts`` helper, so this runtime assert and the
    static donation proof read the same pool-leaf list and cannot drift."""
    from repro.analysis.contracts import assert_donated, pool_buffer_pointers

    cfg, params = qwen
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE, page_size=8,
                      prefill_chunk=16, token_budget=32, kv_dtype="int8")
    eng.submit(_prompts(cfg, [20], seed=97)[0], max_tokens=8)
    eng.tick()  # compile + first real step
    before = pool_buffer_pointers(eng._state)
    if before is None:
        pytest.skip("backend exposes no buffer pointers")
    assert before  # int8 paged model: pools must exist
    eng.tick()
    # "undonated" (backend donated nothing) is tolerated; a PARTIAL
    # donation raises inside assert_donated — that is always a bug
    if assert_donated(before, eng._state) == "undonated":
        pytest.skip("backend does not donate buffers")


# ---------------------------------------------------------------------------
# Roofline / autotune dtype awareness


def test_mixed_bound_int8_halves_decode_side_bytes():
    """Regression: the analytic blend's KV traffic with int8 KV is at most
    half the fp32 traffic for the same mix (values + amortized scales), and
    the bound's tokens/s never degrades."""
    from repro.core.roofline import mixed_bound

    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    kw = dict(n_decode=8, n_prefill=24, context_len=192, page_size=16)
    r32 = mixed_bound(cfg, kv_dtype="float32", **kw)
    r8 = mixed_bound(cfg, kv_dtype="int8", **kw)
    assert r8["kv_read_bytes"] <= 0.5 * r32["kv_read_bytes"]
    assert r8["kv_write_bytes"] <= 0.5 * r32["kv_write_bytes"]
    assert r8["tokens_per_s"] >= r32["tokens_per_s"]
    # bf16 sits exactly at half fp32 (no scale overhead)
    r16 = mixed_bound(cfg, kv_dtype="bfloat16", **kw)
    assert r16["kv_read_bytes"] == pytest.approx(0.5 * r32["kv_read_bytes"])


def test_decode_bound_kv_dtype_only_touches_global_layers():
    """Windowed layers keep activation-dtype circular buffers: on a hybrid
    (gemma3: 5 local + 1 global) the int8 saving applies only to the global
    layer's bytes."""
    from repro.core.roofline import decode_bound

    cfg = get_config("gemma3-4b", smoke=True).replace(dtype="float32")
    r32 = decode_bound(cfg, batch=4, context_len=64, page_size=8,
                       kv_dtype="float32")
    r8 = decode_bound(cfg, batch=4, context_len=64, page_size=8,
                      kv_dtype="int8")
    assert r8["kv_bytes"] < r32["kv_bytes"]  # global layer shrank...
    # ...but the windowed layers' bytes keep the pools from a full 2x cut
    assert r8["kv_bytes"] > 0.25 * r32["kv_bytes"]


def test_bench_serve_json_records_kv_dtype():
    """The committed perf trajectory must carry the dtype axis: the tuned
    config records its chosen kv_dtype and the fp32-vs-int8 A/B rows are
    present (CI regenerates and re-gates this file every push)."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    if not os.path.exists(path):
        pytest.skip("BENCH_serve.json not generated in this checkout")
    with open(path) as f:
        bench = json.load(f)
    assert "kv_dtype" in bench["tuned_serving_config"], bench
    ab = bench["kv_dtype_ab"]
    assert ab["min_top1_agreement"] >= 0.99
    assert ab["pages"]["int8"] >= 2 * ab["pages"]["float32"]


def test_select_serve_defaults_tunes_kv_dtype():
    """The tuned-once serving config now picks the memory representation:
    kv_dtype is on the swept axis and lands in the emitted config (int8
    dominates every memory-bound criterion, so it must win when offered)."""
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100)
    assert out["best"]["kv_dtype"] in ("float32", "bfloat16", "int8")
    assert all("kv_dtype" in r for r in out["table"])
    only8 = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100,
                                  kv_dtypes=("int8",))
    assert only8["best"]["kv_dtype"] == "int8"


# ---------------------------------------------------------------------------
# Backend-aware Pallas interpret default


def test_default_interpret_backend_and_env(monkeypatch):
    """False iff the backend is a real TPU; REPRO_PALLAS_INTERPRET forces
    either mode (the TPU-validation follow-up's prerequisite)."""
    from repro.kernels.ops import default_interpret

    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    assert default_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert default_interpret() is False
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "true")
    assert default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "garbage")  # ignored
    assert default_interpret() == (jax.default_backend() != "tpu")
