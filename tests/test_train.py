"""Training substrate: optimizer math, checkpoint/resume, fault tolerance."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.optim.adamw import AdamWCfg, apply_updates, init_opt_state
from repro.optim.quantized_state import dequantize, quantize
from repro.optim.schedules import warmup_cosine
from repro.train import checkpoint as C
from repro.train.loop import TrainLoop

KEY = jax.random.PRNGKey(0)
TINY = ShapeCfg("tiny", 32, 8, "train")


# ---------------------------------------------------------------------------
# Optimizer


def test_adamw_matches_reference_math():
    cfg = AdamWCfg(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0, grad_clip=None)
    p = {"w": jnp.array([1.0, -2.0, 3.0])}
    g = {"w": jnp.array([0.1, 0.2, -0.3])}
    st_ = init_opt_state(p, cfg)
    new_p, st_, _ = apply_updates(p, g, st_, cfg, lr=0.1)
    m = 0.1 * np.array([0.1, 0.2, -0.3])
    v = 0.01 * np.array([0.1, 0.2, -0.3]) ** 2
    u = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(new_p["w"], np.array([1., -2., 3.]) - 0.1 * u,
                               rtol=1e-5)


def test_grad_clip_bounds_update():
    cfg = AdamWCfg(grad_clip=1.0)
    p = {"w": jnp.zeros((4,))}
    g = {"w": jnp.full((4,), 100.0)}
    st_ = init_opt_state(p, cfg)
    _, _, metrics = apply_updates(p, g, st_, cfg, lr=0.1)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


@settings(max_examples=15, deadline=None)
@given(scale=st.floats(1e-4, 1e3))
def test_int8_quant_roundtrip_bound(scale):
    """Property: |x - dq(q(x))| <= rowwise absmax / 127 / 2 + ulp."""
    x = jax.random.normal(KEY, (8, 64), jnp.float32) * scale
    err = jnp.abs(x - dequantize(quantize(x)))
    bound = jnp.max(jnp.abs(x), axis=-1, keepdims=True) / 127.0 * 0.5001 + 1e-9
    assert bool(jnp.all(err <= bound))


def test_int8_state_training_tracks_fp32():
    """int8-moment AdamW stays close to fp32 AdamW on a quadratic."""
    def loss(w):
        return jnp.sum((w - 3.0) ** 2)

    runs = {}
    for sdt in ("float32", "int8"):
        cfg = AdamWCfg(state_dtype=sdt, weight_decay=0.0, grad_clip=None)
        w = {"w": jnp.zeros((16,))}
        st_ = init_opt_state(w, cfg)
        for _ in range(100):
            g = jax.grad(lambda p: loss(p["w"]))(w)
            w, st_, _ = apply_updates(w, g, st_, cfg, lr=0.05)
        runs[sdt] = w["w"]
    assert float(jnp.max(jnp.abs(runs["int8"] - runs["float32"]))) < 0.15


def test_warmup_cosine_shape():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0, rel=1e-3)
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-2)
    assert float(lr(55)) < float(lr(20))


# ---------------------------------------------------------------------------
# Checkpoint / loop / fault tolerance


def test_checkpoint_roundtrip_exact():
    cfg = get_config("qwen2-1.5b", smoke=True)
    loop = TrainLoop(cfg, TINY, total_steps=10)
    state, _ = loop.init_or_restore()
    d = tempfile.mkdtemp()
    try:
        C.save_checkpoint(d, state, 7)
        assert C.latest_step(d) == 7
        like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
        restored = C.restore_checkpoint(d, like)
        for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    finally:
        shutil.rmtree(d)


def test_resume_is_bit_deterministic():
    """12 straight steps == 6 steps + restart + 6 steps (same data, state)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
    try:
        h_straight = TrainLoop(cfg, TINY, ckpt_dir=d1, save_every=100,
                               total_steps=50, lr=1e-3).run(12)
        TrainLoop(cfg, TINY, ckpt_dir=d2, save_every=6, total_steps=50,
                  lr=1e-3).run(6)
        h_resumed = TrainLoop(cfg, TINY, ckpt_dir=d2, save_every=6,
                              total_steps=50, lr=1e-3).run(12)
        a = [r["loss"] for r in h_straight[6:]]
        b = [r["loss"] for r in h_resumed]
        np.testing.assert_allclose(a, b, rtol=1e-5)
    finally:
        shutil.rmtree(d1)
        shutil.rmtree(d2)


def test_failure_recovery():
    cfg = get_config("qwen2-1.5b", smoke=True)
    d = tempfile.mkdtemp()
    calls = {"n": 0}

    def chaos(step):
        if step in (7, 9) and calls["n"] < 2:
            calls["n"] += 1
            raise RuntimeError("injected failure")

    try:
        h = TrainLoop(cfg, TINY, ckpt_dir=d, save_every=5, total_steps=50,
                      lr=1e-3, failure_hook=chaos).run(12)
        assert h[-1]["step"] == 11
        assert calls["n"] == 2
    finally:
        shutil.rmtree(d)


def test_loss_decreases_on_learnable_data():
    cfg = get_config("qwen2-1.5b", smoke=True)
    h = TrainLoop(cfg, TINY, total_steps=60, lr=3e-3).run(45)
    first = np.mean([r["loss"] for r in h[:5]])
    last = np.mean([r["loss"] for r in h[-5:]])
    assert last < 0.8 * first, (first, last)
