"""Layer-level invariants: RoPE, attention variants, mixers, MoE."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.base import AttnCfg, MambaCfg, MoECfg, XLSTMCfg
from repro.models.layers import attention as A
from repro.models.layers import mamba as Mb
from repro.models.layers import xlstm as X
from repro.models.layers.conv import causal_depthwise_conv, conv_step
from repro.models.layers.embeddings import apply_rope
from repro.models.layers.moe import init_moe, moe_fwd

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# RoPE


def test_rope_preserves_norm():
    x = jax.random.normal(KEY, (2, 16, 3, 4, 32), jnp.float32)
    y = apply_rope(x, jnp.arange(16), 1e4)
    np.testing.assert_allclose(jnp.linalg.norm(x, axis=-1),
                               jnp.linalg.norm(y, axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rope(q,m), rope(k,n)> depends only on m - n."""
    q = jax.random.normal(KEY, (1, 1, 1, 1, 32), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 1, 32), jnp.float32)

    def dot_at(m, n):
        qm = apply_rope(jnp.broadcast_to(q, (1, 1, 1, 1, 32)), jnp.array([m]), 1e4)
        kn = apply_rope(jnp.broadcast_to(k, (1, 1, 1, 1, 32)), jnp.array([n]), 1e4)
        return float(jnp.sum(qm * kn))

    assert abs(dot_at(5, 3) - dot_at(10, 8)) < 1e-4
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-4


# ---------------------------------------------------------------------------
# Attention


def _mk_attn(num_heads=4, num_kv=2, hd=16, **kw):
    cfg = AttnCfg(num_heads=num_heads, num_kv_heads=num_kv, head_dim=hd,
                  rope_theta=1e4, **kw)
    params = A.init_attention(KEY, 32, cfg)
    return cfg, params


def test_gqa_equals_repeated_mha():
    """GQA with kv heads broadcast == MHA with physically repeated KV."""
    x = jax.random.normal(KEY, (2, 24, 32), jnp.float32)
    cfg_g, p_g = _mk_attn(num_heads=4, num_kv=2)
    cfg_m = AttnCfg(num_heads=4, num_kv_heads=4, head_dim=16, rope_theta=1e4)
    p_m = dict(p_g)
    # physically repeat KV heads: (D, 2, hd) -> (D, 4, hd); regroup q/wo
    p_m["wk"] = jnp.repeat(p_g["wk"], 2, axis=1)
    p_m["wv"] = jnp.repeat(p_g["wv"], 2, axis=1)
    p_m["wq"] = p_g["wq"].reshape(32, 4, 1, 16)  # (D,kvH=4,G=1,hd)
    p_m["wo"] = p_g["wo"].reshape(4, 1, 16, 32)
    y_g = A.attention_fwd(p_g, cfg_g, x)
    y_m = A.attention_fwd(p_m, cfg_m, x)
    np.testing.assert_allclose(y_g, y_m, rtol=1e-4, atol=1e-5)


def test_chunked_equals_full():
    x = jax.random.normal(KEY, (2, 64, 32), jnp.float32)
    cfg, p = _mk_attn()
    y_full = A.attention_fwd(p, cfg, x, q_chunk=64)  # full path (S <= 2*chunk)
    y_chunk = A.attention_fwd(p, cfg, x, q_chunk=16)
    np.testing.assert_allclose(y_full, y_chunk, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("window,chunk", [(None, 6), (8, 6), (8, 24), (8, 1)])
def test_paged_chunked_prefill_matches_fwd_oracle(window, chunk):
    """Numerical oracle for the serving prefill path: feeding a prompt
    through paged_attention_step in C-token chunks must reproduce
    attention_fwd's full-sequence outputs at every position — including
    windowed layers where a chunk write evicts circular-buffer entries
    (window_extra = C-1 keeps every in-window key resident)."""
    cfg, p = _mk_attn(window=window)
    B, S = 2, 24
    x = jax.random.normal(KEY, (B, S, 32), jnp.float32)
    want = A.attention_fwd(p, cfg, x, positions=jnp.arange(S), q_chunk=128)
    cache = A.init_paged_cache(cfg, B, 32, jnp.float32, page_size=4,
                               n_pages=2 * 8, window_extra=chunk - 1)
    if "ptab" in cache:  # map pages: slot b owns pool rows [8b, 8(b+1))
        cache["ptab"] = jnp.asarray([[8 * b + i for i in range(8)]
                                     for b in range(B)], jnp.int32)
    outs = []
    for c0 in range(0, S, chunk):
        C = min(chunk, S - c0)
        q_pos = jnp.broadcast_to(c0 + jnp.arange(C), (B, C))
        o, cache = A.paged_attention_step(
            p, cfg, x[:, c0:c0 + C], cache, q_pos, jnp.ones((B, C), bool))
        outs.append(o)
    got = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_sliding_window_masks_far_past():
    """With window w, output at t ignores inputs older than t - w + 1."""
    cfg, p = _mk_attn(window=8)
    x = jax.random.normal(KEY, (1, 32, 32), jnp.float32)
    x2 = x.at[:, :16].set(jax.random.normal(jax.random.PRNGKey(9), (1, 16, 32)))
    y1 = A.attention_fwd(p, cfg, x)
    y2 = A.attention_fwd(p, cfg, x2)
    np.testing.assert_allclose(y1[:, 24:], y2[:, 24:], rtol=1e-4, atol=1e-5)
    assert float(jnp.max(jnp.abs(y1[:, :16] - y2[:, :16]))) > 1e-3


def test_decode_matches_fwd():
    cfg, p = _mk_attn()
    x = jax.random.normal(KEY, (2, 17, 32), jnp.float32)
    y = A.attention_fwd(p, cfg, x)
    cache = A.init_cache(cfg, 2, 32, jnp.float32)
    cache = A.prefill_cache(p, cfg, cache, x[:, :-1], jnp.arange(16))
    y_t, _ = A.attention_decode(p, cfg, x[:, -1:], cache)
    np.testing.assert_allclose(y_t[:, 0], y[:, -1], rtol=1e-4, atol=1e-5)


def test_windowed_circular_cache_decode():
    """Windowed decode with a circular window-sized cache matches full fwd."""
    cfg, p = _mk_attn(window=8)
    S = 24
    x = jax.random.normal(KEY, (1, S, 32), jnp.float32)
    y = A.attention_fwd(p, cfg, x)
    cache = A.init_cache(cfg, 1, 512, jnp.float32)
    assert cache["k"].shape[1] == 8  # capacity = window
    outs = []
    for t in range(S):
        y_t, cache = A.attention_decode(p, cfg, x[:, t : t + 1], cache)
        outs.append(y_t[:, 0])
    np.testing.assert_allclose(jnp.stack(outs, 1), y, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Recurrent mixers


def test_mlstm_chunkwise_matches_sequential():
    cfg = XLSTMCfg(kind="mlstm", num_heads=4, proj_factor=2.0)
    p = X.init_mlstm(KEY, 64, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 96, 64), jnp.float32)
    np.testing.assert_allclose(X.mlstm_fwd(p, cfg, x, chunk=16),
                               X.mlstm_fwd_seq(p, cfg, x), rtol=1e-4, atol=1e-5)


def test_mlstm_decode_matches_fwd():
    cfg = XLSTMCfg(kind="mlstm", num_heads=2, proj_factor=2.0)
    p = X.init_mlstm(KEY, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 20, 32), jnp.float32)
    y = X.mlstm_fwd_seq(p, cfg, x)
    st_ = X.init_mlstm_state(cfg, 32, 2, jnp.float32)
    for t in range(20):
        y_t, st_ = X.mlstm_decode(p, cfg, x[:, t : t + 1], st_)
    np.testing.assert_allclose(y_t[:, 0], y[:, -1], rtol=1e-4, atol=1e-5)


def test_mamba_chunk_invariance_and_decode():
    cfg = MambaCfg(d_state=4)
    p = Mb.init_mamba(KEY, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, 32), jnp.float32)
    y1 = Mb.mamba_fwd(p, cfg, x, chunk=48)
    y2 = Mb.mamba_fwd(p, cfg, x, chunk=8)
    np.testing.assert_allclose(y1, y2, rtol=1e-5, atol=1e-6)
    st_ = Mb.init_mamba_state(cfg, 32, 2, jnp.float32)
    for t in range(48):
        y_t, st_ = Mb.mamba_decode(p, cfg, x[:, t : t + 1], st_)
    np.testing.assert_allclose(y_t[:, 0], y1[:, -1], rtol=1e-4, atol=1e-5)


def test_slstm_decode_matches_fwd():
    cfg = XLSTMCfg(kind="slstm", num_heads=2)
    p = X.init_slstm(KEY, 32, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 32), jnp.float32)
    y = X.slstm_fwd(p, cfg, x, chunk=8)
    st_ = X.init_slstm_state(cfg, 32, 2, jnp.float32)
    for t in range(24):
        y_t, st_ = X.slstm_decode(p, cfg, x[:, t : t + 1], st_)
    np.testing.assert_allclose(y_t[:, 0], y[:, -1], rtol=1e-4, atol=1e-5)


def test_causal_conv_step_consistency():
    w = jax.random.normal(KEY, (4, 8), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(1), (8,), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 8), jnp.float32)
    y = causal_depthwise_conv(x, w, b)
    state = jnp.zeros((2, 3, 8))
    outs = []
    for t in range(12):
        o, state = conv_step(x[:, t], state, w, b)
        outs.append(o)
    np.testing.assert_allclose(jnp.stack(outs, 1), y, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# MoE


def test_moe_dispatch_matches_ragged_at_high_capacity():
    cfg_d = MoECfg(num_experts=4, top_k=2, d_ff=32, capacity_factor=64.0)
    cfg_r = dataclasses.replace(cfg_d, impl="ragged", capacity_factor=1.25)
    p = init_moe(KEY, 16, cfg_d)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 24, 16), jnp.float32)
    y_d, aux_d = moe_fwd(p, cfg_d, x)
    y_r, aux_r = moe_fwd(p, cfg_r, x)
    np.testing.assert_allclose(y_d, y_r, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(aux_d["moe_lb_loss"], aux_r["moe_lb_loss"], rtol=1e-5)


def test_moe_capacity_drops_tokens():
    """At cf=0.25 some tokens must be dropped -> outputs differ from dropless."""
    cfg_low = MoECfg(num_experts=4, top_k=1, d_ff=32, capacity_factor=0.25)
    cfg_r = dataclasses.replace(cfg_low, impl="ragged")
    p = init_moe(KEY, 16, cfg_low)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16), jnp.float32)
    y_low, _ = moe_fwd(p, cfg_low, x)
    y_free, _ = moe_fwd(p, cfg_r, x)
    assert float(jnp.max(jnp.abs(y_low - y_free))) > 1e-4


def test_moe_dense_residual():
    from repro.configs.base import MLPCfg

    cfg = MoECfg(num_experts=4, top_k=1, d_ff=32,
                 dense_residual=MLPCfg(d_ff=32))
    p = init_moe(KEY, 16, cfg)
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16), jnp.float32)
    y, _ = moe_fwd(p, cfg, x)
    assert y.shape == x.shape and bool(jnp.all(jnp.isfinite(y)))


@settings(max_examples=6, deadline=None)
@given(e=st.sampled_from([2, 4, 8]), k=st.integers(2, 2))
def test_moe_gates_convexity(e, k):
    """Ragged MoE output is a convex combination of per-expert outputs:
    with identical experts and k>=2 (renormalized gates sum to 1) the MoE
    equals the single-expert MLP.  (top-1 keeps the raw softmax gate by
    design — Switch-style — so it scales the output instead.)"""
    cfg = MoECfg(num_experts=e, top_k=k, d_ff=16, impl="ragged")
    p = init_moe(KEY, 8, cfg)
    p = dict(p)
    for nm in ("we_gate", "we_up", "we_down"):
        p[nm] = jnp.broadcast_to(p[nm][:1], p[nm].shape)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 8), jnp.float32)
    y, _ = moe_fwd(p, cfg, x)
    from repro.configs.base import MLPCfg
    from repro.models.layers.mlp import mlp_fwd

    ref_ = mlp_fwd({"w_gate": p["we_gate"][0], "w_up": p["we_up"][0],
                    "w_down": p["we_down"][0]}, MLPCfg(d_ff=16), x)
    np.testing.assert_allclose(y, ref_, rtol=1e-4, atol=1e-5)
