"""End-to-end behaviour tests: train → improve → checkpoint → serve."""
import shutil
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.train.loop import TrainLoop


def test_train_then_serve_end_to_end():
    """The quickstart contract: loss falls on learnable data and the trained
    params serve deterministic greedy decodes through the engine."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    shape = ShapeCfg("e2e", 48, 8, "train")
    d = tempfile.mkdtemp()
    try:
        loop = TrainLoop(cfg, shape, lr=3e-3, total_steps=40, ckpt_dir=d,
                         save_every=20)
        hist = loop.run(30)
        assert hist[-1]["loss"] < hist[0]["loss"]

        params = loop.final_state["params"]
        engine = ServeEngine(params, cfg, batch_size=2, cache_len=64)
        prompts = [np.arange(8) % cfg.vocab_size,
                   (np.arange(8) * 5) % cfg.vocab_size]
        uids = [engine.submit(p, max_tokens=5) for p in prompts]
        results = engine.run()
        assert all(len(results[u]) == 5 for u in uids)

        # engine output == single-request reference decode
        state = M.init_decode_state(params, cfg, 1, 64)
        state = M.prefill(params, cfg, state, jnp.asarray(prompts[0])[None])
        tok = jnp.asarray([[prompts[0][-1]]], jnp.int32)
        ref = []
        for _ in range(5):
            lg, state = M.decode_step(params, cfg, state, tok)
            tok = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
            ref.append(int(tok[0, 0]))
        assert ref == results[uids[0]]
    finally:
        shutil.rmtree(d)


def test_moe_arch_trains():
    """An MoE arch trains without NaNs and the aux losses stay bounded."""
    cfg = get_config("arctic-480b", smoke=True)
    shape = ShapeCfg("moe", 32, 4, "train")
    hist = TrainLoop(cfg, shape, lr=1e-3, total_steps=20).run(12)
    assert np.isfinite(hist[-1]["loss"])
    assert hist[-1]["loss"] < hist[0]["loss"] * 1.2  # no divergence


def test_hybrid_arch_trains():
    cfg = get_config("jamba-1.5-large-398b", smoke=True)
    shape = ShapeCfg("hyb", 32, 4, "train")
    hist = TrainLoop(cfg, shape, lr=1e-3, total_steps=20).run(8)
    assert np.isfinite(hist[-1]["loss"])
