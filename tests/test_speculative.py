"""Speculative decoding inside the ragged token budget.

The contract under test, layer by layer:

- ``prompt_lookup_draft`` — the model-free drafter: longest tail n-gram,
  latest earlier occurrence, k-capped, [] on a miss.
- ``SpeculativeScheduler`` — a pure wrapper: every ordering delegates to
  the inner policy verbatim; only ``draft`` is new.
- The engine — drafts pack into the LEFTOVER (T,) budget after decode and
  prefill (strict priority: non-spec packing is bit-identical with spec
  on), one forward verifies every chain through a (B, 1+spec_k)
  ``logit_idx``, the longest agreeing prefix is accepted, and rejected
  tails roll kpos/slen back — all with ``stats["traces"] == 1``.
- Exactness — greedy transcripts are token-identical with speculation on
  or off; with per-(request, position) seeded sampling the same holds at
  ANY temperature, and sampling is packing-invariant even without
  speculation (the satellite regression).
- The analytic side — ``mixed_bound(draft_tokens=, accept_rate=)`` prices
  verify tokens as compute + KV writes but zero extra KV reads, and the
  tuner's ``spec_ks`` axis scores accepted-token goodput.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import (SloScheduler, SpeculativeScheduler,
                                   make_scheduler, prompt_lookup_draft)

KEY = jax.random.PRNGKey(0)
CACHE = 128


@pytest.fixture(scope="module")
def qwen():
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


def _solo_decode(params, cfg, prompt, max_tokens, cache_len=CACHE):
    state = M.init_decode_state(params, cfg, 1, cache_len)
    state = M.prefill(params, cfg, state, np.asarray(prompt, np.int32)[None])
    t = jnp.asarray([[int(prompt[-1])]], jnp.int32)
    out = []
    for _ in range(max_tokens):
        logits, state = M.decode_step(params, cfg, state, t)
        tok = int(jnp.argmax(logits[:, -1], -1)[0])
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def _engine(params, cfg, **kw):
    kw.setdefault("batch_size", 3)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 48)
    return ServeEngine(params, cfg, **kw)


def _tiled_prompts(cfg, n, pattern_len=6, reps=6, seed=7):
    """Repetitive completion prompts: a short pattern tiled — the greedy
    continuation loops, which prompt lookup predicts almost perfectly."""
    rng = np.random.RandomState(seed)
    return [np.tile(rng.randint(0, cfg.vocab_size, pattern_len), reps)
            for _ in range(n)]


def _small_alphabet_prompts(cfg, n, seed=11):
    """Prompts over a tiny token alphabet: lookup always finds a repeated
    n-gram but the model's actual continuation disagrees often — the
    reject/rollback workload."""
    rng = np.random.RandomState(seed)
    return [rng.randint(1, 5, 40) for _ in range(n)]


# ---------------------------------------------------------------------------
# Drafter + wrapper units


def test_prompt_lookup_draft_finds_latest_continuation():
    # tail [2,3] recurs at index 1; its continuation follows
    assert prompt_lookup_draft([1, 2, 3, 4, 2, 3], 3) == [4, 2, 3]
    assert prompt_lookup_draft([1, 2, 3, 4, 2, 3], 1) == [4]
    # among equal-length matches the LATEST earlier occurrence wins
    assert prompt_lookup_draft([5, 1, 2, 6, 1, 2, 7, 1, 2], 1) == [7]
    # nothing repeats -> no draft, zero model work
    assert prompt_lookup_draft([1, 2, 3, 4, 5], 4) == []
    assert prompt_lookup_draft([1], 4) == []
    assert prompt_lookup_draft([1, 2, 1, 2], 0) == []
    # longer n-grams are preferred: [9,1,2] tail matches exactly once even
    # though the 1-gram [2] has a nearer (different-continuation) match
    assert prompt_lookup_draft([9, 1, 2, 8, 2, 5, 9, 1, 2], 1,
                               ngram_max=3) == [8]


def test_speculative_scheduler_delegates_orderings():
    inner = SloScheduler()
    s = SpeculativeScheduler(inner, spec_k=3)
    assert s.inner is inner
    assert s.name == "speculative(slo,k=3)"
    # orderings are the inner policy's, method for method

    class V:  # duck-typed view: delegation never inspects it
        queue = ()

    v = V()
    assert list(s.admission_order(v)) == list(inner.admission_order(v))
    assert s.decode_order(v, [2, 0, 1]) == inner.decode_order(v, [2, 0, 1])
    # draft is capped at spec_k even when asked for more: the tail 3-gram
    # [2,1,2] matches at index 1 and only 2 tokens follow it
    assert s.draft([1, 2, 1, 2, 1, 2], 99) == [1, 2]
    assert s.draft([7, 8, 9, 7, 8, 9, 7, 8, 9], 99) == [7, 8, 9]
    # registry resolution and default inner (FIFO)
    r = make_scheduler("speculative")
    assert isinstance(r, SpeculativeScheduler) and r.inner.name == "fifo"


def test_speculative_scheduler_validates():
    with pytest.raises(ValueError):
        SpeculativeScheduler(spec_k=0)
    with pytest.raises(ValueError):
        SpeculativeScheduler(spec_k=2, ngram_min=0)
    with pytest.raises(ValueError):
        SpeculativeScheduler(spec_k=2, ngram_min=3, ngram_max=2)


def test_engine_validates_spec_k(qwen):
    cfg, params = qwen
    with pytest.raises(ValueError):
        _engine(params, cfg, spec_k=-1)
    with pytest.raises(ValueError):
        _engine(params, cfg, spec_k=2, ragged=False)


# ---------------------------------------------------------------------------
# The tentpole: accept >1 token per slot-tick, stay exact, one trace


def test_spec_greedy_identical_fewer_ticks_one_trace(qwen):
    cfg, params = qwen
    prompts = _tiled_prompts(cfg, 3)
    runs = {}
    for k in (0, 4):
        eng = _engine(params, cfg, spec_k=k)
        uids = [eng.submit(p, max_tokens=24) for p in prompts]
        got = eng.run()
        runs[k] = ([got[u] for u in uids], dict(eng.stats), eng)
    off, on = runs[0], runs[4]
    # exactness: verification accepts only what greedy would have emitted
    assert on[0] == off[0]
    assert off[0][0] == _solo_decode(params, cfg, prompts[0], 24)
    # speculation is a packing policy, not a new program
    assert on[1]["traces"] == off[1]["traces"] == 1
    # the point: >1 accepted token per sampled slot-tick, fewer ticks
    assert on[1]["spec_accepted"] > 0
    per_tick = sum(len(t) for t in on[0]) / on[1]["sampled_slot_ticks"]
    assert per_tick > 1.0, on[1]
    assert on[1]["ticks"] < off[1]["ticks"]
    # the ledger balances: every drafted token was accepted or rejected
    assert (on[1]["spec_drafted"]
            == on[1]["spec_accepted"] + on[1]["spec_rejected"])
    # both pools drain clean
    for _, _, eng in runs.values():
        assert eng.reclaimable_pages == eng.n_pages


def _assert_no_stale_rows(eng):
    """After any tick, no slot may have KV metadata at positions it has not
    reached: a rejected draft tail that skipped rollback would leave
    kpos >= pos rows that poison the jnp-path mask on later ticks."""
    leaves = jax.tree_util.tree_flatten_with_path(eng._state)[0]
    for b, s in enumerate(eng.slots):
        if s is None:
            continue
        # mid-prefill s.pos is still 0 and ``fill`` tracks written rows;
        # once decoding, pos is the next position and fill the prompt len
        lim = max(s.pos, s.fill)
        for path, leaf in leaves:
            name = [p.key for p in path
                    if isinstance(p, jax.tree_util.DictKey)][-1]
            if name == "kpos":
                assert int(np.asarray(leaf)[..., b, :].max()) < lim, (
                    b, lim, np.asarray(leaf)[..., b, :].max())
            elif name == "slen":
                assert int(np.asarray(leaf)[..., b].max()) <= lim


def test_spec_rejection_rolls_back_kpos_slen(qwen):
    cfg, params = qwen
    prompts = _small_alphabet_prompts(cfg, 3)
    eng = _engine(params, cfg, spec_k=4)
    handles = [eng.submit(p, max_tokens=16) for p in prompts]
    while not eng.idle:
        eng.tick()
        _assert_no_stale_rows(eng)
    # the workload actually exercised the reject path
    assert eng.stats["spec_rejected"] > 0
    assert eng.stats["spec_rollbacks"] > 0
    # ...and the transcripts still match non-speculative exactly
    ref = _engine(params, cfg, spec_k=0)
    ruids = [ref.submit(p, max_tokens=16) for p in prompts]
    rgot = ref.run()
    assert [h.result() for h in handles] == [rgot[u] for u in ruids]
    assert eng.reclaimable_pages == eng.n_pages


def test_spec_identical_at_temperature_with_seed(qwen):
    """Per-(request, position) seeded sampling: the verify loop re-samples
    position j from draft-row logits, so identity must hold at any
    temperature — not just greedy argmax."""
    cfg, params = qwen
    prompts = _tiled_prompts(cfg, 2, seed=19)
    outs = {}
    for k in (0, 5):
        eng = _engine(params, cfg, spec_k=k)
        uids = [eng.submit(p, max_tokens=16, temperature=2.0, top_k=40,
                           seed=100 + i) for i, p in enumerate(prompts)]
        got = eng.run()
        outs[k] = [got[u] for u in uids]
    assert outs[0] == outs[5]


def test_seeded_sampling_is_packing_invariant_single_emit(qwen):
    """The satellite regression (no speculation anywhere): a seeded
    temperature request must produce the same tokens whether it runs solo
    or packed beside co-traffic — the RNG is keyed by (seed, position),
    not by a per-request draw sequence that co-traffic could shift."""
    cfg, params = qwen
    [p] = _tiled_prompts(cfg, 1, seed=23)
    solo = _engine(params, cfg, batch_size=1)
    u = solo.submit(p, max_tokens=12, temperature=1.5, top_k=16, seed=77)
    alone = solo.run()[u]
    busy = _engine(params, cfg, batch_size=3)
    rng = np.random.RandomState(29)
    co = [busy.submit(rng.randint(0, cfg.vocab_size, 30), max_tokens=20)
          for _ in range(2)]
    u2 = busy.submit(p, max_tokens=12, temperature=1.5, top_k=16, seed=77)
    assert busy.run()[u2] == alone
    assert co  # co-traffic actually shared the packs


# ---------------------------------------------------------------------------
# Edges: budget, max_tokens, gating, quantized pool


def test_spec_respects_max_tokens_mid_chain(qwen):
    """A draft chain may not run a request past max_tokens: the engine caps
    the packed room at max_tokens - emitted - 1, so the final emission
    still lands exactly on the cap with spec on."""
    cfg, params = qwen
    prompts = _tiled_prompts(cfg, 2, seed=31)
    outs = {}
    for k in (0, 6):
        eng = _engine(params, cfg, spec_k=k)
        uids = [eng.submit(p, max_tokens=5) for p in prompts]
        got = eng.run()
        outs[k] = [got[u] for u in uids]
        assert all(len(t) == 5 for t in outs[k])
    assert outs[0] == outs[6]


def test_spec_budget_tight_packs_no_drafts(qwen):
    """Zero leftover budget: decode-first strict priority means NO draft
    ever packs (a single slot whose decode token fills the whole budget)
    and the engine degrades to exactly the non-speculative tick.  With
    several slots, ramp-up ticks (others still prefilling) legitimately
    leave room — there the gate is output identity, not a draft-free pack."""
    cfg, params = qwen
    prompts = _tiled_prompts(cfg, 3, seed=37)
    [p] = prompts[:1]
    solo = {}
    for k in (0, 4):
        eng = _engine(params, cfg, batch_size=1, token_budget=1,
                      prefill_chunk=1, spec_k=k)
        u = eng.submit(p, max_tokens=8)
        solo[k] = eng.run()[u]
        if k:
            assert eng.stats["spec_drafted"] == 0
    assert solo[0] == solo[4]
    outs = {}
    for k in (0, 4):
        eng = _engine(params, cfg, batch_size=3, token_budget=3,
                      prefill_chunk=2, spec_k=k)
        uids = [eng.submit(q, max_tokens=8) for q in prompts]
        got = eng.run()
        outs[k] = [got[u] for u in uids]
    assert outs[0] == outs[4]


def test_spec_gated_off_for_hybrid_attention():
    """Windowed/hybrid models can't host drafts (rollback metadata only
    covers the paged global path), so spec_k silently gates to 0 — same
    convention as the prefix cache — and the engine still serves."""
    cfg = get_config("gemma3-4b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    eng = _engine(params, cfg, spec_k=4)
    assert eng.stats["spec_k"] == 0
    rng = np.random.RandomState(41)
    u = eng.submit(rng.randint(0, cfg.vocab_size, 20), max_tokens=6)
    got = eng.run()
    assert len(got[u]) == 6
    assert eng.stats["spec_drafted"] == 0


def test_spec_identical_on_int8_pool(qwen):
    """Quantize-at-write + speculation: draft rows quantize exactly like
    decode rows, and rollback touches only metadata (never scale rows), so
    int8 transcripts stay identical across spec on/off."""
    cfg, params = qwen
    prompts = _tiled_prompts(cfg, 2, seed=43)
    outs = {}
    for k in (0, 4):
        eng = _engine(params, cfg, kv_dtype="int8", spec_k=k)
        uids = [eng.submit(p, max_tokens=16) for p in prompts]
        got = eng.run()
        outs[k] = [got[u] for u in uids]
        assert eng.reclaimable_pages == eng.n_pages
    assert outs[0] == outs[4]


# ---------------------------------------------------------------------------
# Analytic layer: roofline asymmetry + the tuner's spec axis


def test_mixed_bound_draft_terms():
    from repro.core.roofline import mixed_bound

    cfg = get_config("qwen2-1.5b")
    kw = dict(n_decode=8, n_prefill=0, context_len=512, page_size=16,
              kv_dtype="int8")
    base = mixed_bound(cfg, **kw)
    spec = mixed_bound(cfg, draft_tokens=4, accept_rate=0.7, **kw)
    # defaults are bit-identical (the axis is invisible until used)
    assert base == mixed_bound(cfg, draft_tokens=0.0, accept_rate=0.0, **kw)
    # the asymmetry that makes verification near-free on memory-bound
    # ticks: drafts add KV WRITES and compute but zero extra KV READS
    # (they ride the slot's existing page-stream)
    assert spec["kv_read_bytes"] == base["kv_read_bytes"]
    assert spec["kv_write_bytes"] > base["kv_write_bytes"]
    # goodput: tokens_per_s is EMITTED tokens, so acceptance scales it
    assert spec["tokens_per_s"] > base["tokens_per_s"]
    assert spec["accepted_per_slot_tick"] == pytest.approx(1 + 0.7 * 4)
    assert spec["drafted_tokens"] == pytest.approx(8 * 4)
    assert base["accepted_per_slot_tick"] == 1.0
    with pytest.raises(ValueError):
        mixed_bound(cfg, accept_rate=1.5, **kw)
    with pytest.raises(ValueError):
        mixed_bound(cfg, draft_tokens=-1, **kw)


def test_select_serve_defaults_spec_axis():
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100)
    assert out["best"]["spec_k"] == 0  # default axis is non-speculative
    assert "spec@repetitive" not in out["table"][0]["criteria"]
    on = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100,
                               spec_ks=(0, 4))
    assert {r["spec_k"] for r in on["table"]} == {0, 4}
    assert all("spec@repetitive" in r["criteria"] for r in on["table"])
    # where the budget leaves draft room, speculation strictly lifts the
    # repetitive-goodput criterion over its k=0 twin
    knobs = ("token_budget", "prefill_chunk", "page_size", "kv_dtype",
             "scheduler", "n_devices", "host_pool_pages")
    for r in on["table"]:
        if r["spec_k"] == 4 and r["token_budget"] >= 2 * 8:
            twin = next(t for t in on["table"] if t["spec_k"] == 0
                        and all(t[k] == r[k] for k in knobs))
            assert (r["criteria"]["spec@repetitive"]
                    > twin["criteria"]["spec@repetitive"])
    assert on["best"]["spec_k"] == 4
