"""Data pipeline: determinism, skip-ahead, learnability, prefetch."""
import numpy as np

from repro.configs import get_config
from repro.configs.base import ShapeCfg
from repro.data.pipeline import SyntheticLMData

TINY = ShapeCfg("tiny", 64, 4, "train")


def test_batch_at_deterministic():
    cfg = get_config("qwen2-1.5b", smoke=True)
    d1 = SyntheticLMData(cfg, TINY, seed=3)
    d2 = SyntheticLMData(cfg, TINY, seed=3)
    for s in (0, 7, 123):
        a, b = d1.batch_at(s), d2.batch_at(s)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_different_steps_different_batches():
    cfg = get_config("qwen2-1.5b", smoke=True)
    d = SyntheticLMData(cfg, TINY)
    assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])


def test_labels_are_next_tokens():
    cfg = get_config("qwen2-1.5b", smoke=True)
    d = SyntheticLMData(cfg, TINY)
    b = d.batch_at(0)
    # the stream is mostly-deterministic: label[t] should usually equal
    # (token[t] + drift) mod V -> check shift consistency instead
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_learnable_structure():
    """>=90% of transitions follow the per-row drift rule (5% noise)."""
    cfg = get_config("qwen2-1.5b", smoke=True)
    d = SyntheticLMData(cfg, TINY)
    b = d.batch_at(11)
    t, l = b["tokens"], b["labels"]
    V = cfg.vocab_size
    drift = (l[:, :1] - t[:, :1]) % V
    frac = np.mean((t + drift) % V == l)
    assert frac > 0.85


def test_prefetch_iterator_matches_batch_at():
    cfg = get_config("qwen2-1.5b", smoke=True)
    d = SyntheticLMData(cfg, TINY)
    it = d.iter_from(5)
    first = next(it)
    np.testing.assert_array_equal(np.asarray(first["tokens"]),
                                  d.batch_at(5)["tokens"])
    second = next(it)
    np.testing.assert_array_equal(np.asarray(second["tokens"]),
                                  d.batch_at(6)["tokens"])


def test_audio_and_vision_batches():
    for arch in ("hubert-xlarge", "llama-3.2-vision-11b"):
        cfg = get_config(arch, smoke=True)
        d = SyntheticLMData(cfg, TINY)
        b = d.batch_at(0)
        if cfg.frontend == "audio":
            assert b["feats"].shape == (4, 64, cfg.d_model // 2)
        else:
            assert b["img_feats"].shape == (4, cfg.n_img_tokens, cfg.d_model // 2)
