"""Layered serving-API tests: pluggable schedulers (FIFO identity,
prefix-aware family grouping + bounded fairness, SLO interactive-first),
streaming request handles (int compatibility, incremental ``tokens()``,
``result()``), and cancellation (queued / mid-prefill / mid-decode / while
holding shared prefix pages — zero page leak, siblings unperturbed,
property-based interleavings).  PagePool policies in isolation live in
tests/test_pool.py; the pre-refactor engine behavior (which FIFO must
reproduce bit-for-bit) in tests/test_serve.py."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.handle import Request, RequestHandle
from repro.serve.scheduler import (ClassThenFamilyScheduler, EngineView,
                                   FifoScheduler, PrefixAwareScheduler,
                                   Scheduler, SloScheduler, make_scheduler)

KEY = jax.random.PRNGKey(0)
CACHE = 64


@pytest.fixture(scope="module")
def qwen():
    # float32 keeps greedy argmax stable across batching layouts
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, L) for L in lens]


def _solo_decode(params, cfg, prompt, max_tokens, cache_len=CACHE):
    state = M.init_decode_state(params, cfg, 1, cache_len)
    state = M.prefill(params, cfg, state, np.asarray(prompt, np.int32)[None])
    t = jnp.asarray([[int(prompt[-1])]], jnp.int32)
    out = []
    for _ in range(max_tokens):
        logits, state = M.decode_step(params, cfg, state, t)
        tok = int(jnp.argmax(logits[:, -1], -1)[0])
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def _engine(params, cfg, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 32)
    return ServeEngine(params, cfg, **kw)


# ---------------------------------------------------------------------------
# Scheduler unit tests (no engine: a hand-built EngineView)


def _view(queue, page_size=4, cached=()):
    """EngineView over synthetic requests; ``cached`` lists prompts whose
    full pages the fake index already holds."""
    cached = [tuple(int(t) for t in c) for c in cached]

    def match_len(prompt):
        best = 0
        for c in cached:
            n = 0
            while (n + page_size <= min(len(c), len(prompt))
                   and tuple(int(t) for t in prompt[n:n + page_size])
                   == c[n:n + page_size]):
                n += page_size
            best = max(best, n)
        return best

    return EngineView(queue=tuple(queue), slot_requests=(None, None),
                      slot_fill=(0, 0), budget=32, chunk=16,
                      page_size=page_size, match_len=match_len)


def _req(uid, prompt, priority=0):
    return Request(uid, np.asarray(prompt, np.int32), priority=priority)


def test_fifo_orders_are_identity():
    s = FifoScheduler()
    v = _view([_req(1, [1] * 8), _req(2, [2] * 8)])
    assert list(s.admission_order(v)) == [0, 1]
    assert s.decode_order(v, [0, 1]) == [0, 1]
    assert s.prefill_order(v, [1]) == [1]


def test_prefix_aware_groups_families_and_prefers_warm():
    s = PrefixAwareScheduler(depth=8)
    A, B = [7, 7, 7, 7], [9, 9, 9, 9]
    # arrival: A1 B1 A2 B2, with family B already cached -> B group first
    q = [_req(1, A + [1]), _req(2, B + [2]),
         _req(3, A + [3]), _req(4, B + [4])]
    order = list(s.admission_order(_view(q, cached=[B])))
    assert order == [1, 3, 0, 2]
    # nothing cached -> families still contiguous, FIFO between them
    s2 = PrefixAwareScheduler(depth=8)
    assert list(s2.admission_order(_view(q))) == [0, 2, 1, 3]
    # beyond the window, order is untouched
    s3 = PrefixAwareScheduler(depth=2)
    assert list(s3.admission_order(_view(q, cached=[B]))) == [1, 0, 2, 3]


def test_prefix_aware_head_bypass_is_bounded():
    """A head of line with no family must not starve: after max_bypass
    ACTUAL overtakes (a proposed-ahead request left the queue, i.e. was
    admitted past the head), the next round is strict FIFO."""
    s = PrefixAwareScheduler(depth=8, max_bypass=2)
    B = [9, 9, 9, 9]
    head = _req(1, [5, 5, 5, 5, 1])

    def q_with(uids):
        return [head] + [_req(u, B + [u]) for u in uids]

    # each round proposes the warm B family ahead of the head, and one B
    # member is then admitted (gone from the next round's queue)
    assert list(s.admission_order(_view(q_with([2, 3, 4]), cached=[B])))[0] == 1
    assert list(s.admission_order(_view(q_with([3, 4]), cached=[B])))[0] == 1
    # two real overtakes: the budget is spent, strict FIFO until admitted
    assert list(s.admission_order(_view(q_with([4]), cached=[B]))) == [0, 1]


def test_stall_blocked_head_gets_fifo_backstop():
    """Liveness: an infeasible candidate ranked ahead of a feasible head
    (admission stops at the first infeasible request, so nothing admits
    and nothing ever leaves the queue) must not block the head forever —
    consecutive no-progress proposals exhaust the same budget and force a
    strict-FIFO round; once the head admits, the budget refreshes and
    grouping resumes."""
    s = PrefixAwareScheduler(depth=8, max_bypass=2)
    B = [9, 9, 9, 9]
    q = [_req(1, [5, 5, 5, 5, 1]), _req(2, B + [2]), _req(3, B + [3])]
    v = _view(q, cached=[B])
    assert list(s.admission_order(v))[0] == 1  # proposal round 1
    assert list(s.admission_order(v))[0] == 1  # stall 1 counted, retries
    assert list(s.admission_order(v)) == [0, 1, 2]  # stall 2: backstop
    # the FIFO round admits the head -> new head, fresh budget, grouping
    q2 = [_req(4, [6, 6, 6, 6, 4]), _req(2, B + [2]), _req(3, B + [3])]
    assert list(s.admission_order(_view(q2, cached=[B])))[0] == 1


def test_slo_orders_by_priority_class_stable():
    s = SloScheduler()
    q = [_req(1, [1] * 8), _req(2, [2] * 8, priority=1),
         _req(3, [3] * 8), _req(4, [4] * 8, priority=2)]
    v = EngineView(queue=tuple(q), slot_requests=tuple(q),
                   slot_fill=(0, 0, 0, 0), budget=32, chunk=16,
                   page_size=4, match_len=lambda p: 0)
    assert list(s.admission_order(v)) == [3, 1, 0, 2]
    assert s.prefill_order(v, [0, 1]) == [1, 0]
    # decode needs no ordering (every ready slot packs each tick): slo
    # keeps the protocol's identity so the engine skips nothing for it
    assert s.decode_order(v, [0, 1, 2, 3]) == [0, 1, 2, 3]


def test_slo_head_bypass_is_bounded():
    """A batch head of line under a saturating interactive stream is
    admitted within max_bypass actual overtakes: priority inverts latency,
    never liveness."""
    s = SloScheduler(max_bypass=2)
    head = _req(1, [1] * 8)
    # interactive arrivals keep refilling the window; each round the
    # previous one was admitted past the still-waiting batch head
    assert list(s.admission_order(
        _view([head, _req(2, [2] * 8, priority=1)])))[0] == 1
    assert list(s.admission_order(
        _view([head, _req(3, [3] * 8, priority=1)])))[0] == 1
    assert list(s.admission_order(
        _view([head, _req(4, [4] * 8, priority=1)]))) == [0, 1]


def test_class_then_family_partitions_then_groups():
    """The composite policy: priority classes first (SLO's axis), family
    grouping within each class (prefix-aware's axis), warm families first
    within a class."""
    s = ClassThenFamilyScheduler(depth=8)
    A, B = [7, 7, 7, 7], [9, 9, 9, 9]
    q = [_req(1, A + [1]),               # batch, family A
         _req(2, B + [2]),               # batch, family B (cached)
         _req(3, A + [3], priority=1),   # interactive, family A
         _req(4, A + [4]),               # batch, family A
         _req(5, B + [5], priority=1)]   # interactive, family B (cached)
    order = list(s.admission_order(_view(q, cached=[B])))
    # interactive class first (warm B before cold A), then batch likewise;
    # members FIFO within their family
    assert order == [4, 2, 1, 0, 3]
    # beyond the window, order untouched
    s2 = ClassThenFamilyScheduler(depth=2)
    assert list(s2.admission_order(_view(q, cached=[B])))[2:] == [2, 3, 4]


def test_class_then_family_is_tier_aware():
    """With a tiered pool the view carries match_split: within one class,
    device-warm families admit before host-warm before cold (a host hit
    pays a promotion copy; a miss pays re-prefill)."""
    D, H, C = [1, 1, 1, 1], [2, 2, 2, 2], [3, 3, 3, 3]
    q = [_req(1, C + [1]), _req(2, H + [2]), _req(3, D + [3])]

    def split(prompt):
        head = tuple(int(t) for t in prompt[:4])
        if head == tuple(D):
            return 4, 0  # 4 device-resident tokens
        if head == tuple(H):
            return 0, 4  # 4 host-resident tokens
        return 0, 0

    v = EngineView(queue=tuple(q), slot_requests=(None, None),
                   slot_fill=(0, 0), budget=32, chunk=16, page_size=4,
                   match_len=lambda p: sum(split(p)), match_split=split)
    s = ClassThenFamilyScheduler(depth=8)
    assert list(s.admission_order(v)) == [2, 1, 0]
    # without match_split the same view degrades to match_len warmth:
    # device- and host-warm tie at 4 matched tokens, FIFO breaks the tie
    v2 = dataclasses.replace(v, match_split=None)
    assert list(ClassThenFamilyScheduler(depth=8).admission_order(v2)) \
        == [1, 2, 0]


def test_class_then_family_prefill_prefers_interactive():
    s = ClassThenFamilyScheduler()
    q = [_req(1, [1] * 8), _req(2, [2] * 8, priority=1)]
    v = EngineView(queue=(), slot_requests=tuple(q), slot_fill=(0, 0),
                   budget=32, chunk=16, page_size=4, match_len=lambda p: 0)
    assert s.prefill_order(v, [0, 1]) == [1, 0]


def test_class_then_family_prefill_order_mixed_priorities():
    """Prefill packing under a full mixed-class slot set: strictly by
    priority class (higher first), slot index breaking ties WITHIN a class
    — deterministic for any slot permutation of the same requests."""
    s = ClassThenFamilyScheduler()
    q = [_req(1, [1] * 8), _req(2, [2] * 8, priority=2),
         _req(3, [3] * 8, priority=1), _req(4, [4] * 8, priority=2),
         _req(5, [5] * 8)]
    v = EngineView(queue=(), slot_requests=tuple(q),
                   slot_fill=(0,) * 5, budget=32, chunk=16, page_size=4,
                   match_len=lambda p: 0)
    assert s.prefill_order(v, [0, 1, 2, 3, 4]) == [1, 3, 2, 0, 4]
    # a subset of filling slots keeps the same relative order
    assert s.prefill_order(v, [4, 2, 1]) == [1, 2, 4]
    assert s.prefill_order(v, [0, 4]) == [0, 4]


def test_class_then_family_prefill_unperturbed_by_host_tier_hits():
    """Warmth — device OR host tier — is an ADMISSION concern
    (promotion-cost ordering of ``_family_order``); once slots are
    filling, prefill packing must order by class alone.  The same
    mixed-priority slot set keeps an identical prefill order whether the
    view reports cold, device-warm, or host-warm prompts — while the
    admission side of the SAME view does reorder on the tier split."""
    D, H = [1, 1, 1, 1], [2, 2, 2, 2]
    # batch device-warm, batch host-warm, interactive cold
    q = [_req(1, D + [1]), _req(2, H + [2]), _req(3, [3] * 8, priority=1)]

    def split(prompt):
        head = tuple(int(t) for t in prompt[:4])
        return (4, 0) if head == tuple(D) else \
            (0, 4) if head == tuple(H) else (0, 0)

    s = ClassThenFamilyScheduler(depth=8)
    cold = EngineView(queue=tuple(q), slot_requests=tuple(q),
                      slot_fill=(0, 0, 0), budget=32, chunk=16, page_size=4,
                      match_len=lambda p: 0)
    tiered = dataclasses.replace(cold, match_len=lambda p: sum(split(p)),
                                 match_split=split)
    # admission sees the tiers: interactive class first, then device-warm
    # batch before host-warm batch
    assert list(s.admission_order(tiered)) == [2, 0, 1]
    # prefill does not: interactive first, batch slots in slot order, and
    # the host-tier hit moves nothing
    for v in (cold, tiered):
        assert s.prefill_order(v, [0, 1, 2]) == [2, 0, 1]


def test_class_then_family_prefill_with_host_hits_end_to_end(qwen):
    """Engine-level: a TIERED pool under the composite policy — a batch
    family whose prefix was demoted to host RAM replays (host hits pay a
    promotion) while an interactive arrival prefills; the interactive
    request takes the prefill budget first (first token within its own
    prefill ticks, not after the batch wave) and every transcript stays
    exactly the solo tokens."""
    cfg, params = qwen
    page = 8
    eng = _engine(params, cfg, batch_size=2, scheduler="class-then-family",
                  max_pages=4, host_pages=12, prefill_chunk=8,
                  cache_len=CACHE)
    [fam] = _prompts(cfg, [2 * page], seed=310)
    family = [np.concatenate([fam, s]) for s in _prompts(cfg, [2, 3],
                                                         seed=311)]
    # populate: the family's full prefix pages index; then a filler wave
    # allocates past the 4-page device pool, demoting the cached prefix
    for p in family:
        eng.submit(p, max_tokens=4)
    eng.run()
    [filler] = _prompts(cfg, [3 * page], seed=313)
    eng.submit(filler, max_tokens=4)
    eng.run()
    assert eng.stats["demotions"] >= 1
    # replay the family (host-warm batch) with an interactive arrival
    hb = [eng.submit(p, max_tokens=4) for p in family]
    hi = eng.submit(_prompts(cfg, [12], seed=312)[0], max_tokens=4,
                    priority=1)
    got = eng.run()
    assert eng.stats["host_hits"] >= 1
    for h, p in zip(hb, family):
        assert got[h] == _solo_decode(params, cfg, p, 4)
    assert got[hi] == _solo_decode(params, cfg, hi.request.prompt, 4)
    assert eng.reclaimable_pages == eng.n_pages


def test_class_then_family_head_bypass_is_bounded():
    """The composite inherits the shared fairness backstop: a batch head
    bypassed max_bypass times by interactive arrivals pins strict FIFO."""
    s = ClassThenFamilyScheduler(max_bypass=2)
    head = _req(1, [1] * 8)
    assert list(s.admission_order(
        _view([head, _req(2, [2] * 8, priority=1)])))[0] == 1
    assert list(s.admission_order(
        _view([head, _req(3, [3] * 8, priority=1)])))[0] == 1
    assert list(s.admission_order(
        _view([head, _req(4, [4] * 8, priority=1)]))) == [0, 1]


def test_make_scheduler_resolution_and_validation():
    assert isinstance(make_scheduler(None), FifoScheduler)
    assert isinstance(make_scheduler("slo"), SloScheduler)
    with pytest.raises(ValueError):
        make_scheduler("lifo")
    with pytest.raises(TypeError):
        make_scheduler(object())
    custom = Scheduler()  # protocol defaults are a valid policy
    assert make_scheduler(custom) is custom
    with pytest.raises(ValueError):
        PrefixAwareScheduler(depth=0)


def test_engine_rejects_malformed_admission_order(qwen):
    cfg, params = qwen

    class Broken(Scheduler):
        name = "broken"

        def admission_order(self, view):
            return [0, 0]

    eng = _engine(params, cfg, scheduler=Broken())
    eng.submit(np.arange(1, 9, dtype=np.int32), max_tokens=2)
    with pytest.raises(ValueError):
        eng.tick()


def test_duck_typed_scheduler_without_name(qwen):
    """make_scheduler promises duck-typing on the three ordering methods
    alone; an object with no ``name`` must still construct and serve (the
    engine falls back to the class name for stats and errors)."""
    cfg, params = qwen

    class Nameless:
        def admission_order(self, view):
            return range(len(view.queue))

        def decode_order(self, view, ready):
            return ready

        def prefill_order(self, view, filling):
            return filling

    eng = _engine(params, cfg, scheduler=Nameless())
    assert eng.stats["scheduler"] == "Nameless"
    [p] = _prompts(cfg, [6], seed=110)
    h = eng.submit(p, max_tokens=2)
    assert eng.run()[h] == _solo_decode(params, cfg, p, 2)


def test_engine_rejects_malformed_pack_order(qwen):
    """A pack order must permute the engine's slot list — a duplicate
    would sample a slot twice, an omission would stall a decoder."""
    cfg, params = qwen

    class Broken(Scheduler):
        name = "broken-pack"

        def decode_order(self, view, ready):
            return list(ready) + list(ready)

    eng = _engine(params, cfg, scheduler=Broken())
    eng.submit(np.arange(1, 9, dtype=np.int32), max_tokens=2)
    with pytest.raises(ValueError):
        eng.run()  # raises on the first tick with a decoding slot


# ---------------------------------------------------------------------------
# Engine-level: policies change order, never tokens


def test_outputs_identical_across_policies(qwen):
    """Greedy outputs depend only on the prompt: every named policy must
    produce token-identical results on shared-prefix traffic with mixed
    priorities — scheduling reorders work, never changes it."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [16], seed=90)
    prompts = ([np.concatenate([shared, s])
                for s in _prompts(cfg, [4, 6], seed=91)]
               + _prompts(cfg, [7, 11], seed=92))
    outs = {}
    for sched in ("fifo", "prefix-aware", "slo", "class-then-family"):
        eng = _engine(params, cfg, scheduler=sched)
        uids = [eng.submit(p, max_tokens=4, priority=i % 2)
                for i, p in enumerate(prompts)]
        got = eng.run()
        outs[sched] = [got[u] for u in uids]
        assert eng.stats["traces"] == 1
        assert eng.stats["scheduler"] == sched
        assert eng.reclaimable_pages == eng.n_pages
    assert (outs["fifo"] == outs["prefix-aware"] == outs["slo"]
            == outs["class-then-family"])
    for out, p in zip(outs["fifo"], prompts):
        assert out == _solo_decode(params, cfg, p, 4)


def test_prefix_aware_beats_fifo_on_family_traffic(qwen):
    """The structural win behind the benchmark's tokens/s gate, asserted on
    DETERMINISTIC counters: interleaved prefix families through a pool too
    small to hold them all -> the prefix-aware window reuses strictly more
    cached tokens, packs strictly fewer prefill tokens, and evicts less."""
    cfg, params = qwen
    fams = _prompts(cfg, [24, 24], seed=93)  # 3 full pages each
    prompts = [np.concatenate([fams[f], s]) for s in _prompts(
        cfg, [3, 4, 5], seed=94) for f in range(2)]  # A B A B A B
    stats = {}
    for sched in ("fifo", "prefix-aware"):
        eng = _engine(params, cfg, batch_size=1, scheduler=sched,
                      max_pages=5)  # one 4-page request + 1 spare
        uids = [eng.submit(p, max_tokens=2) for p in prompts]
        got = eng.run()
        for u, p in zip(uids, prompts):
            assert got[u] == _solo_decode(params, cfg, p, 2)
        stats[sched] = eng.stats
    assert (stats["prefix-aware"]["prefix_tokens_reused"]
            > stats["fifo"]["prefix_tokens_reused"])
    assert (stats["prefix-aware"]["packed_tokens"]
            < stats["fifo"]["packed_tokens"])
    assert (stats["prefix-aware"]["evictions"]
            <= stats["fifo"]["evictions"])


def test_slo_admits_interactive_before_earlier_batch(qwen):
    """An interactive arrival jumps a queue of batch documents: it finishes
    before batch requests that were submitted earlier (FIFO would finish it
    last), with everyone's tokens still exact."""
    cfg, params = qwen
    docs = _prompts(cfg, [40, 40, 40], seed=95)
    [chat] = _prompts(cfg, [5], seed=96)

    def run(sched):
        eng = _engine(params, cfg, batch_size=1, scheduler=sched)
        uids = [eng.submit(p, max_tokens=2) for p in docs]
        uids.append(eng.submit(chat, max_tokens=2, priority=1))
        got = eng.run()
        for u, p in zip(uids, docs + [chat]):
            assert got[u] == _solo_decode(params, cfg, p, 2)
        return eng.completion_order.index(uids[-1])

    assert run("slo") == 0  # interactive first
    assert run("fifo") == 3  # arrival order


# ---------------------------------------------------------------------------
# Streaming handles


def test_handle_is_int_compatible(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg)
    [p] = _prompts(cfg, [6], seed=97)
    h = eng.submit(p, max_tokens=3)
    assert isinstance(h, int) and isinstance(h, RequestHandle)
    assert h == h.uid and {h: "x"}[h.uid] == "x" and f"{h:3d}" == f"{h.uid:3d}"
    assert not h.done
    got = eng.run()
    assert h.done and not h.cancelled
    assert got[h] == h.result() == _solo_decode(params, cfg, p, 3)
    assert sorted([h]) == [h]
    assert "done" in repr(h)
    # pickle / deepcopy degrade to the plain uid int (what pre-handle
    # drivers shipped across process and cache boundaries)
    import copy
    import pickle
    assert pickle.loads(pickle.dumps(h)) == h.uid
    assert copy.deepcopy([h]) == [h.uid]
    assert type(copy.deepcopy(h)) is int


def test_handle_tokens_streams_incrementally(qwen):
    """tokens() yields each token as ticks produce it; two interleaved
    iterators share the same ticks and both finish with exact outputs."""
    cfg, params = qwen
    pa, pb = _prompts(cfg, [9, 13], seed=98)
    eng = _engine(params, cfg)
    ha = eng.submit(pa, max_tokens=4)
    hb = eng.submit(pb, max_tokens=6)
    ita, itb = ha.tokens(), hb.tokens()
    seen_a = [next(ita)]  # drives ticks until a's first token
    ticks_at_first = eng.stats["ticks"]
    assert ticks_at_first >= 1 and len(ha.request.out_tokens) == 1
    seen_a += list(ita)
    seen_b = list(itb)  # b progressed on a's ticks; replays buffered tokens
    assert seen_a == _solo_decode(params, cfg, pa, 4)
    assert seen_b == _solo_decode(params, cfg, pb, 6)
    assert eng.idle


def test_handle_result_drains_only_as_needed(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg)
    [p] = _prompts(cfg, [7], seed=99)
    h = eng.submit(p, max_tokens=2)
    assert h.result() == _solo_decode(params, cfg, p, 2)
    # a 7-token prompt packs prefill + its first decode token in ONE tick,
    # so a 1-tick iterator yields exactly one token then times out
    it = eng.submit(p, max_tokens=30).tokens(max_ticks=1)
    assert next(it) is not None
    with pytest.raises(TimeoutError):
        next(it)
    eng.run()  # drain the timed-out request: the engine stays reusable


# ---------------------------------------------------------------------------
# Cancellation: queued / mid-prefill / mid-decode / shared pages


def test_cancel_queued_request_never_takes_pages(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, batch_size=1)
    pa, pb = _prompts(cfg, [8, 8], seed=100)
    ha = eng.submit(pa, max_tokens=2)
    hb = eng.submit(pb, max_tokens=2)  # queued behind a
    assert hb.cancel() and hb.cancelled and hb.done
    assert not hb.cancel()  # idempotent no-op
    got = eng.run()
    assert got[ha] == _solo_decode(params, cfg, pa, 2)
    assert hb not in got and hb.result() == []
    assert eng.stats["cancelled"] == 1
    assert eng.reclaimable_pages == eng.n_pages


def test_cancel_mid_prefill_returns_pages(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, batch_size=1, prefill_chunk=8)
    [p] = _prompts(cfg, [40], seed=101)
    h = eng.submit(p, max_tokens=4)
    eng.tick()  # one 8-token chunk of a 40-token prompt: mid-prefill
    assert h.request.out_tokens == [] and not h.done
    assert h.cancel()
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
    # the cancelled prefill's FULL pages were real work: they stay cached
    # and a resubmit rides them to the exact solo tokens
    assert eng.cached_pages >= 1
    h2 = eng.submit(p, max_tokens=4)
    assert eng.run()[h2] == _solo_decode(params, cfg, p, 4)
    assert eng.stats["prefix_hits"] >= 1


def test_cancel_mid_decode_frees_slot_for_queue(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, batch_size=1)
    pa, pb = _prompts(cfg, [9, 11], seed=102)
    ha = eng.submit(pa, max_tokens=30)
    hb = eng.submit(pb, max_tokens=3)  # blocked: single slot
    for _ in range(4):
        eng.tick()
    assert 0 < len(ha.request.out_tokens) < 30
    assert ha.cancel()
    partial = ha.result()  # cancelled: returns what was generated
    assert partial == ha.request.out_tokens and len(partial) < 30
    got = eng.run()
    assert got[hb] == _solo_decode(params, cfg, pb, 3)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


def test_cancel_with_shared_prefix_pages_keeps_siblings_exact(qwen):
    """Cancel a request that holds refs on ANOTHER request's prefix pages
    mid-flight: the shared pages must survive for the sibling (refcount
    drops 2->1, not ->0), the sibling's tokens never change, and after the
    sibling completes the pool is fully reclaimable."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [24], seed=103)
    a, b = [np.concatenate([shared, s])
            for s in _prompts(cfg, [4, 6], seed=104)]
    eng = _engine(params, cfg)
    ha = eng.submit(a, max_tokens=12)
    for _ in range(3):  # a prefills (indexing its pages) and starts decoding
        eng.tick()
    hb = eng.submit(b, max_tokens=8)
    eng.tick()  # b admitted, mapping a's 3 indexed prefix pages (ref 2)
    assert eng.stats["prefix_hits"] == 1
    assert (eng._ref == 2).sum() == 24 // 8
    assert hb.cancel()
    assert (eng._ref == 2).sum() == 0  # shared pages back to a's ref only
    assert (eng._ref < 0).sum() == 0
    got = eng.run()
    assert got[ha] == _solo_decode(params, cfg, a, 12)  # sibling unperturbed
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
    # ...and the mirror image: cancel the OWNER while the sibling rides its
    # pages — the sibling must keep them alive
    ha2 = eng.submit(a, max_tokens=12)
    for _ in range(2):
        eng.tick()
    hb2 = eng.submit(b, max_tokens=6)
    eng.tick()
    assert ha2.cancel()
    got = eng.run()
    assert got[hb2] == _solo_decode(params, cfg, b, 6)
    assert eng.reclaimable_pages == eng.n_pages


def _drive_interleaving(eng, cfg, ops):
    """Shared property body: drive one op interleaving, assert full drain."""
    [shared] = _prompts(cfg, [16], seed=105)
    handles = []
    rng = np.random.RandomState(sum(i for _, i in ops))
    for op, i in ops:
        if op == "submit":
            prompt = (np.concatenate([shared,
                                      rng.randint(0, cfg.vocab_size, 1 + i)])
                      if i % 2 else rng.randint(0, cfg.vocab_size, 4 + i))
            handles.append(eng.submit(prompt, max_tokens=1 + i % 4))
        elif op == "tick":
            eng.tick()
        elif handles:
            handles[i % len(handles)].cancel()
    eng.run()
    assert all(h.done for h in handles)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "cancel"]),
                              st.integers(0, 7)),
                    min_size=3, max_size=14))
def test_cancel_interleavings_never_leak_pages(qwen, ops):
    """Property (the acceptance gate): ANY interleaving of submit / tick /
    cancel — cancels hitting queued, prefilling, decoding, finished, and
    prefix-sharing requests alike — drains to a fully reclaimable pool with
    every refcount at zero."""
    cfg, params = qwen
    if not hasattr(test_cancel_interleavings_never_leak_pages, "_eng"):
        # one engine (and prefix cache) across examples: later examples
        # start from whatever cache state earlier ones left — more
        # adversarial than a fresh pool, and an order of magnitude faster
        test_cancel_interleavings_never_leak_pages._eng = _engine(
            params, cfg, max_pages=12)
    _drive_interleaving(test_cancel_interleavings_never_leak_pages._eng,
                        cfg, ops)


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "cancel"]),
                              st.integers(0, 7)),
                    min_size=3, max_size=14))
def test_cancel_interleavings_never_leak_pages_meshed(qwen, ops):
    """The same no-leak property through a MESHED engine (1-device mesh —
    the parent process has one device; 2/4-device interleavings run in
    tests/test_serve_tp.py subprocesses): host-side page accounting must be
    device-count-agnostic, so putting the compiled programs under a mesh
    and sharded-state placement must not perturb any refcount path."""
    from repro.launch.mesh import make_mesh

    cfg, params = qwen
    fn = test_cancel_interleavings_never_leak_pages_meshed
    if not hasattr(fn, "_eng"):
        fn._eng = _engine(params, cfg, max_pages=12,
                          mesh=make_mesh((1,), ("model",)))
    _drive_interleaving(fn._eng, cfg, ops)


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "cancel"]),
                              st.integers(0, 7)),
                    min_size=3, max_size=14))
def test_cancel_interleavings_never_leak_pages_tiered(qwen, ops):
    """The same no-leak property through a TIERED engine whose device pool
    is smaller than the traffic's working set, so interleavings demote,
    promote, and host-evict continuously — plus the cross-tier invariant:
    the engine's host byte store mirrors the pool's host residency exactly,
    and host slots stay partitioned free/resident."""
    cfg, params = qwen
    fn = test_cancel_interleavings_never_leak_pages_tiered
    if not hasattr(fn, "_eng"):
        fn._eng = _engine(params, cfg, max_pages=6, host_pages=4)
    eng = fn._eng
    _drive_interleaving(eng, cfg, ops)
    assert set(eng._host_store) == set(eng.pool._host_node)
    assert sorted(eng.pool._host_free + list(eng.pool._host_node)) == list(
        range(eng.host_pages))


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "cancel"]),
                              st.integers(0, 7)),
                    min_size=3, max_size=14))
def test_cancel_interleavings_never_leak_pages_speculative(qwen, ops):
    """The same no-leak property through a SPECULATIVE engine: interleaved
    submits use repetitive (tiled-pattern) prompts so ticks continuously
    draft, accept, reject, and roll back while cancels land on slots with
    draft chains in flight — every interleaving must still drain to a
    fully reclaimable pool with zero refcounts."""
    cfg, params = qwen
    fn = test_cancel_interleavings_never_leak_pages_speculative
    if not hasattr(fn, "_eng"):
        fn._eng = _engine(params, cfg, max_pages=12, spec_k=3)
    eng = fn._eng
    [shared] = _prompts(cfg, [16], seed=106)
    handles = []
    rng = np.random.RandomState(sum(i for _, i in ops))
    before = eng.stats["spec_drafted"]
    for op, i in ops:
        if op == "submit":
            # alternate prefix-sharing, repetitive (drafts fire), random
            if i % 3 == 0:
                prompt = np.concatenate(
                    [shared, rng.randint(0, cfg.vocab_size, 1 + i)])
            elif i % 3 == 1:
                prompt = np.tile(rng.randint(0, cfg.vocab_size, 3), 5 + i)
            else:
                prompt = rng.randint(0, cfg.vocab_size, 4 + i)
            handles.append(eng.submit(prompt, max_tokens=1 + i % 6))
        elif op == "tick":
            eng.tick()
        elif handles:
            handles[i % len(handles)].cancel()
    eng.run()
    assert all(h.done for h in handles)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


# ---------------------------------------------------------------------------
# Tuned config carries the scheduler axis


def test_select_serve_defaults_tunes_scheduler():
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100)
    assert out["best"]["scheduler"] in ("fifo", "prefix-aware", "slo",
                                        "class-then-family")
    assert all("scheduler" in r for r in out["table"])
    assert out["best"]["host_pool_pages"] == 0  # default axis is untiered
    only = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100,
                                 schedulers=("prefix-aware",))
    assert only["best"]["scheduler"] == "prefix-aware"


def test_select_serve_defaults_host_pool_axis():
    """A nonzero host_pool_pages axis adds the spill@replay criterion and
    the tiered point wins it: warm-replay decode priced at promotion
    bandwidth beats re-prefilling the spilled prefix from scratch."""
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100,
                                host_pool_pages=(0, 64))
    assert out["best"]["host_pool_pages"] == 64
    assert all("spill@replay" in r["criteria"] for r in out["table"])
    tiered = {r["host_pool_pages"]: r["criteria"]["spill@replay"]
              for r in out["table"]
              if r["scheduler"] == out["best"]["scheduler"]
              and r["token_budget"] == out["best"]["token_budget"]
              and r["page_size"] == out["best"]["page_size"]
              and r["kv_dtype"] == out["best"]["kv_dtype"]
              and r["n_devices"] == out["best"]["n_devices"]
              and r["prefill_chunk"] == out["best"]["prefill_chunk"]}
    assert tiered[64] > tiered[0]
