"""Core paper-technique modules: affinity, memory modes, sweep, roofline,
HLO cost walker, memory model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import affinity, memory_modes
from repro.core.hlo_cost import analyze
from repro.core.roofline import V5E, mixed_bound, roofline_terms
from repro.core.sweep import SweepCell, factorizations, score
from repro.core.memory_model import estimate
from repro.configs import SHAPES_BY_NAME, get_config


# ---------------------------------------------------------------------------
# affinity (taskset-pinning analogue)


def test_pinned_model_rings_are_single_hop():
    p = affinity.pinned_placement()
    assert p.axis_ring_cost["model"] == pytest.approx(1.0)


def test_naive_placement_is_worse():
    p = affinity.pinned_placement()
    n = affinity.naive_placement()
    assert n.axis_ring_cost["model"] > 2 * p.axis_ring_cost["model"]
    rows = affinity.placement_table()
    assert {r["placement"] for r in rows} == {"pinned", "naive"}


def test_torus_hop_symmetry():
    c = affinity.torus_coords()
    assert affinity.hop_distance(c[0], c[15]) == 1  # wrap-around column
    assert affinity.hop_distance(c[0], c[8 * 16 + 8]) == 16  # antipode


# ---------------------------------------------------------------------------
# memory modes (MCDRAM analogue)


def test_memory_modes_vmem_budget():
    for m in memory_modes.tiling_grid():
        assert m.vmem_bytes() <= 100 * 2**20
    assert memory_modes.MODES["cache"].remat == "dots"
    cfg = get_config("qwen2-1.5b", smoke=True)
    assert memory_modes.apply(cfg, memory_modes.HYBRID).remat == "full"


# ---------------------------------------------------------------------------
# sweep protocol


def test_factorizations_cover_paper_range():
    f = factorizations(256)
    assert (1, 256) in f and (256, 1) in f and (16, 16) in f
    assert all(p * t == 256 for p, t in f)


def test_constant_memory_protocol():
    """N = N0/√Nproc keeps total bytes ~constant (paper's 55 GB protocol)."""
    base = SweepCell(1, 256).n ** 2 * 1
    for nproc in (4, 16, 64, 256):
        cell = SweepCell(nproc, 256 // nproc)
        total = nproc * cell.n ** 2
        assert abs(total - base) / base < 0.1, (nproc, total, base)


def test_factorizations_are_power_of_two_splits():
    assert factorizations(8) == [(1, 8), (2, 4), (4, 2), (8, 1)]
    assert factorizations(1) == [(1, 1)]
    for n in (4, 16, 64):
        assert all(p * t == n for p, t in factorizations(n))
        assert len(factorizations(n)) == n.bit_length()


def test_sweepcell_n_alignment_and_monotonicity():
    """SweepCell.n stays a 256-aligned, floor-clamped, non-increasing
    function of Nproc (the constant-total-memory protocol)."""
    prev = None
    for nproc in (1, 2, 4, 8, 16, 64, 256):
        n = SweepCell(nproc, 256 // min(nproc, 256)).n
        assert n % 256 == 0 and n >= 256
        assert prev is None or n <= prev
        prev = n
    assert SweepCell(256, 1, n0=512).n == 256  # floor clamp


@pytest.mark.slow
def test_run_sweep_cache_never_scores_below_flat(multidevice):
    """Golden check on a small pod: single-pass ('cache') accumulation never
    scores below 8-pass ('flat') for the same cell — the paper's
    MCDRAM-cache-vs-flat ordering, reproduced by the roofline scorer."""
    import json

    out = multidevice("""
        import json
        from repro.core.sweep import run_sweep
        rows = run_sweep(n_units=8, placements=("colsplit",),
                         memories=("cache", "flat"), n0=4096)
        print(json.dumps([{k: r[k] for k in
                           ("nproc", "nthread", "memory", "peak_fraction")}
                          for r in rows]))
    """, n_devices=8)
    rows = json.loads(out.strip().splitlines()[-1])
    cells = {}
    for r in rows:
        cells.setdefault((r["nproc"], r["nthread"]), {})[r["memory"]] = \
            r["peak_fraction"]
    assert len(cells) == 4  # (1,8) (2,4) (4,2) (8,1)
    for cell, scores in cells.items():
        assert scores["cache"] >= scores["flat"], (cell, scores)


def test_score_identifies_dominant_term():
    row = {"flops_per_device": 197e12, "bytes_per_device": 1e9,
           "collective_bytes_per_device": 0.0, "model_flops": 197e12,
           "n_devices": 1, "peak_bytes": 0}
    s = score(row)
    assert s["dominant"] == "compute"
    assert s["peak_fraction"] == pytest.approx(1.0, rel=1e-6)


# ---------------------------------------------------------------------------
# HLO cost walker


def test_walker_counts_loop_trips():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ jnp.ones((32, 32))), None
        c, _ = jax.lax.scan(body, x, None, length=11)
        return c.sum()

    compiled = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 32), jnp.float32)).compile()
    r = analyze(compiled.as_text())
    assert r["flops"] == pytest.approx(11 * 2 * 4 * 32 * 32, rel=0.01)


def test_walker_nested_scans():
    def g(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ jnp.eye(16), None
            c2, _ = jax.lax.scan(inner, c, None, length=5)
            return c2, None
        c, _ = jax.lax.scan(outer, x, None, length=3)
        return c.sum()

    compiled = jax.jit(g).lower(jax.ShapeDtypeStruct((2, 16), jnp.float32)).compile()
    r = analyze(compiled.as_text())
    assert r["flops"] == pytest.approx(15 * 2 * 2 * 16 * 16, rel=0.01)


# ---------------------------------------------------------------------------
# roofline + memory model


def test_roofline_terms_math():
    res = {"arch": "qwen2-1.5b", "shape": "train_4k", "mesh": "16x16",
           "n_devices": 256, "flops_per_device": 197e12,
           "bytes_per_device": 819e9, "collective_bytes_per_device": 50e9}
    t = roofline_terms(res)
    assert t["compute_s"] == pytest.approx(1.0)
    # memory term is the ANALYTIC traffic model (cfg-derived, not the row's
    # HLO proxy — that one is reported separately)
    assert t["memory_s_hlo_proxy"] == pytest.approx(1.0)
    assert t["memory_s"] > 0
    assert t["collective_s"] == pytest.approx(1.0)
    assert 0 < t["useful_flop_ratio"] < 1


def test_mixed_bound_blend():
    """The ragged-tick bound: a mixed pack is never slower than running the
    same tokens as separate prefill + decode programs (the parameter sweep
    is paid once), and page rounding only adds traffic."""
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b")
    mixed = mixed_bound(cfg, n_decode=8, n_prefill=120, context_len=2048,
                        page_size=16)
    assert mixed["tokens_per_s"] > 0
    assert mixed["speedup_vs_two_phase"] >= 1.0
    # degenerate blends still make sense
    dec_only = mixed_bound(cfg, n_decode=8, n_prefill=0, context_len=2048)
    pre_only = mixed_bound(cfg, n_decode=0, n_prefill=128, context_len=2048)
    assert dec_only["speedup_vs_two_phase"] == pytest.approx(1.0)
    assert pre_only["speedup_vs_two_phase"] == pytest.approx(1.0)
    # small-batch serving is memory-bound: the blend amortizes the param
    # sweep, so tokens/s of the mix beats the decode-only tick's
    assert mixed["tokens_per_s"] > dec_only["tokens_per_s"]
    # coarser pages -> more KV traffic -> no faster
    coarse = mixed_bound(cfg, n_decode=8, n_prefill=120, context_len=2048,
                         page_size=256)
    assert coarse["tick_s"] >= mixed["tick_s"]


def test_memory_model_scaling():
    cfg = get_config("arctic-480b")
    mesh = {"data": 16, "model": 16}
    train = estimate(cfg, SHAPES_BY_NAME["train_4k"], mesh, microbatches=8)
    assert train["params"] == pytest.approx(480e9 * 2 / 256, rel=0.15)
    assert train["total"] < 16 * 2**30  # fits v5e with microbatching
    dec = estimate(cfg, SHAPES_BY_NAME["decode_32k"], mesh)
    # KV: 2*2B*35L*128B*32k*8kv*128hd / 256 devices
    expect_kv = 2 * 2 * 35 * 128 * 32768 * 8 * 128 / 256
    assert dec["kv_cache"] == pytest.approx(expect_kv, rel=0.01)


def test_multipod_halves_per_device():
    cfg = get_config("glm4-9b")
    one = estimate(cfg, SHAPES_BY_NAME["train_4k"], {"data": 16, "model": 16})
    two = estimate(cfg, SHAPES_BY_NAME["train_4k"],
                   {"pod": 2, "data": 16, "model": 16})
    assert two["params"] == pytest.approx(one["params"] / 2, rel=1e-6)
