"""launch.hillclimb — the perf-hillclimbing driver (hypothesis → lower →
score → confirm/refute log), wired into tier 1.

The module was a seed asset no test imported.  Two things need gating:
the import-time footgun (the module prepends a 512-device
``xla_force_host_platform_device_count`` to ``XLA_FLAGS`` for its CLI use
— importing it into a live process must not perturb an already-initialized
jax backend, and tests must restore the env), and one tiny-cell
``run_plan`` step end to end: lower candidates on a real mesh, score them
with ``roofline_terms``, and write the hypothesis→score rows JSON.
"""
import json
import os

import jax


def test_hillclimb_import_is_env_safe():
    """Importing the module after jax is initialized neither changes the
    live device topology (the backend is already up; the module's
    ``XLA_FLAGS`` mutation only matters for its ``python -m`` CLI entry)
    nor is allowed to leak that mutation into the test process env."""
    before_flags = os.environ.get("XLA_FLAGS")
    n_before = jax.device_count()  # force backend init BEFORE the import
    try:
        import repro.launch.hillclimb as hc

        assert jax.device_count() == n_before
        # the CLI plans are structurally sound: every cell names a real
        # arch and shape, and every candidate is (hypothesis, overrides)
        from repro.configs import SHAPES_BY_NAME, get_config

        assert set(hc.PLANS) == {"glm4", "arctic", "qwen15"}
        for plan in hc.PLANS.values():
            get_config(plan["arch"])  # raises on unknown arch
            assert plan["shape"] in SHAPES_BY_NAME
            assert all(isinstance(label, str) and isinstance(ov, dict)
                       for label, ov in plan["candidates"])
    finally:
        if before_flags is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = before_flags


def test_hillclimb_tiny_cell_step(multidevice, tmp_path):
    """One ``run_plan`` step on a tiny hand-built plan: both candidates
    lower and score (no error rows), the log row carries the full
    hypothesis → before/after fields, and the JSON lands on disk."""
    out = tmp_path / "hillclimb"
    out.mkdir()
    stdout = multidevice(f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
jax.devices()  # initialize the 8-device backend before the import
import repro.launch.hillclimb as hc
from pathlib import Path
from repro.launch.mesh import make_mesh

assert jax.device_count() == 8  # the module's 512-device flag was too late
plan = {{"arch": "xlstm-350m", "shape": "decode_32k",
         "candidates": [("baseline", {{}}),
                        ("H1 remat=dots: less recompute", {{"remat": "dots"}})]}}
mesh = make_mesh((4, 2), ("data", "model"))
rows = hc.run_plan("tiny", plan, mesh, Path({str(out)!r}))
assert len(rows) == 2, rows
assert all("error" not in r for r in rows), rows
for r in rows:
    assert r["cell"] == "tiny"
    assert r["step_bound_s"] > 0 and r["dominant"] in (
        "compute_s", "memory_s", "collective_s"), r
print("OK", [r["label"][:12] for r in rows])
""", n_devices=8, timeout=600)
    assert "OK" in stdout
    rows = json.loads((out / "tiny.json").read_text())
    assert len(rows) == 2 and all("step_bound_s" in r for r in rows)
