"""Sharded-execution tests (each in a subprocess with fake devices, so the
main pytest process keeps a single device — see conftest.run_multidevice).

The subprocess env is scrubbed of inherited ``XLA_*``/``JAX_*`` knobs and
pinned to an explicit ``--xla_force_host_platform_device_count`` so these
tests are insensitive to the invoking shell's accelerator config."""


def test_sharded_train_step_matches_single_device(multidevice):
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import use_mesh, make_rules
from repro.train.train_step import (batch_specs, init_train_state,
                                    make_train_step, train_state_specs)
from repro.optim.adamw import AdamWCfg
from repro.optim.schedules import constant

cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32",
                                                   param_dtype="float32")
opt = AdamWCfg()
key = jax.random.PRNGKey(0)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(jax.random.PRNGKey(1), (8, 32), 0,
                                      cfg.vocab_size)}
step = make_train_step(cfg, opt, constant(1e-3))

# single device reference
state0 = init_train_state(key, cfg, opt)
ref_state, ref_metrics = jax.jit(step)(state0, batch)

# sharded
mesh = make_mesh((4, 2), ("data", "model"))
with use_mesh(mesh):
    state1 = init_train_state(key, cfg, opt)
    ss = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state1)
    specs = train_state_specs(ss)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t,
                                is_leaf=lambda x: isinstance(x, P))
    sh_state, sh_metrics = jax.jit(
        step, in_shardings=(ns(specs), ns(batch_specs(batch))),
        out_shardings=(ns(specs), None))(state1, batch)
assert abs(float(sh_metrics["loss"]) - float(ref_metrics["loss"])) < 1e-3, \
    (float(sh_metrics["loss"]), float(ref_metrics["loss"]))
for a, b in zip(jax.tree.leaves(ref_state["params"]),
                jax.tree.leaves(sh_state["params"])):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-4)
print("OK")
""")


def test_sp_flash_decode_matches_local(multidevice):
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import use_mesh, make_rules
from repro.serve.decode_attention import sp_flash_decode, _partial_terms

mesh = make_mesh((4, 2), ("data", "model"))
key = jax.random.PRNGKey(0)
B, T, kvH, G, hd = 4, 64, 2, 3, 16
q = jax.random.normal(key, (B, 1, kvH, G, hd), jnp.float32)
k = jax.random.normal(jax.random.PRNGKey(1), (B, T, kvH, hd), jnp.float32)
v = jax.random.normal(jax.random.PRNGKey(2), (B, T, kvH, hd), jnp.float32)
k_pos = jnp.arange(T)
pos = jnp.asarray(40)

# local reference (no mesh)
m, l, o = _partial_terms(q, k, v, k_pos, pos, None)
want = (o / jnp.maximum(l, 1e-30)[..., None])[:, None]

rules = make_rules(mesh, decode=True)
with use_mesh(mesh, rules):
    got = jax.jit(lambda *a: sp_flash_decode(*a))(q, k, v, k_pos, pos)
np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

# long-ctx rules: seq over both axes
rules = make_rules(mesh, long_ctx=True)
with use_mesh(mesh, rules):
    got2 = jax.jit(lambda *a: sp_flash_decode(*a))(q[:1], k[:1], v[:1], k_pos, pos)
np.testing.assert_allclose(np.asarray(got2), np.asarray(want[:1]), rtol=1e-5, atol=1e-5)
print("OK")
""")


def test_pipeline_parallel_matches_sequential(multidevice):
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply

mesh = make_mesh((8,), ("pipe",))
Ws = jax.random.normal(jax.random.PRNGKey(2), (8, 16, 16)) * 0.3
xs = jax.random.normal(jax.random.PRNGKey(3), (5, 2, 16))
def stage(w, x): return jnp.tanh(x @ w)
with mesh:
    out = pipeline_apply(stage, Ws, xs, mesh, "pipe")
ref = xs
for i in range(8):
    ref = jnp.tanh(ref @ Ws[i])
np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
g = jax.grad(lambda W: float('nan') if False else jnp.sum(
    pipeline_apply(stage, W, xs, mesh, "pipe") ** 2))(Ws)
assert bool(jnp.all(jnp.isfinite(g)))
print("OK")
""")


def test_compressed_ddp_converges(multidevice):
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_mesh
from repro.optim.compress import make_ddp_value_and_grad, ef_init_tree

mesh = make_mesh((4,), ("data",))
key = jax.random.PRNGKey(0)
X = jax.random.normal(key, (64, 16)); w_true = jax.random.normal(jax.random.PRNGKey(1), (16,))
y = X @ w_true
fn = make_ddp_value_and_grad(lambda w, b: jnp.mean((b[0] @ w - b[1]) ** 2), mesh)
w = jnp.zeros((16,)); ef = ef_init_tree(w, 4)
with mesh:
    step = jax.jit(lambda w, ef: fn(w, ef, (X, y)))
    for _ in range(250):
        l, g, ef = step(w, ef)
        w = w - 0.1 * g
assert float(l) < 1e-8, float(l)
print("OK")
""")


def test_elastic_reshard_roundtrip(multidevice):
    multidevice("""
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_mesh
from repro.parallel.sharding import use_mesh
from repro.optim.adamw import AdamWCfg
from repro.train.train_step import init_train_state
from repro.train.elastic import rescale_plan, reshard_state

assert rescale_plan(256) == (16, 16)
assert rescale_plan(192, prefer_model=16) == (12, 16)
assert rescale_plan(3) == (3, 1)

cfg = get_config("qwen2-1.5b", smoke=True)
state = init_train_state(jax.random.PRNGKey(0), cfg, AdamWCfg())
m1 = make_mesh((4, 2), ("data", "model"))
m2 = make_mesh((2, 2), ("data", "model"))
s1 = reshard_state(state, m1)
s2 = reshard_state(s1, m2)
for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(s2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("OK")
""")


def test_full_param_spec_coverage_all_archs(multidevice):
    """param_specs + decode_state_specs resolve for every FULL config
    (eval_shape only; proves sharding-rule coverage at production scale)."""
    multidevice("""
import jax
from repro.configs import ARCH_NAMES, get_config, skip_reason
from repro.launch.mesh import make_mesh
from repro.models import model as M
from repro.parallel.sharding import use_mesh, make_rules, param_specs
from repro.serve.serve_step import decode_state_specs

mesh = make_mesh((4, 2), ("data", "model"))
for arch in ARCH_NAMES:
    cfg = get_config(arch)
    with use_mesh(mesh):
        ps = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0), cfg))
        specs = param_specs(ps)
        assert len(jax.tree.leaves(ps)) == len(jax.tree.leaves(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec)))
    if skip_reason(arch, "decode_32k") is None and cfg.frontend != "vision":
        rules = make_rules(mesh, decode=True)
        with use_mesh(mesh, rules):
            ss = jax.eval_shape(
                lambda p: M.init_decode_state(p, cfg, 8, 256), ps)
            decode_state_specs(ss)
print("OK")
""", timeout=900)
