"""Tiered KV cache — engine-level tests for the host-RAM second tier.

The paper's cache-mode result, applied to serving: the device pool is the
fast tier, ``host_pages=`` adds a host-RAM tier that catches what pressure
evicts.  These tests drive a device pool sized BELOW the prefix working set
(three 3-page families through a 6-page pool) so warm replay without the
tier re-prefills from scratch, and assert the tiered contract end to end:

- demotion keeps evicted prefixes matchable; a warm replay hits the HOST
  tier and promotes instead of re-prefilling (``host_hits`` between the
  warm ``prefix_hits`` and the cold miss);
- transcripts stay token-identical to the untiered engine and to solo
  decode — for float32 AND int8 pools (scale rows ride through the
  demote-gather / promote-scatter round trip);
- the serve path still traces exactly ONE program (movers are control
  plane);
- cross-tier hygiene: refcounts drain to zero, the engine's host byte
  store tracks the pool's host residency exactly, and dropping the cache
  empties both tiers.

Pool-level tier policies in isolation: tests/test_pool.py.  Scheduler
tier-awareness: tests/test_serve_api.py."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine

KEY = jax.random.PRNGKey(0)
CACHE = 64


@pytest.fixture(scope="module")
def qwen():
    # float32 keeps greedy argmax stable across batching layouts
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


def _families(cfg, n=3, pages=3, page_size=8, seed=40):
    """n prompts of ``pages`` full pages each — a prefix working set of
    n * pages pages, to be pushed through a device pool smaller than that."""
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, pages * page_size)
            for _ in range(n)]


def _engine(params, cfg, **kw):
    # device pool (6 pages) below the working set (3 families x 3 pages +
    # a generated page each): every admission evicts someone else's prefix
    kw.setdefault("batch_size", 1)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 32)
    kw.setdefault("max_pages", 6)
    return ServeEngine(params, cfg, **kw)


def _wave(eng, prompts, max_tokens=4):
    uids = [eng.submit(p, max_tokens=max_tokens) for p in prompts]
    got = eng.run()
    return [got[u] for u in uids]


def _solo_decode(params, cfg, prompt, max_tokens, cache_len=CACHE):
    import jax.numpy as jnp

    state = M.init_decode_state(params, cfg, 1, cache_len)
    state = M.prefill(params, cfg, state, np.asarray(prompt, np.int32)[None])
    t = jnp.asarray([[int(prompt[-1])]], jnp.int32)
    out = []
    for _ in range(max_tokens):
        logits, state = M.decode_step(params, cfg, state, t)
        tok = int(jnp.argmax(logits[:, -1], -1)[0])
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def _assert_cross_tier_hygiene(eng):
    """Every page accounted for, engine host bytes == pool host residency,
    host slots partitioned free/resident."""
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
    assert set(eng._host_store) == set(eng.pool._host_node)
    assert sorted(eng.pool._host_free + list(eng.pool._host_node)) == list(
        range(eng.host_pages))


# ---------------------------------------------------------------------------
# The headline: warm replay hits the host tier instead of re-prefilling


def test_warm_replay_promotes_instead_of_reprefilling(qwen):
    cfg, params = qwen
    fams = _families(cfg)

    cold = _engine(params, cfg, host_pages=0)
    cold1, cold2 = _wave(cold, fams), _wave(cold, fams)
    # untiered, the 9-page working set churns straight through the 6-page
    # pool: the replay wave finds nothing cached
    assert cold.stats["host_hits"] == 0 and cold.stats["demotions"] == 0
    replay_hits = cold.stats["prefix_hits"]

    warm = _engine(params, cfg, host_pages=16)
    warm1, warm2 = _wave(warm, fams), _wave(warm, fams)
    # tiered, eviction DEMOTED those prefixes, so every replayed family is
    # a host hit promoted back — no prefix is ever re-prefilled
    assert warm.stats["demotions"] > 0
    assert warm.stats["host_hits"] == len(fams)
    assert warm.stats["host_pages_promoted"] >= len(fams)
    assert warm.stats["prefix_hits"] > replay_hits
    assert warm.stats["evictions"] == 0  # the tier caught every eviction

    # transcripts are token-identical: tiering moves bytes, never changes
    # them — and the serve path is still exactly one compiled program
    assert warm1 == cold1 and warm2 == cold2 and warm1 == warm2
    for out, p in zip(warm1, fams):
        assert out == _solo_decode(params, cfg, p, 4)
    assert warm.stats["traces"] == 1
    _assert_cross_tier_hygiene(warm)


def test_int8_scales_survive_promotion_roundtrip(qwen):
    """int8 pools store per-entry scale rows next to the quantized values;
    the demote gather and promote scatter must carry BOTH, or a promoted
    page dequantizes garbage.  Identical cold/warm transcripts through an
    int8 tiered engine prove the full round trip."""
    cfg, params = qwen
    fams = _families(cfg, seed=41)
    cold = _engine(params, cfg, host_pages=0, kv_dtype="int8")
    warm = _engine(params, cfg, host_pages=16, kv_dtype="int8")
    cold1, cold2 = _wave(cold, fams), _wave(cold, fams)
    warm1, warm2 = _wave(warm, fams), _wave(warm, fams)
    assert warm.stats["host_hits"] == len(fams)
    assert warm1 == cold1 and warm2 == cold2 and warm1 == warm2
    _assert_cross_tier_hygiene(warm)


def test_host_tier_capacity_bounds_residency(qwen):
    """A host tier smaller than the spill set hevicts LRU: residency never
    exceeds host_pages and the engine's byte store shrinks in lockstep."""
    cfg, params = qwen
    eng = _engine(params, cfg, host_pages=2)
    fams = _families(cfg, seed=42)
    _wave(eng, fams)
    assert eng.stats["host_evictions"] > 0
    assert eng.pool.host_cached_pages <= 2
    assert len(eng._host_store) <= 2
    _assert_cross_tier_hygiene(eng)


# ---------------------------------------------------------------------------
# Hygiene: the 3-wave regression, extended across tiers


def test_tiered_pool_returns_to_initial_after_three_waves(qwen):
    """The PR 3 pool-hygiene regression through a TIERED engine: three
    admit/retire waves under demotion pressure, cross-tier invariants after
    every wave, and a final drop that empties both tiers and the engine's
    host byte store."""
    cfg, params = qwen
    eng = _engine(params, cfg, host_pages=8)
    assert len(eng._free) == eng.n_pages
    for wave in range(3):
        prompts = _families(cfg, seed=43 + wave)
        outs = _wave(eng, prompts, max_tokens=3)
        assert all(len(o) == 3 for o in outs)
        assert not any(eng.slots)
        _assert_cross_tier_hygiene(eng)
    assert eng.stats["demotions"] > 0  # the waves actually exercised tiers
    # wave 4: a cancellation mid-flight must not perturb tier bookkeeping
    prompts = _families(cfg, seed=46)
    handles = [eng.submit(p, max_tokens=4) for p in prompts]
    eng.tick()
    assert handles[1].cancel()
    eng.run()
    _assert_cross_tier_hygiene(eng)
    # dropping the cache clears BOTH tiers and the host byte store
    eng.drop_prefix_cache()
    assert len(eng._free) == eng.n_pages and eng.cached_pages == 0
    assert eng.pool.host_cached_pages == 0 and not eng._host_store
    assert eng.pool.host_free_slots == eng.host_pages
