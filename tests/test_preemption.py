"""Preemptible serving under pressure: PagePool park/unpark/drop_parked in
isolation, slot preemption with KV swap-to-host (park-hit resume) and
without a host tier (re-prefill resume) — token-identical both ways, zero
leaked pages on both tiers, one serve-path trace — plus the pressure-facing
API surface: typed ``RequestTooLarge`` / ``EngineOverloaded`` on submit,
``deadline_ticks`` expiry (queued and live) raising ``DeadlineExceeded``
with partial output attached, ``result(timeout_ticks=)`` bounding the
drain, and the ``preempt_order`` policy hook (default order, SLO
interactive exemption).  Fault-injection chaos runs live in
tests/test_chaos.py."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.errors import (Cancelled, DeadlineExceeded,
                                EngineOverloaded, RequestTooLarge,
                                ServeError)
from repro.serve.handle import Request
from repro.serve.pool import PagePool
from repro.serve.scheduler import (ClassThenFamilyScheduler, EngineView,
                                   Scheduler, SloScheduler)

KEY = jax.random.PRNGKey(0)
CACHE = 64


@pytest.fixture(scope="module")
def qwen():
    # float32 keeps greedy argmax stable across batching layouts
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, L) for L in lens]


def _engine(params, cfg, **kw):
    kw.setdefault("batch_size", 1)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 32)
    return ServeEngine(params, cfg, **kw)


def _leak_free(eng):
    pool = eng.pool
    return bool((eng._ref == 0).all()
                and eng.reclaimable_pages == eng.n_pages
                and pool.parked_pages == 0
                and len(pool._host_free) + pool.host_cached_pages
                == pool.host_pages
                and set(eng._host_store) == set(pool._host_node))


# ---------------------------------------------------------------------------
# PagePool park / unpark / drop_parked (no engine, pure host bookkeeping)


def test_pool_park_moves_private_pages_to_host():
    pool = PagePool(4, 4, host_pages=4)
    pages = pool.alloc(3)
    slots = pool.park(pages)
    assert slots is not None and len(slots) == 3
    assert pool.parked_pages == 3
    assert pool.free_pages == 4  # parked pages left the device pool
    assert all(pool.ref(p) == 0 for p in pages)
    evs = pool.drain_events()
    assert [e[0] for e in evs] == ["demote"] * 3
    assert [e[1] for e in evs] == list(pages)
    assert pool.stats["park_demotions"] == 3


def test_pool_park_all_or_nothing_when_tier_small_or_absent():
    pool = PagePool(4, 4, host_pages=2)
    pages = pool.alloc(3)
    assert pool.park(pages) is None  # 3 pages, 2 host slots: refuse whole
    assert pool.parked_pages == 0
    assert pool.free_pages == 1  # pages still owned by the caller
    assert pool.drain_events() == []
    untiered = PagePool(4, 4, host_pages=0)
    assert untiered.park(untiered.alloc(1)) is None


def test_pool_unpark_allocates_and_promotes():
    pool = PagePool(4, 4, host_pages=4)
    slots = pool.park(pool.alloc(2))
    pool.drain_events()
    devs = pool.unpark(slots)
    assert len(devs) == 2
    assert pool.parked_pages == 0
    assert all(pool.ref(p) == 1 for p in devs)
    evs = pool.drain_events()
    assert [(e[0], e[1]) for e in evs] == [("promote", s) for s in slots]
    assert pool.stats["park_promotions"] == 2
    assert sorted(pool._host_free) == list(range(4))


def test_pool_drop_parked_frees_slots_with_hevict():
    pool = PagePool(4, 4, host_pages=4)
    slots = pool.park(pool.alloc(2))
    pool.drain_events()
    pool.drop_parked(slots)
    assert pool.parked_pages == 0
    assert sorted(pool._host_free) == list(range(4))
    assert [e[0] for e in pool.drain_events()] == ["hevict"] * 2
    assert pool.stats["parks_dropped"] == 2


def test_pool_storm_spares_parked_slots():
    pool = PagePool(8, 2, host_pages=8)
    slots = pool.park(pool.alloc(2))
    # a cached (trie-indexed, refcount-0) host page: park a prefix through
    # the normal demote path by filling and releasing an indexed chain
    node, pages, matched, cow = pool.match_prefix(np.arange(4))
    (pg,) = pool.alloc(1)
    node = pool.index_page(node, tuple(range(2)), pg)
    pool.release([pg])
    assert pool.evict_one()  # demotes the cached page to the host tier
    assert pool.host_cached_pages == 1
    n = pool.storm_host_cache()
    assert n == 1  # the cache entry died ...
    assert pool.host_cached_pages == 0
    assert pool.parked_pages == 2  # ... the parked live state survived
    assert sorted(pool._parked) == sorted(slots)


# ---------------------------------------------------------------------------
# Preempt / resume through the engine: token identity on both resume paths


def _overload_run(params, cfg, *, host_pages, preempt=True, scheduler="slo"):
    """One hog fills the only slot and the whole pool; an interactive chat
    arrives mid-decode.  Returns (engine, hog transcript, chat transcript)."""
    hog, chat = _prompts(cfg, [16, 6])
    eng = _engine(params, cfg, max_pages=4, host_pages=host_pages,
                  scheduler=scheduler, preempt=preempt)
    h_hog = eng.submit(hog, max_tokens=16)
    for _ in range(4):  # prefill + a few decode ticks
        eng.tick()
    assert len(h_hog.request.out_tokens) >= 1
    h_chat = eng.submit(chat, max_tokens=3, priority=1)
    res = eng.run()
    return eng, res[h_hog], res[h_chat]


def _solo_transcripts(params, cfg):
    hog, chat = _prompts(cfg, [16, 6])
    eng = _engine(params, cfg, batch_size=2, max_pages=16)
    uids = [eng.submit(hog, max_tokens=16),
            eng.submit(chat, max_tokens=3, priority=1)]
    res = eng.run()
    return res[uids[0]], res[uids[1]]


def test_preempt_resume_park_hit_token_identical(qwen):
    cfg, params = qwen
    want_hog, want_chat = _solo_transcripts(params, cfg)
    eng, got_hog, got_chat = _overload_run(params, cfg, host_pages=6)
    assert eng.stats["preemptions"] == 1
    assert eng.stats["resumes"] == 1
    assert eng.stats["resume_park_hits"] == 1
    assert eng.stats["resume_reprefills"] == 0
    assert eng.stats["preempt_pages_parked"] >= 1
    assert (got_hog, got_chat) == (want_hog, want_chat)
    assert eng.stats["traces"] == 1
    assert _leak_free(eng)


def test_preempt_resume_reprefill_token_identical(qwen):
    cfg, params = qwen
    want_hog, want_chat = _solo_transcripts(params, cfg)
    # no host tier: the victim's generated KV cannot park; resume replays
    # prompt + generated history through prefill instead
    eng, got_hog, got_chat = _overload_run(params, cfg, host_pages=0)
    assert eng.stats["preemptions"] == 1
    assert eng.stats["resumes"] == 1
    assert eng.stats["resume_reprefills"] == 1
    assert eng.stats["resume_park_hits"] == 0
    assert (got_hog, got_chat) == (want_hog, want_chat)
    assert eng.stats["traces"] == 1
    assert _leak_free(eng)


def test_preempt_off_stalls_instead(qwen):
    cfg, params = qwen
    eng, got_hog, got_chat = _overload_run(params, cfg, host_pages=6,
                                           preempt=False)
    assert eng.stats["preemptions"] == 0
    want_hog, want_chat = _solo_transcripts(params, cfg)
    assert (got_hog, got_chat) == (want_hog, want_chat)  # just later
    assert _leak_free(eng)


def test_equal_priority_never_preempts(qwen):
    cfg, params = qwen
    # strict-priority guard: a same-class backlog waits, it never thrashes
    eng = _engine(params, cfg, max_pages=4, host_pages=6)
    for p in _prompts(cfg, [16, 16, 16]):
        eng.submit(p, max_tokens=8)
    eng.run()
    assert eng.stats["preemptions"] == 0
    assert _leak_free(eng)


# ---------------------------------------------------------------------------
# Typed submit errors and deadlines


def test_submit_too_large_raises_typed(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, max_pages=4)
    (p,) = _prompts(cfg, [CACHE])
    with pytest.raises(RequestTooLarge):
        eng.submit(p, max_tokens=8)  # prompt + output exceeds cache_len
    with pytest.raises(RequestTooLarge):
        # fits the cache but its footprint exceeds the whole page pool
        eng.submit(p[:40], max_tokens=8)
    assert issubclass(RequestTooLarge, ValueError)  # legacy except clauses
    assert issubclass(RequestTooLarge, ServeError)
    assert eng.stats["overload_rejections"] == 0
    (ok,) = _prompts(cfg, [8], seed=1)
    assert eng.submit(ok, max_tokens=4).result()  # engine still serves


def test_submit_overload_raises_typed(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, max_queue=2)
    prompts = _prompts(cfg, [8, 8, 8])
    handles = [eng.submit(p, max_tokens=2) for p in prompts[:2]]
    with pytest.raises(EngineOverloaded):
        eng.submit(prompts[2], max_tokens=2)
    assert issubclass(EngineOverloaded, RuntimeError)
    assert eng.stats["overload_rejections"] == 1
    res = eng.run()
    assert all(len(res[h]) == 2 for h in handles)
    eng.submit(prompts[2], max_tokens=2).result()  # room again after drain


def test_deadline_expires_live_request_with_partial_tokens(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg)
    (p,) = _prompts(cfg, [8])
    h = eng.submit(p, max_tokens=32, deadline_ticks=6)
    res = eng.run()
    assert eng.stats["deadline_expired"] == 1
    with pytest.raises(DeadlineExceeded) as exc:
        h.tokens_list = h.result()
    assert 1 <= len(exc.value.tokens) < 32  # partial output attached
    assert list(exc.value.tokens) == res.get(int(h), exc.value.tokens)
    assert issubclass(DeadlineExceeded, TimeoutError)
    assert _leak_free(eng)


def test_deadline_expires_starved_queued_request(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg, max_pages=4, preempt=False)
    hog, chat = _prompts(cfg, [16, 6])
    eng.submit(hog, max_tokens=16)
    starved = eng.submit(chat, max_tokens=2, deadline_ticks=4)
    eng.run()
    with pytest.raises(DeadlineExceeded) as exc:
        starved.result()
    assert exc.value.tokens == []  # never admitted, nothing served
    assert _leak_free(eng)


def test_result_timeout_ticks_bounds_the_drain(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg)
    (p,) = _prompts(cfg, [8])
    h = eng.submit(p, max_tokens=32)
    with pytest.raises(TimeoutError) as exc:
        h.result(timeout_ticks=2)
    assert not isinstance(exc.value, ServeError)  # a drain bound, not abort
    assert h.result() == list(h.request.out_tokens)  # finishes when drained


def test_engine_cancel_error_is_typed_cancelled(qwen):
    cfg, params = qwen
    eng = _engine(params, cfg)
    (p,) = _prompts(cfg, [8])
    h = eng.submit(p, max_tokens=32)
    for _ in range(3):
        eng.tick()
    eng.cancel(h, error=Cancelled("admin abort", tokens=None))
    with pytest.raises(Cancelled) as exc:
        h.result()
    assert len(exc.value.tokens) >= 1  # partial output rides the exception
    # CLIENT cancel keeps the historical contract: partial result, no raise
    h2 = eng.submit(p, max_tokens=32)
    for _ in range(3):
        eng.tick()
    h2.cancel()
    assert isinstance(h2.result(), list)
    assert _leak_free(eng)


# ---------------------------------------------------------------------------
# preempt_order policy hook (hand-built views, no engine)


def _pview(reqs):
    return EngineView(queue=(), slot_requests=tuple(reqs),
                      slot_fill=tuple(0 for _ in reqs), budget=32,
                      chunk=16, page_size=8, match_len=lambda p: 0)


def _reqs(specs):
    return [Request(uid=u, prompt=np.arange(4), priority=pr)
            for u, pr in specs]


def test_default_preempt_order_low_priority_young_first():
    view = _pview(_reqs([(0, 1), (1, 0), (2, 0), (3, 2)]))
    assert list(Scheduler().preempt_order(view, [0, 1, 2, 3])) == [2, 1, 0, 3]


def test_slo_preempt_order_exempts_interactive():
    view = _pview(_reqs([(0, 1), (1, 0), (2, 0), (3, 2)]))
    for sched in (SloScheduler(), ClassThenFamilyScheduler()):
        order = list(sched.preempt_order(view, [0, 1, 2, 3]))
        assert order == [2, 1]  # batch only, youngest first


# ---------------------------------------------------------------------------
# Roofline: preemption swap bytes priced like promotion bytes


def test_mixed_bound_prices_swap_like_promotion():
    from repro.configs import get_config
    from repro.core.roofline import mixed_bound

    cfg = get_config("qwen2-1.5b")
    kw = dict(n_decode=8, n_prefill=64, context_len=1024, page_size=16)
    base = mixed_bound(cfg, **kw)
    assert base["swap_s"] == 0.0 and base["swapped_bytes"] == 0.0
    promo = mixed_bound(cfg, promoted_pages=4, **kw)
    swap = mixed_bound(cfg, swapped_pages=4, **kw)
    # identical per-page bytes, identical H2D link: same third roof
    assert swap["swapped_bytes"] == promo["promoted_bytes"] > 0
    assert swap["promotion_s"] == pytest.approx(promo["promotion_s"])
    assert swap["tick_s"] == promo["tick_s"]
    both = mixed_bound(cfg, promoted_pages=4, swapped_pages=4, **kw)
    assert both["promotion_s"] == pytest.approx(2 * promo["promotion_s"])
