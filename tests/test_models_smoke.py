"""Per-architecture smoke tests (deliverable f): a REDUCED config of each
assigned arch runs one forward + one train step on CPU, asserting output
shapes and no NaNs; decoder archs additionally run prefill + decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, skip_reason
from repro.models import model as M
from repro.optim.adamw import AdamWCfg
from repro.optim.schedules import constant
from repro.train.train_step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32

# The last seed-era xfail group is gone: transformer._barrier gives
# optimization_barrier a custom JVP, so the remat-barrier archs' train
# steps differentiate and every arch gates strictly.
_TRAIN_ARCHS = list(ARCH_NAMES)


def _batch(cfg, key=KEY):
    ks = jax.random.split(key, 4)
    batch = {}
    if cfg.frontend == "audio":
        batch["feats"] = jax.random.normal(ks[0], (B, S, cfg.d_model // 2),
                                           jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)
    if cfg.frontend == "vision":
        batch["img_feats"] = jax.random.normal(
            ks[1], (B, cfg.n_img_tokens, cfg.d_model // 2), jnp.bfloat16)
    batch["labels"] = jax.random.randint(ks[2], (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_forward_shapes_and_finite(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(KEY, cfg)
    logits, aux = M.forward(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    assert np.isfinite(float(aux["moe_lb_loss"]))


@pytest.mark.parametrize("arch", _TRAIN_ARCHS)
def test_train_step_no_nans(arch):
    cfg = get_config(arch, smoke=True)
    opt = AdamWCfg()
    state = init_train_state(KEY, cfg, opt)
    step = make_train_step(cfg, opt, constant(1e-3))
    state, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    for leaf in jax.tree.leaves(state["params"]):
        assert not bool(jnp.isnan(leaf).any())


@pytest.mark.parametrize("arch", [a for a in ARCH_NAMES
                                  if skip_reason(a, "decode_32k") is None])
def test_decode_smoke(arch):
    cfg = get_config(arch, smoke=True)
    params = M.init_params(KEY, cfg)
    enc = None
    if cfg.frontend == "vision":
        enc = jax.random.normal(KEY, (B, cfg.n_img_tokens, cfg.d_model // 2),
                                jnp.bfloat16)
    state = M.init_decode_state(params, cfg, B, 64, enc_feats=enc)
    toks = jax.random.randint(KEY, (B, 8), 0, cfg.vocab_size)
    state = M.prefill(params, cfg, state, toks, enc_feats=enc)
    t = toks[:, -1:]
    for _ in range(3):
        logits, state = M.decode_step(params, cfg, state, t)
        t = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())


@pytest.mark.parametrize("arch", _TRAIN_ARCHS)
def test_microbatched_step_matches_structure(arch):
    """Grad accumulation path traces and yields finite loss (mb=2)."""
    cfg = get_config(arch, smoke=True)
    opt = AdamWCfg()
    state = init_train_state(KEY, cfg, opt)
    step = make_train_step(cfg, opt, constant(1e-3), microbatches=2)
    state, metrics = jax.jit(step)(state, _batch(cfg))
    assert np.isfinite(float(metrics["loss"]))


def test_param_counts_match_analytic():
    """Analytic param_count (roofline MODEL_FLOPS source) matches real trees
    on smoke configs."""
    from repro.configs import param_count

    for arch in ARCH_NAMES:
        cfg = get_config(arch, smoke=True)
        params = M.init_params(KEY, cfg)
        real = sum(x.size for x in jax.tree.leaves(params))
        pred = param_count(cfg)
        assert abs(real - pred) / real < 0.25, (arch, real, pred)
