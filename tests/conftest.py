import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a snippet in a subprocess with N fake devices.

    Multi-device tests must not set xla_force_host_platform_device_count in
    this process (smoke tests and benches must see 1 device), so each
    sharded test runs in its own interpreter.

    The child env is pinned, not inherited: every ``XLA_*`` / ``JAX_*`` /
    ``LIBTPU*`` / ``TPU_*`` variable from the invoking shell is scrubbed
    before setting an explicit ``XLA_FLAGS``.  An inherited
    ``XLA_FLAGS`` would silently *replace* our device-count flag (the
    assignment below clobbers it) or, worse, an inherited
    ``JAX_PLATFORMS``/``JAX_NUM_CPU_DEVICES`` would change the child's
    device topology and make these tests CPU-environment sensitive —
    exactly the seed-era flakiness this scrub retires.
    """
    env = {k: v for k, v in os.environ.items()
           if not k.startswith(("XLA_", "JAX_", "LIBTPU", "TPU_"))}
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
