import gc
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")


@pytest.fixture(autouse=True)
def _reclaim_jit_mappings():
    """Collect dropped engines (and their compiled XLA executables) before
    the process runs out of memory mappings.

    Each compiled CPU executable holds ~100 ``mmap`` regions for its JIT
    code, and a dead ``ServeEngine`` sits in a reference cycle until the
    cyclic GC runs — so a full-suite process accumulates mappings
    monotonically and eventually trips ``vm.max_map_count`` (65530
    default), which XLA's code allocator answers with a hard segfault
    mid-compile.  Collecting whenever the map count crosses a threshold
    well below the ceiling keeps the live set bounded at negligible cost
    (the count check is one /proc read per test).  Executables still
    reachable through jax's global jit caches survive a plain collect —
    if one doesn't bring the count back under a high-water mark, drop
    those caches too (rare, costs only recompiles)."""
    yield

    def n_maps():
        try:
            with open(f"/proc/{os.getpid()}/maps") as f:
                return sum(1 for _ in f)
        except OSError:  # no procfs: treat as always over threshold
            return None

    n = n_maps()
    if n is None or n > 30_000:
        gc.collect()
        n = n_maps()
        if n is not None and n > 45_000:
            import jax

            jax.clear_caches()
            gc.collect()


def run_multidevice(code: str, n_devices: int = 8, timeout: int = 600) -> str:
    """Run a snippet in a subprocess with N fake devices.

    Multi-device tests must not set xla_force_host_platform_device_count in
    this process (smoke tests and benches must see 1 device), so each
    sharded test runs in its own interpreter.

    The child env is pinned, not inherited: every ``XLA_*`` / ``JAX_*`` /
    ``LIBTPU*`` / ``TPU_*`` variable from the invoking shell is scrubbed
    before setting an explicit ``XLA_FLAGS``.  An inherited
    ``XLA_FLAGS`` would silently *replace* our device-count flag (the
    assignment below clobbers it) or, worse, an inherited
    ``JAX_PLATFORMS``/``JAX_NUM_CPU_DEVICES`` would change the child's
    device topology and make these tests CPU-environment sensitive —
    exactly the seed-era flakiness this scrub retires.
    """
    env = {k: v for k, v in os.environ.items()
           if not k.startswith(("XLA_", "JAX_", "LIBTPU", "TPU_"))}
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"multidevice subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture(scope="session")
def multidevice():
    return run_multidevice
