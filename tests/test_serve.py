"""Serving-engine tests: ragged single-program token identity (vs the seed
reference engine and solo decode), exactly-one-compiled-program assertions,
decode-never-stalls-during-prefill, seeded sampling, FIFO ordering and slot
reuse under churn, EOS / max-token termination, page-pool hygiene and
overcommit, the refcounted prefix cache (warm-prefix identity, mid-page COW
divergence, LRU eviction, refcount no-leak), and the Pallas ragged
paged-decode path (including aliased shared pages)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.reference import ReferenceEngine

KEY = jax.random.PRNGKey(0)
CACHE = 64


def _setup(arch):
    # float32 keeps greedy argmax stable across batching layouts
    cfg = get_config(arch, smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def qwen():
    return _setup("qwen2-1.5b")


@pytest.fixture(scope="module")
def gemma():
    return _setup("gemma3-4b")  # 5:1 local(window=16):global mix


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, L) for L in lens]


def _solo_decode(params, cfg, prompt, max_tokens, cache_len=CACHE):
    """Batch-1 ground truth replicating the engines' decode scheme (full
    prompt prefill, then decode restarts from the last prompt token)."""
    state = M.init_decode_state(params, cfg, 1, cache_len)
    state = M.prefill(params, cfg, state, np.asarray(prompt, np.int32)[None])
    t = jnp.asarray([[int(prompt[-1])]], jnp.int32)
    out = []
    for _ in range(max_tokens):
        logits, state = M.decode_step(params, cfg, state, t)
        tok = int(jnp.argmax(logits[:, -1], -1)[0])
        out.append(tok)
        t = jnp.asarray([[tok]], jnp.int32)
    return out


def _serve(cfg, params, prompts, max_tokens=4, eos_id=None, **kw):
    kw.setdefault("batch_size", 3)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    eng = ServeEngine(params, cfg, **kw)
    uids = [eng.submit(p, max_tokens=max_tokens, eos_id=eos_id)
            for p in prompts]
    return eng, uids, eng.run()


# ---------------------------------------------------------------------------
# Token identity


def test_equal_length_wave_matches_reference_engine(qwen):
    """Greedy output bit-matches the seed engine on a single equal-length
    wave — the only traffic the lock-step seed engine serves correctly
    (on slot reuse its shared ``pos`` keeps the previous wave's maximum, so
    later waves decode at wrong positions; the paged engine instead matches
    the solo ground truth — see the churn tests)."""
    cfg, params = qwen
    prompts = _prompts(cfg, [12] * 3)
    ref = ReferenceEngine(params, cfg, batch_size=3, cache_len=CACHE)
    ref_uids = [ref.submit(p, max_tokens=5) for p in prompts]
    want = ref.run()
    _, uids, got = _serve(cfg, params, prompts, max_tokens=5)
    for ru, u in zip(ref_uids, uids):
        assert got[u] == want[ru]


def test_mixed_lengths_match_solo_runs(qwen):
    cfg, params = qwen
    lens = [5, 19, 11, 26]
    prompts = _prompts(cfg, lens, seed=1)
    _, uids, got = _serve(cfg, params, prompts, batch_size=2)
    for u, p in zip(uids, prompts):
        assert got[u] == _solo_decode(params, cfg, p, 4)


def test_windowed_layers_mixed_lengths(gemma):
    """Prompts longer than the sliding window exercise the per-slot
    circular buffers (chunk > window wraps within one scatter)."""
    cfg, params = gemma
    prompts = _prompts(cfg, [33, 7, 21], seed=2)
    _, uids, got = _serve(cfg, params, prompts, batch_size=2,
                          prefill_chunk=24)
    for u, p in zip(uids, prompts):
        assert got[u] == _solo_decode(params, cfg, p, 4)


def test_paged_matches_contiguous_cache(qwen):
    """page_size == cache_len is a contiguous cache (one page per slot);
    fine paging must produce identical tokens."""
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11, 26], seed=3)
    _, u1, r1 = _serve(cfg, params, prompts, page_size=CACHE)
    _, u2, r2 = _serve(cfg, params, prompts, page_size=4)
    assert [r1[u] for u in u1] == [r2[u] for u in u2]


def test_chunked_prefill_matches_unchunked(qwen):
    """Splitting prompts into small chunks interleaved across ticks must
    not change the cache contents (greedy-token identity)."""
    cfg, params = qwen
    prompts = _prompts(cfg, [26, 9, 17], seed=4)
    _, u1, r1 = _serve(cfg, params, prompts, prefill_chunk=CACHE)
    _, u2, r2 = _serve(cfg, params, prompts, prefill_chunk=4)
    assert [r1[u] for u in u1] == [r2[u] for u in u2]


def test_flash_paged_decode_matches_jnp_path(qwen):
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11], seed=5)
    _, u1, r1 = _serve(cfg, params, prompts, batch_size=2)
    _, u2, r2 = _serve(cfg, params, prompts, batch_size=2, flash_decode=True)
    assert [r1[u] for u in u1] == [r2[u] for u in u2]


def test_recurrent_hybrid_serves_correctly():
    """Masked recurrent rolls: per-slot states must not advance on pad
    tails or idle ticks (xlstm has no attention cache at all)."""
    cfg, params = _setup("xlstm-350m")
    prompts = _prompts(cfg, [5, 14, 9], seed=6)
    _, uids, got = _serve(cfg, params, prompts, batch_size=2)
    for u, p in zip(uids, prompts):
        assert got[u] == _solo_decode(params, cfg, p, 4)


# ---------------------------------------------------------------------------
# Ragged single program


def _reference_solo(params, cfg, prompt, max_tokens):
    """Ground truth via the seed ReferenceEngine at batch 1 — the only
    traffic shape it serves correctly for arbitrary lengths (its positions
    are lock-step across slots)."""
    ref = ReferenceEngine(params, cfg, batch_size=1, cache_len=CACHE)
    uid = ref.submit(prompt, max_tokens=max_tokens)
    return ref.run()[uid]


def test_ragged_mixed_concurrent_matches_reference(qwen):
    """Token identity on mixed-length concurrent traffic: every request out
    of the ragged pack matches the seed reference engine run solo."""
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11, 26, 8], seed=21)
    eng, uids, got = _serve(cfg, params, prompts, batch_size=2,
                            token_budget=24)
    for u, p in zip(uids, prompts):
        assert got[u] == _reference_solo(params, cfg, p, 4)
    assert eng.stats["traces"] == 1


def test_exactly_one_program_for_any_traffic_mix(qwen):
    """The tentpole claim: one compiled program serves pure prefill, pure
    decode, and every blend — asserted by the trace counter AND the jit
    cache across two full runs with different traffic."""
    cfg, params = qwen
    eng = ServeEngine(params, cfg, batch_size=3, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    uids = [eng.submit(p, max_tokens=5)
            for p in _prompts(cfg, [26, 4, 17, 9, 12], seed=22)]
    got = eng.run()
    assert sorted(got) == sorted(uids)
    # second run, different mix, same engine: still the one program
    u2 = [eng.submit(p, max_tokens=2) for p in _prompts(cfg, [7, 7], seed=23)]
    got2 = eng.run()
    assert sorted(got2) == sorted(u2)
    assert eng.stats["traces"] == 1
    cache_size = getattr(eng._ragged_step, "_cache_size", lambda: 1)()
    assert cache_size == 1


def test_ragged_matches_chunked_two_phase(qwen):
    """A/B: the ragged engine and the PR 1 two-phase engine (ragged=False)
    emit identical greedy tokens on identical traffic."""
    cfg, params = qwen
    prompts = _prompts(cfg, [26, 9, 17, 5], seed=24)
    _, u1, r1 = _serve(cfg, params, prompts, ragged=True)
    _, u2, r2 = _serve(cfg, params, prompts, ragged=False)
    assert [r1[u] for u in u1] == [r2[u] for u in u2]


@settings(max_examples=5, deadline=None)
@given(budget=st.sampled_from([8, 24, 64]),
       chunk=st.sampled_from([4, 8, 16]),
       page=st.sampled_from([4, 8, 64]))
def test_ragged_property_over_budget_chunk_page(qwen, budget, chunk, page):
    """Property: token identity and single-program compilation hold over
    random (token_budget, prefill_chunk, page_size) combos."""
    cfg, params = qwen
    prompts = _prompts(cfg, [5, 19, 11, 26], seed=1)
    eng, uids, got = _serve(cfg, params, prompts, batch_size=2,
                            prefill_chunk=chunk, page_size=page,
                            token_budget=budget)
    for u, p in zip(uids, prompts):
        assert got[u] == _solo_decode(params, cfg, p, 4)
    assert eng.stats["traces"] == 1


def test_decode_never_stalls_during_prefill(qwen):
    """The head-of-line fix: while a long document prefills, a decoding
    chat slot emits a token EVERY tick in the ragged engine; the two-phase
    engine stalls it for the whole prefill burst."""
    cfg, params = qwen
    [chat] = _prompts(cfg, [4], seed=30)
    [filler] = _prompts(cfg, [4], seed=31)
    [doc] = _prompts(cfg, [56], seed=32)

    def run(ragged):
        eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                          page_size=8, prefill_chunk=8, token_budget=16,
                          ragged=ragged)
        u_chat = eng.submit(chat, max_tokens=12)
        eng.submit(filler, max_tokens=1)  # frees its slot for the doc
        eng.submit(doc, max_tokens=2)  # admitted mid-chat-decode
        eng.run()
        ticks = [t for uid, t, _ in eng.token_log if uid == u_chat]
        return eng, ticks

    eng, ticks = run(True)
    assert max(np.diff(ticks)) == 1  # consecutive ticks, no stall
    # ...and the doc really was prefilling during several of those ticks
    assert sum(eng.tick_log[t][0] for t in ticks) >= 3
    _, ticks_chunked = run(False)
    assert max(np.diff(ticks_chunked)) > 1  # the two-phase engine stalls


# ---------------------------------------------------------------------------
# Sampling


def test_seeded_sampling_deterministic_and_packing_invariant(qwen):
    """Seeded temperature/top-k sampling repeats exactly and is invariant
    to how ticks were packed (one RNG draw per emitted token)."""
    cfg, params = qwen
    prompts = _prompts(cfg, [8, 14], seed=40)

    def run(chunk, budget, temperature):
        eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                          page_size=8, prefill_chunk=chunk,
                          token_budget=budget)
        uids = [eng.submit(p, max_tokens=6, temperature=temperature,
                           top_k=50, seed=123 + i)
                for i, p in enumerate(prompts)]
        got = eng.run()
        return [got[u] for u in uids]

    a = run(16, 24, 8.0)
    assert a == run(16, 24, 8.0)  # same seeds -> same tokens
    assert a == run(8, 40, 8.0)  # packing-invariant
    assert a != run(16, 24, 0.0)  # actually samples (high temperature)


def test_top_k_one_is_greedy(qwen):
    cfg, params = qwen
    [prompt] = _prompts(cfg, [10], seed=41)
    eng = ServeEngine(params, cfg, batch_size=1, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=8)
    u1 = eng.submit(prompt, max_tokens=4, temperature=5.0, top_k=1, seed=0)
    u2 = eng.submit(prompt, max_tokens=4)  # greedy default
    got = eng.run()
    assert got[u1] == got[u2]


def test_sampling_validation(qwen):
    cfg, params = qwen
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=32, page_size=8)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], temperature=-0.5)
    with pytest.raises(ValueError):
        eng.submit([1, 2, 3], temperature=1.0, top_k=0)
    with pytest.raises(ValueError):
        ServeEngine(params, cfg, batch_size=4, token_budget=2)


# ---------------------------------------------------------------------------
# Scheduling / lifecycle


def test_fifo_ordering_and_slot_reuse_under_churn(qwen):
    """9 equal requests through 3 slots: three full waves, FIFO admission,
    every slot reused, pages recycled."""
    cfg, params = qwen
    prompts = _prompts(cfg, [8] * 9)
    eng, uids, got = _serve(cfg, params, prompts, max_tokens=3)
    assert sorted(got) == sorted(uids) and all(len(v) == 3 for v in got.values())
    waves = [set(eng.completion_order[i:i + 3]) for i in (0, 3, 6)]
    assert waves == [set(uids[0:3]), set(uids[3:6]), set(uids[6:9])]
    # completed prompt pages may stay resident as prefix cache (refcount 0,
    # evictable); every page must be reclaimable and unpinned
    assert not any(eng.slots) and (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


def test_eos_termination(qwen):
    cfg, params = qwen
    [prompt] = _prompts(cfg, [10], seed=7)
    _, [u], free_run = _serve(cfg, params, [prompt], max_tokens=6)
    first = free_run[u][0]
    _, [u2], stopped = _serve(cfg, params, [prompt], max_tokens=6,
                              eos_id=first)
    assert stopped[u2] == [first]


def test_max_tokens_termination(qwen):
    cfg, params = qwen
    prompts = _prompts(cfg, [6, 6], seed=8)
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16)
    uids = [eng.submit(p, max_tokens=m) for p, m in zip(prompts, (2, 7))]
    got = eng.run()
    assert [len(got[u]) for u in uids] == [2, 7]


def test_page_pool_overcommit_queues_fifo(qwen):
    """batch_size=4 slots over a pool that only fits ~2 requests: admission
    waits for pages, everyone still completes with identical tokens."""
    cfg, params = qwen
    prompts = _prompts(cfg, [20, 24, 18, 22], seed=9)
    _, u_full, r_full = _serve(cfg, params, prompts, batch_size=4)
    pages_two = 2 * ((24 + 4 + 7) // 8)  # fits two largest requests
    eng, u_tight, r_tight = _serve(cfg, params, prompts, batch_size=4,
                                   max_pages=pages_two)
    assert [r_tight[u] for u in u_tight] == [r_full[u] for u in u_full]
    assert eng.stats["pages_in_use_peak"] <= pages_two
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


def test_page_pool_returns_to_initial_after_three_waves(qwen):
    """Page-pool hygiene regression, extended to refcounts: admit/retire 3
    waves of requests through one engine and assert after every wave that
    every page has returned to refcount 0 and the pool is fully reclaimable
    (free + refcount-0 cached == n_pages — no leak, no pinned stragglers),
    including a wave terminated early by EOS."""
    cfg, params = qwen
    eng = ServeEngine(params, cfg, batch_size=3, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    n0 = len(eng._free)
    assert n0 == eng.n_pages
    eos = None
    for wave in range(3):
        prompts = _prompts(cfg, [9, 17, 12], seed=50 + wave)
        uids = [eng.submit(p, max_tokens=3, eos_id=eos) for p in prompts]
        got = eng.run()
        assert sorted(got) == sorted(uids)
        assert not any(eng.slots)
        assert (eng._ref == 0).all()
        assert eng.reclaimable_pages == eng.n_pages
        assert len(eng._free) + eng.cached_pages == eng.n_pages
        # next wave terminates via EOS on a token the model actually emits
        eos = got[uids[0]][0]
    # wave 4: cancellations mid-flight — one admitted request cancelled
    # after its first tick, one cancelled while still queued; hygiene must
    # hold exactly as for completed waves
    prompts = _prompts(cfg, [9, 17, 12], seed=53)
    handles = [eng.submit(p, max_tokens=4) for p in prompts]
    extra = eng.submit(prompts[0], max_tokens=4)  # queued: 3 slots taken
    eng.tick()
    assert handles[1].cancel() and extra.cancel()
    got = eng.run()
    assert sorted(got) == sorted([handles[0], handles[2]])
    assert not any(eng.slots)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
    # dropping the cache returns every page to the free list
    eng.drop_prefix_cache()
    assert len(eng._free) == eng.n_pages and eng.cached_pages == 0


def test_submit_validation(qwen):
    cfg, params = qwen
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=32, page_size=8)
    with pytest.raises(ValueError):
        eng.submit(np.zeros(30, np.int32), max_tokens=8)  # > cache_len
    with pytest.raises(ValueError):
        eng.submit([], max_tokens=4)  # empty prompt
    eng2 = ServeEngine(params, cfg, batch_size=2, cache_len=64, page_size=8,
                       max_pages=2)
    with pytest.raises(ValueError):
        eng2.submit(np.zeros(40, np.int32), max_tokens=8)  # > whole pool


def test_tick_budget_exhaustion_releases_slots(qwen):
    """A run() cut off mid-decode returns partials, frees every page, and
    leaves the engine reusable (fresh run produces correct tokens)."""
    cfg, params = qwen
    prompts = _prompts(cfg, [9, 9], seed=10)
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16)
    uids = [eng.submit(p, max_tokens=6) for p in prompts]
    # 3 ragged ticks: the first packs the whole 9-token prompt AND the
    # first decode token, so each request has 3 of its 6 tokens
    partial = eng.run(max_ticks=3)
    assert all(len(partial[u]) == 3 for u in uids)
    assert eng.reclaimable_pages == eng.n_pages and not any(eng.slots)
    assert (eng._ref == 0).all()
    # resubmitting hits the prefix cached by the truncated run and must
    # still be token-identical to the solo ground truth
    u2 = eng.submit(prompts[0], max_tokens=4)
    assert eng.run()[u2] == _solo_decode(params, cfg, prompts[0], 4)
    assert eng.stats["prefix_hits"] >= 1


# ---------------------------------------------------------------------------
# Prefix cache: warm hits, COW divergence, eviction, refcount hygiene


def _with_prefix(shared, suffixes):
    return [np.concatenate([shared, s]) for s in suffixes]


def test_warm_prefix_hits_are_token_identical(qwen):
    """A second wave reusing a cached system prompt skips its prefill
    (prefix_hits / prefix_tokens_reused advance, packed tokens drop) and
    stays bit-identical to the solo ground truth — with the serve path
    still exactly one compiled program."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [40], seed=60)  # 5 full pages at page_size=8
    wave1 = _with_prefix(shared, _prompts(cfg, [5, 7], seed=61))
    wave2 = _with_prefix(shared, _prompts(cfg, [6, 4], seed=62))
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    u1 = [eng.submit(p, max_tokens=4) for p in wave1]
    r1 = eng.run()
    cold_packed = eng.stats["packed_tokens"]
    u2 = [eng.submit(p, max_tokens=4) for p in wave2]
    r2 = eng.run()
    warm_packed = eng.stats["packed_tokens"] - cold_packed
    for u, p in zip(u1 + u2, wave1 + wave2):
        assert {**r1, **r2}[u] == _solo_decode(params, cfg, p, 4)
    assert eng.stats["prefix_hits"] >= 2  # both wave-2 requests hit
    assert eng.stats["prefix_tokens_reused"] >= 2 * 40
    assert warm_packed < cold_packed / 2  # the prefill compute was skipped
    assert eng.stats["traces"] == 1
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


def test_fully_cached_prompt_skips_straight_to_decode(qwen):
    """A prompt that is one exact full cached page starts decoding on its
    first tick (zero prefill tokens packed for it)."""
    cfg, params = qwen
    [p] = _prompts(cfg, [16], seed=63)  # exactly 2 pages at page_size=8
    eng = ServeEngine(params, cfg, batch_size=1, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=8)
    u1 = eng.submit(p, max_tokens=3)
    r1 = eng.run()
    packed_cold = eng.stats["packed_tokens"]
    u2 = eng.submit(p, max_tokens=3)
    r2 = eng.run()
    assert r2[u2] == r1[u1] == _solo_decode(params, cfg, p, 3)
    # warm run packs exactly one decode token per emitted token
    assert eng.stats["packed_tokens"] - packed_cold == 3
    assert eng.stats["prefix_tokens_reused"] == 16


def test_cow_divergence_mid_page(qwen):
    """Two prompts sharing 18 tokens then diverging mid-page (page_size=8):
    the second rides 2 shared pages plus a COW copy of the third (a full
    cached page whose tail it overwrites), and both outputs match their solo
    ground truths."""
    cfg, params = qwen
    rng = np.random.RandomState(70)
    a = rng.randint(0, cfg.vocab_size, 26)  # 3 FULL pages + a partial tail
    b = a.copy()
    b[18:] = (b[18:] + 1) % cfg.vocab_size
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    ua = eng.submit(a, max_tokens=4)
    ra = eng.run()
    ub = eng.submit(b, max_tokens=4)
    rb = eng.run()
    assert ra[ua] == _solo_decode(params, cfg, a, 4)
    assert rb[ub] == _solo_decode(params, cfg, b, 4)
    assert eng.stats["cow_copies"] == 1
    assert eng.stats["prefix_tokens_reused"] == 18  # 2 full pages + 2 in-page
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


@settings(max_examples=6, deadline=None)
@given(share=st.sampled_from([3, 9, 16, 21, 27]),
       page=st.sampled_from([4, 8]))
def test_cow_property_shared_then_divergent(qwen, share, page):
    """Property: for any shared-prefix length (page-aligned or mid-page) and
    page size, serving A then a B that diverges at ``share`` reuses exactly
    the shared tokens covered by A's FULL (indexable) pages — a mid-page
    share point costs one COW copy — and both stay token-identical to solo
    decode, with the pool fully reclaimable after."""
    cfg, params = qwen
    rng = np.random.RandomState(71)
    a = rng.randint(0, cfg.vocab_size, 28)
    b = a.copy()
    b[share:] = (b[share:] + 1 + rng.randint(0, 100)) % cfg.vocab_size
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=page, prefill_chunk=8, token_budget=16)
    ua = eng.submit(a, max_tokens=4)
    ra = eng.run()
    ub = eng.submit(b, max_tokens=4)
    rb = eng.run()
    assert ra[ua] == _solo_decode(params, cfg, a, 4)
    assert rb[ub] == _solo_decode(params, cfg, b, 4)
    # only A's full pages enter the index: its partial tail page is private
    reusable = min(share, (len(a) // page) * page)
    assert eng.stats["prefix_tokens_reused"] == reusable
    assert eng.stats["cow_copies"] == (1 if reusable % page else 0)
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages


def test_prefix_cache_lru_eviction_under_pressure(qwen):
    """A pool sized for ~2 requests serving many distinct prompts must evict
    cached pages (LRU over refcount-0) instead of deadlocking, and still
    produce correct tokens; a recently cached prefix still hits."""
    cfg, params = qwen
    prompts = _prompts(cfg, [20, 24, 18, 22, 21, 19], seed=72)
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32,
                      max_pages=8)
    uids = [eng.submit(p, max_tokens=3) for p in prompts]
    got = eng.run()
    for u, p in zip(uids, prompts):
        assert got[u] == _solo_decode(params, cfg, p, 3)
    assert eng.stats["evictions"] > 0
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
    # the LAST prompt's pages are the freshest cache entries: resubmitting
    # it hits even in the tight pool
    hits0 = eng.stats["prefix_hits"]
    u2 = eng.submit(prompts[-1], max_tokens=3)
    assert eng.run()[u2] == got[uids[-1]]
    assert eng.stats["prefix_hits"] > hits0


def test_prefix_sharing_concurrent_in_flight(qwen):
    """A request admitted while the prefix OWNER is still decoding shares
    the owner's pages (refcount > 1 on the shared pages, asserted
    mid-flight via the tick API) — and both finish correctly."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [24], seed=73)
    a, b = _with_prefix(shared, _prompts(cfg, [4, 6], seed=74))
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    ua = eng.submit(a, max_tokens=12)
    done = {}
    for _ in range(3):  # a finishes prefill and starts decoding
        done.update(eng.tick())
    ub = eng.submit(b, max_tokens=2)
    done.update(eng.tick())  # b admitted while a decodes
    assert eng.stats["prefix_hits"] == 1
    shared_pages = 24 // 8
    assert (eng._ref == 2).sum() == shared_pages  # pages aliased by a and b
    while not eng.idle:
        done.update(eng.tick())
    assert done[ua] == _solo_decode(params, cfg, a, 12)
    assert done[ub] == _solo_decode(params, cfg, b, 2)
    assert (eng._ref == 0).all()


def test_flash_ragged_shared_pages_match_jnp(qwen):
    """The Pallas ragged kernel needs no change for aliased block-table
    rows: warm-prefix traffic through flash_decode=True matches the jnp
    path token for token, with hits on both engines."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [32], seed=75)
    prompts = _with_prefix(shared, _prompts(cfg, [5, 3], seed=76))

    def run(flash):
        eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                          page_size=8, prefill_chunk=16, token_budget=32,
                          flash_decode=flash)
        u1 = [eng.submit(p, max_tokens=4) for p in prompts]
        r1 = eng.run()
        u2 = [eng.submit(p, max_tokens=4) for p in prompts]
        r2 = eng.run()
        assert eng.stats["prefix_hits"] >= 2
        return [r1[u] for u in u1] + [r2[u] for u in u2]

    assert run(False) == run(True)


def test_prefix_cache_disabled_for_hybrid_models(gemma):
    """Windowed circular buffers and recurrent states are per-slot and
    cannot be inherited from shared pages: sharing is auto-disabled (and
    explicit opt-out works on shareable models too)."""
    cfg_g, params_g = gemma
    eng = ServeEngine(params_g, cfg_g, batch_size=2, cache_len=CACHE,
                      page_size=8)
    assert not eng.prefix_cache
    cfg_x = get_config("xlstm-350m", smoke=True)
    params_x = M.init_params(KEY, cfg_x)
    eng = ServeEngine(params_x, cfg_x, batch_size=2, cache_len=CACHE,
                      page_size=8)
    assert not eng.prefix_cache


def test_prefix_cache_opt_out_matches_opt_in(qwen):
    """prefix_cache=False serves identical warm traffic with zero hits and
    identical tokens (the A/B knob the benchmark sweeps)."""
    cfg, params = qwen
    [shared] = _prompts(cfg, [24], seed=77)
    prompts = _with_prefix(shared, _prompts(cfg, [4, 5], seed=78))

    def run(on):
        eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                          page_size=8, prefill_chunk=16, token_budget=32,
                          prefix_cache=on)
        outs = []
        for _ in range(2):
            uids = [eng.submit(p, max_tokens=4) for p in prompts]
            got = eng.run()
            outs += [got[u] for u in uids]
        return outs, eng.stats

    on_outs, on_stats = run(True)
    off_outs, off_stats = run(False)
    assert on_outs == off_outs
    assert on_stats["prefix_hits"] >= 2 and off_stats["prefix_hits"] == 0
    assert off_stats["prefix_tokens_reused"] == 0


def test_tick_api_continuous_arrivals(qwen):
    """Requests submitted mid-flight through the public tick() API (the
    continuous-arrival driver contract) complete token-identically."""
    cfg, params = qwen
    prompts = _prompts(cfg, [9, 13, 7], seed=79)
    eng = ServeEngine(params, cfg, batch_size=2, cache_len=CACHE,
                      page_size=8, prefill_chunk=16, token_budget=32)
    uids = [eng.submit(prompts[0], max_tokens=5)]
    done = {}
    done.update(eng.tick())
    uids.append(eng.submit(prompts[1], max_tokens=5))
    done.update(eng.tick())
    uids.append(eng.submit(prompts[2], max_tokens=5))
    for _ in range(64):
        if eng.idle:
            break
        done.update(eng.tick())
    for u, p in zip(uids, prompts):
        assert done[u] == _solo_decode(params, cfg, p, 5)
    assert eng.stats["traces"] == 1


def test_admission_feasible_when_match_pins_all_evictable_pages(qwen):
    """Corner from review: the head request's own matched (refcount-0)
    pages and COW source must not be counted as evictable supply for its
    allocation.  Here the request's footprint equals the whole pool, every
    cached page belongs to its own match, and pinning the COW source too
    would leave the pool one page short — the engine must forgo the
    partial-page COW and admit on the full-page match alone rather than
    dying in _alloc or waiting forever."""
    cfg, params = qwen
    rng = np.random.RandomState(80)
    a = rng.randint(0, cfg.vocab_size, 24)  # 3 full pages cached after run
    eng = ServeEngine(params, cfg, batch_size=1, cache_len=56, page_size=8,
                      prefill_chunk=16, token_budget=16, max_pages=7)
    ua = eng.submit(a, max_tokens=8)
    ra = eng.run()
    assert eng.cached_pages == 3 and (eng._ref == 0).all()
    # b: 2 full pages + 3-token mid-page lcp of a, then diverges; its
    # 7-page footprint is the ENTIRE pool
    b = np.concatenate([a[:19], rng.randint(0, cfg.vocab_size, 25)])
    ub = eng.submit(b, max_tokens=8)
    rb = eng.run()
    assert rb[ub] == _solo_decode(params, cfg, b, 8)
    assert eng.stats["cow_copies"] == 0  # COW forgone, not crashed
    assert eng.stats["prefix_tokens_reused"] == 16  # full-page match kept
    assert (eng._ref == 0).all()
    assert eng.reclaimable_pages == eng.n_pages
