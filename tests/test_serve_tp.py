"""Tensor-parallel serving invariance suite.

The engine's ``mesh=`` contract (serve/engine.py): the paged KV pools shard
over the KV-head axis while ALL host bookkeeping stays global, so the same
seeded traffic must produce TOKEN-IDENTICAL output at every device count,
with zero page leaks and exactly one traced serve program per count.  Each
device count runs in a subprocess with forked fake devices (see
conftest.run_multidevice); the parent compares canonical transcripts.

qwen1.5-4b smoke is the config under test: its global-attention layers have
num_kv_heads == 4, so the pools genuinely split 4 ways (qwen2-1.5b smoke has
kvH == 2 and could not).
"""
import pytest

# Seeded mixed traffic: staggered submits, prefix-sharing family prompts,
# mid-flight cancels, int8 pools — everything the engine's bookkeeping
# touches; prints a canonical transcript plus the in-process invariants.
_DRIVER = """
import jax, numpy as np
from repro.configs import get_config
from repro.models import model as M
from repro.serve.engine import ServeEngine
from repro.serve.pool import kv_page_bytes

N_DEV = {n}
cfg = get_config("qwen1.5-4b", smoke=True)
params = M.init_params(jax.random.PRNGKey(0), cfg)
kw = dict(batch_size=2, cache_len=64, page_size=8, prefill_chunk=8,
          token_budget=16, kv_dtype="int8", flash_decode={flash})
if N_DEV > 1:
    from repro.launch.mesh import make_mesh
    kw["mesh"] = make_mesh((N_DEV,), ("model",))
eng = ServeEngine(params, cfg, **kw)

rng = np.random.default_rng(7)
[family] = [rng.integers(1, cfg.vocab_size, size=16)]
handles = []
for i in range(6):
    if i % 2:
        prompt = np.concatenate([family, rng.integers(1, cfg.vocab_size,
                                                      size=1 + i)])
    else:
        prompt = rng.integers(1, cfg.vocab_size, size=int(rng.integers(3, 24)))
    handles.append(eng.submit(prompt, max_tokens=4 + i % 5))
    eng.tick()
handles[2].cancel()
res = eng.run()

# host bookkeeping must be device-count-agnostic
assert eng.stats["traces"] == 1, eng.stats["traces"]
assert (eng._ref == 0).all()
assert eng.reclaimable_pages == eng.n_pages
assert eng.stats["kv_shards"] == (N_DEV if N_DEV > 1 else 1)
assert eng.stats["n_devices"] == N_DEV
# per-device pool bytes shrink by the shard count (kvH=4 divides exactly)
assert eng.stats["kv_pool_bytes_per_device"] * eng.stats["kv_shards"] \\
    == eng.stats["kv_pool_bytes"]
print("TRANSCRIPT", sorted((int(k), tuple(v)) for k, v in res.items()))
"""


def _transcript(multidevice, n_devices: int, flash: bool) -> str:
    out = multidevice(_DRIVER.format(n=n_devices, flash=flash),
                      n_devices=n_devices, timeout=900)
    lines = [l for l in out.splitlines() if l.startswith("TRANSCRIPT")]
    assert lines, out
    return lines[-1]


@pytest.mark.slow
def test_token_identity_across_device_counts(multidevice):
    """Same seeds, same traffic, device counts {1, 2, 4}: token-identical
    transcripts, zero leaks, one trace per count (asserted in-process)."""
    t1 = _transcript(multidevice, 1, flash=False)
    t2 = _transcript(multidevice, 2, flash=False)
    t4 = _transcript(multidevice, 4, flash=False)
    assert t1 == t2, f"{t1}\nvs\n{t2}"
    assert t1 == t4, f"{t1}\nvs\n{t4}"


@pytest.mark.slow
def test_token_identity_flash_kernel_path(multidevice):
    """The Pallas flash path (shard_map'd over KV heads in
    serve.decode_attention) preserves the same identity contract."""
    t1 = _transcript(multidevice, 1, flash=True)
    t4 = _transcript(multidevice, 4, flash=True)
    assert t1 == t4, f"{t1}\nvs\n{t4}"
