"""Fixture: mutable default arguments.

Line numbers asserted exactly by tests/test_analysis.py; edit with care.
"""


def accum(x, out=[]):  # VIOLATION line 7: shared list default
    out.append(x)
    return out


def keyed(x, *, table=dict()):  # VIOLATION line 12: dict() call default
    table[x] = True
    return table


def fine(x, out=None):
    return (out or []) + [x]
