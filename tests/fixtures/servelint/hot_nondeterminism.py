"""Fixture: nondeterminism in a hot path (rel=serve/...).

Line numbers asserted exactly by tests/test_analysis.py; edit with care.
"""
import time

import numpy as np


def tick(pool):
    jitter = np.random.rand()  # VIOLATION line 11: unseeded RNG
    start = time.perf_counter()  # VIOLATION line 12: wall clock
    for page in {3, 1, 2}:  # VIOLATION line 13: unordered set iteration
        pool.append(page)
    ok = sum(1 for p in set(pool))  # reducer over a set: NOT flagged
    rng = np.random.default_rng((42, 7))  # tuple-seeded but NOT an
    # allowlisted file -> VIOLATION line 16
    return jitter, start, ok, rng
