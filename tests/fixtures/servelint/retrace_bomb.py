"""Fixture: jitted programs invoked with Python scalars (rel=serve/...).

Line numbers asserted exactly by tests/test_analysis.py; edit with care.
"""
import numpy as np


class FakeEngine:
    def tick(self, params, state, tokens, page):
        state = self._decode_step(params, state, len(tokens))  # VIOLATION 10
        data, state = self._gather_page(state, page)  # VIOLATION line 11:
        # bare page id bakes into the trace
        state = self._insert_page(state, data, np.int32(page))  # wrapped: OK
        return state
