"""Fixture: jax.jit outside a registered factory site (seeded violation).

Linted with rel="serve/jit_outside_factory.py" — not a registered site.
Line numbers are asserted exactly by tests/test_analysis.py; edit with care.
"""
import jax


def tick(fn, x):
    prog = jax.jit(fn)  # VIOLATION line 10: jit in the run path
    return prog(x)


@jax.jit  # decorator position: NOT flagged (module-level program def)
def decorated(x):
    return x + 1
