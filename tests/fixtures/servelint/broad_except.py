"""Fixture: bare/broad except (any scope).

Line numbers asserted exactly by tests/test_analysis.py; edit with care.
"""


def swallow(fn):
    try:
        return fn()
    except Exception:  # VIOLATION line 10: broad
        return None


def swallow_bare(fn):
    try:
        return fn()
    except:  # noqa: E722  VIOLATION line 17: bare
        return None


def narrow(fn):
    try:
        return fn()
    except (ValueError, KeyError):  # specific: NOT flagged
        return None
