"""Fixture: suppression syntax — findings here must come back suppressed.

Line numbers asserted exactly by tests/test_analysis.py; edit with care.
"""
import time


def measured(fn):
    t0 = time.perf_counter()  # servelint: ignore[hot-nondeterminism] — measurement-only fixture
    out = fn()
    # servelint: ignore[hot-nondeterminism] — own-line comment covers next line
    t1 = time.perf_counter()
    return out, t1 - t0


def unrelated(fn):
    try:  # servelint: ignore[hot-nondeterminism] — wrong rule: does NOT cover
        return fn()
    except Exception:  # VIOLATION line 19: broad-except, not suppressed
        return None
