"""Autotuner: candidate evaluation plumbing (multidevice subprocess — the
full-size lowering needs fake devices)."""
import pytest


@pytest.mark.xfail(strict=False, reason="seed-era: autotune ranking is CPU-environment sensitive")
def test_autotune_ranks_candidates(multidevice):
    multidevice("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core.autotune import Candidate, select_defaults
from repro.launch.mesh import make_mesh

# a small mesh keeps this quick; the production flow uses 16x16
mesh = make_mesh((4, 2), ("data", "model"))
out = select_defaults(
    "xlstm-350m", "decode_32k", mesh,
    candidates=(Candidate("baseline", {}),
                Candidate("bf16-params", {"param_dtype": "bfloat16"})))
assert "best" in out and "candidate" in out["best"], out
names = {r.get("candidate") for r in out["table"]}
assert names == {"baseline", "bf16-params"}
assert all("error" not in r for r in out["table"]), out["table"]
print("OK", out["best"]["candidate"], out["best"]["dominant"])
""", n_devices=8, timeout=600)
