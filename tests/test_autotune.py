"""Autotuner: candidate evaluation plumbing (multidevice subprocess — the
full-size lowering needs fake devices)."""
import pytest


def test_autotune_ranks_candidates(multidevice):
    multidevice("""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core.autotune import Candidate, select_defaults
from repro.launch.mesh import make_mesh

# a small mesh keeps this quick; the production flow uses 16x16
mesh = make_mesh((4, 2), ("data", "model"))
out = select_defaults(
    "xlstm-350m", "decode_32k", mesh,
    candidates=(Candidate("baseline", {}),
                Candidate("bf16-params", {"param_dtype": "bfloat16"})))
assert "best" in out and "candidate" in out["best"], out
names = {r.get("candidate") for r in out["table"]}
assert names == {"baseline", "bf16-params"}
assert all("error" not in r for r in out["table"]), out["table"]
print("OK", out["best"]["candidate"], out["best"]["dominant"])
""", n_devices=8, timeout=600)


def test_select_serve_defaults_emits_one_config():
    """The serving-time analogue of the paper's tuned-once config: the sweep
    emits exactly one (token_budget, prefill_chunk, page_size, kv_dtype,
    scheduler) whose worst traffic-mix point is the best worst-case across
    the grid — ONE config that also picks the memory representation and the
    scheduling policy."""
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100)
    best, table = out["best"], out["table"]
    assert best["token_budget"] in (64, 128, 256)
    assert best["prefill_chunk"] in (16, 32, 64)
    assert best["page_size"] in (8, 16, 32)
    assert best["kv_dtype"] in ("float32", "bfloat16", "int8")
    assert best["scheduler"] in ("fifo", "prefix-aware", "slo",
                                 "class-then-family")
    assert 0.0 < best["score"] <= 1.0
    # full grid evaluated (chunks must leave decode room in the budget)
    n_valid = sum(1 for tb in (64, 128, 256) for pc in (16, 32, 64)
                  if pc < tb) * 3 * 3 * 4
    assert len(table) == n_valid
    # max-min selection: nobody beats the winner's worst-case fraction
    assert all(r["score"] <= best["score"] + 1e-12 for r in table)
    # deterministic (analytic model, no measurement noise)
    again = select_serve_defaults("qwen2-1.5b", smoke=True, context_len=100)
    assert again["best"] == best


def test_select_serve_defaults_respects_batch_constraint():
    from repro.core.autotune import select_serve_defaults

    out = select_serve_defaults("qwen2-1.5b", smoke=True, batch_size=96,
                                context_len=100)
    # token_budget < batch_size candidates are dropped (engine invariant)
    assert all(r["token_budget"] >= 96 for r in out["table"])
    assert out["best"]["token_budget"] >= 96
