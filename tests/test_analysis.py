"""Tier-1 gate for repro.analysis: per-rule fixtures with exact file:line
assertions, clean-run over the real tree, the donation proof, the
lifecycle model checker (incl. seeded-broken tables), scheduler protocol
conformance, and a CLI smoke — so ``pytest -x -q`` gates the analyzer the
same way CI's ``python -m repro.analysis --fail-on-findings`` does."""
import json
import textwrap
from pathlib import Path

import pytest

from repro.analysis import run_all
from repro.analysis.findings import Finding, Suppressions, load_baseline
from repro.analysis.servelint import lint_file, lint_tree

FIXTURES = Path(__file__).parent / "fixtures" / "servelint"


def _lint_fixture(name: str):
    """Lint a fixture as if it lived in serve/ (hot-path rules active)."""
    return lint_file(FIXTURES / name, rel=f"serve/{name}")


def _keys(findings, only_rule=None):
    return sorted((f.rule, f.line) for f in findings
                  if not f.suppressed and (only_rule is None
                                           or f.rule == only_rule))


# ---------------------------------------------------------------------------
# servelint: one fixture per rule, exact line/rule-id assertions


def test_jit_outside_factory_fixture():
    got = _lint_fixture("jit_outside_factory.py")
    assert _keys(got) == [("jit-outside-factory", 10)]


def test_hot_nondeterminism_fixture():
    got = _lint_fixture("hot_nondeterminism.py")
    assert _keys(got) == [("hot-nondeterminism", 11),
                          ("hot-nondeterminism", 12),
                          ("hot-nondeterminism", 13),
                          ("hot-nondeterminism", 16)]


def test_hot_rules_scope_to_hot_paths():
    # the same file linted OUTSIDE serve//kernels/: hot rules are off
    got = lint_file(FIXTURES / "hot_nondeterminism.py",
                    rel="core/hot_nondeterminism.py")
    assert _keys(got, "hot-nondeterminism") == []


def test_broad_except_fixture():
    got = _lint_fixture("broad_except.py")
    assert _keys(got) == [("broad-except", 10), ("broad-except", 17)]


def test_mutable_default_fixture():
    got = _lint_fixture("mutable_default.py")
    assert _keys(got) == [("mutable-default", 7), ("mutable-default", 12)]


def test_retrace_bomb_fixture():
    got = _lint_fixture("retrace_bomb.py")
    assert _keys(got) == [("retrace-bomb", 10), ("retrace-bomb", 11)]


def test_suppression_fixture():
    got = _lint_fixture("suppressed.py")
    sup = [f for f in got if f.suppressed]
    # both perf_counter hits suppressed (same-line and own-line-above)
    assert sorted(f.line for f in sup) == [9, 12]
    assert all(f.rule == "hot-nondeterminism" for f in sup)
    assert "measurement-only fixture" in sup[0].reason
    # a suppression naming the WRONG rule does not cover the broad except
    assert _keys(got) == [("broad-except", 19)]


def test_suppression_requires_named_rule():
    sup = Suppressions("x = 1  # servelint: ignore[other-rule] — nope\n")
    assert sup.lookup(1, "broad-except") == (False, "")
    hit, reason = sup.lookup(1, "other-rule")
    assert hit and reason == "nope"


# ---------------------------------------------------------------------------
# clean run + baseline: the real tree must have zero actionable findings


def test_real_tree_is_clean():
    unsuppressed = [f for f in lint_tree() if not f.suppressed]
    assert unsuppressed == [], \
        "\n".join(str(f) for f in unsuppressed)


def test_baseline_is_empty():
    assert load_baseline() == set(), \
        "baseline.json must stay empty: fix or inline-suppress findings"


def test_suppressions_carry_reasons():
    tolerated = [f for f in lint_tree() if f.suppressed]
    assert tolerated, "expected the documented intentional catch-alls"
    for f in tolerated:
        assert f.reason, f"suppression without a reason: {f}"


# ---------------------------------------------------------------------------
# contracts: the donation proof over the real serve programs


def test_donation_contract_static_proof():
    from repro.analysis.contracts import SERVE_PROGRAMS, check_contracts

    findings, stats = check_contracts(compile_programs=True)
    assert [str(f) for f in findings] == []
    progs = stats["programs"]
    assert set(progs) == set(SERVE_PROGRAMS)
    for name, rec in progs.items():
        assert rec["proved"], (name, rec)
        assert rec["donated_leaves"] == 4  # kp/vp + int8 ks/vs
    # the gather keeps its state LIVE: nothing aliased at all
    assert progs["_gather_page"]["aliased_params"] == 0
    assert all(progs[n]["aliased_params"] > 0 for n in progs
               if n != "_gather_page")


def test_donation_ast_layers_catch_drift(tmp_path):
    # rewriting the engine source with a missing donation must be caught by
    # the AST cross-check layer (compile_programs=False path)
    from repro.analysis import contracts

    src = contracts._ENGINE_PATH.read_text()
    broken = src.replace(
        "self._chunk_step = jax.jit(step(False), donate_argnums=donate)",
        "self._chunk_step = jax.jit(step(False))")
    assert broken != src
    bad = tmp_path / "engine.py"
    bad.write_text(broken)
    orig = contracts._ENGINE_PATH
    try:
        contracts._ENGINE_PATH = bad
        findings, _ = contracts.check_contracts(compile_programs=False)
    finally:
        contracts._ENGINE_PATH = orig
    assert any(f.rule == "donation-contract" and "_chunk_step" in f.message
               for f in findings)


def test_assert_donated_rejects_partial():
    from repro.analysis.contracts import assert_donated

    class FakeLeaf:
        def __init__(self, ptr):
            self._ptr = ptr

        def unsafe_buffer_pointer(self):
            return self._ptr

    before = {"['kp']": 1, "['vp']": 2}
    with pytest.raises(AssertionError, match="partially donated"):
        assert_donated(before, {"kp": FakeLeaf(1), "vp": FakeLeaf(99)})
    assert assert_donated(before, {"kp": FakeLeaf(1),
                                   "vp": FakeLeaf(2)}) == "donated"
    assert assert_donated(before, {"kp": FakeLeaf(7),
                                   "vp": FakeLeaf(8)}) == "undonated"


# ---------------------------------------------------------------------------
# lifecycle: exhaustive pass on the real table, counterexamples on broken


def test_lifecycle_exhaustive_pass():
    from repro.analysis.lifecycle import check_lifecycle

    res = check_lifecycle()
    assert res.ok, res.violations
    assert res.states_explored > 50  # genuinely explored, not vacuous
    assert res.states_explored < 200_000  # full closure, not truncated


@pytest.mark.parametrize("breakage,invariant", [
    ("storm-drops-parks", "parked-pinned"),
    ("release-leaks", "conservation"),
    ("double-free", "conservation"),
])
def test_lifecycle_broken_tables_caught(breakage, invariant):
    from repro.analysis.lifecycle import broken_model, check_lifecycle

    res = check_lifecycle(broken_model(breakage))
    assert not res.ok
    names = [inv for inv, _, _ in res.violations]
    assert invariant in names
    # the counterexample trace is replayable: non-empty op sequence
    trace = next(tr for inv, _, tr in res.violations if inv == invariant)
    assert trace, "BFS must return the shortest witnessing op sequence"


# ---------------------------------------------------------------------------
# protocols: the live registry conforms; a broken policy is caught


def test_scheduler_registry_conforms():
    from repro.analysis.protocols import check_protocols

    findings, stats = check_protocols()
    assert [str(f) for f in findings] == []
    assert set(stats["schedulers"]) >= {"fifo", "slo", "speculative"}


def test_broken_scheduler_caught():
    from repro.analysis.protocols import _check_instance
    from repro.serve.scheduler import Scheduler

    class DoubleAdmit(Scheduler):
        def admission_order(self, view):
            return [0, 0] if view.queue else []

    class SlotDropper(Scheduler):
        def decode_order(self, view, ready):
            return list(ready)[:-1]

    assert any("duplicate" in f.message for f in _check_instance(
        "dup", DoubleAdmit(), "x.py", 1))
    assert any("PERMUTE" in f.message for f in _check_instance(
        "drop", SlotDropper(), "x.py", 1))


def test_nondelegating_wrapper_caught(tmp_path):
    from repro.analysis.protocols import _check_wrapper_delegation

    src = textwrap.dedent("""
        class SneakyWrapper:
            def __init__(self, inner):
                self.inner = inner

            def admission_order(self, view):
                return self.inner.admission_order(view)

            def decode_order(self, view, ready):
                return list(reversed(self.inner.decode_order(view, ready)))
    """)
    p = tmp_path / "sched.py"
    p.write_text(src)
    findings = _check_wrapper_delegation("sched.py", p)
    assert len(findings) == 1
    assert "decode_order" in findings[0].message
    assert "VERBATIM" in findings[0].message


# ---------------------------------------------------------------------------
# CLI: the CI entrypoint, in-process


def test_cli_clean_run(tmp_path, capsys):
    from repro.analysis.__main__ import main

    out = tmp_path / "findings.json"
    rc = main(["--fail-on-findings", "--passes", "lint,lifecycle,protocols",
               "--json", str(out)])
    assert rc == 0
    text = capsys.readouterr().out
    assert "0 actionable" in text
    dumped = json.loads(out.read_text())
    assert all(f["suppressed"] for f in dumped)


def test_cli_rejects_unknown_pass():
    with pytest.raises(ValueError, match="unknown passes"):
        run_all(["nope"])


def test_full_gate():
    """Exactly what CI runs: every pass, fail on any actionable finding."""
    from repro.analysis.__main__ import main

    assert main(["--fail-on-findings"]) == 0
