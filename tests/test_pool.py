"""PagePool unit tests — the memory-settings layer in isolation.

The point of the three-layer split: every page policy (refcounts, prefix
trie, COW matching, LRU eviction, admission supply) is testable here in
microseconds with NO model, NO jax arrays, NO engine — just integer page
ids.  The engine-level behavior these policies produce is covered by
tests/test_serve.py and tests/test_serve_api.py."""
import numpy as np
import pytest

from repro.serve.pool import PagePool, kv_bytes_per_token, kv_page_bytes


def _prompt(*toks):
    return np.asarray(toks, np.int32)


def _chain(pool, tokens, page_size=4):
    """Index ``tokens`` (a multiple of page_size) as a cached chain of
    freshly allocated pages; returns the pages (refcount 1, caller owns)."""
    assert len(tokens) % page_size == 0
    node = pool.root
    pages = pool.alloc(len(tokens) // page_size)
    for j, p in enumerate(pages):
        key = tuple(tokens[j * page_size:(j + 1) * page_size])
        node = pool.index_page(node, key, p)
        assert node is not None
    return pages


# ---------------------------------------------------------------------------
# Allocation / refcounts


def test_alloc_release_roundtrip():
    pool = PagePool(6, 4)
    pages = pool.alloc(4)
    assert len(pages) == len(set(pages)) == 4
    assert pool.free_pages == 2
    assert all(pool.ref(p) == 1 for p in pages)
    pool.share(pages[:2])
    assert [pool.ref(p) for p in pages] == [2, 2, 1, 1]
    pool.release(pages)
    assert [pool.ref(p) for p in pages] == [1, 1, 0, 0]
    assert pool.free_pages == 4  # unindexed ref-0 pages free immediately
    pool.release(pages[:2])
    assert pool.free_pages == 6
    assert pool.reclaimable_pages == pool.n_pages


def test_over_release_asserts():
    pool = PagePool(2, 4)
    [p] = pool.alloc(1)
    pool.release([p])
    with pytest.raises(AssertionError):
        pool.release([p])


def test_alloc_beyond_supply_raises():
    pool = PagePool(2, 4)
    pool.alloc(2)
    with pytest.raises(RuntimeError):
        pool.alloc(1)  # nothing free, nothing evictable


# ---------------------------------------------------------------------------
# Prefix trie: match, index ownership, COW candidates


def test_match_prefix_full_pages_and_cow():
    pool = PagePool(8, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)  # indexed: stays cached at refcount 0
    assert pool.cached_pages == 2 and pool.free_pages == 6

    node, hit, matched, cow = pool.match_prefix(
        _prompt(1, 2, 3, 4, 5, 6, 7, 8, 9))
    assert hit == pages and matched == 8 and cow is None
    # diverging mid-page: 2 full pages... no wait, diverges inside page 2
    node, hit, matched, cow = pool.match_prefix(_prompt(1, 2, 3, 4, 5, 6, 99))
    assert hit == [pages[0]] and matched == 4
    assert cow == (pages[1], 2)  # lcp(5,6 | 5,6,7,8) = 2 extra tokens
    # no shared tokens at all
    node, hit, matched, cow = pool.match_prefix(_prompt(9, 9, 9, 9, 9))
    assert hit == [] and matched == 0 and cow is None


def test_index_page_ownership_conflict():
    """A second, byte-identical page never displaces the index owner — the
    caller learns to stop indexing (None) and keeps its private copy."""
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4])
    [dup] = pool.alloc(1)
    assert pool.index_page(pool.root, (1, 2, 3, 4), dup) is None
    assert pool.cached_pages == 1  # still just the original
    pool.release(pages)
    pool.release([dup])
    assert pool.free_pages == 3 and pool.cached_pages == 1


def test_probe_prefix_len_matches_and_does_not_touch_lru():
    pool = PagePool(8, 4)
    a = _chain(pool, [1, 2, 3, 4])
    b = _chain(pool, [5, 6, 7, 8])
    pool.release(a)
    pool.release(b)
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4, 9)) == 4
    assert pool.probe_prefix_len(_prompt(9, 1, 2, 3)) == 0
    # a MUTATING match on `a` makes it most-recently-used...
    pool.match_prefix(_prompt(1, 2, 3, 4))
    # ...then probing `b` must NOT refresh it: b is still the LRU victim
    pool.probe_prefix_len(_prompt(5, 6, 7, 8))
    pool.alloc(7)  # forces one eviction
    assert pool.cached_pages == 1
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4)) == 4  # a survived
    assert pool.probe_prefix_len(_prompt(5, 6, 7, 8)) == 0  # b evicted


# ---------------------------------------------------------------------------
# Eviction: LRU over refcount-0, leaf-first


def test_evict_lru_leaf_first():
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])  # one 2-page chain
    pool.release(pages)
    assert pool.evictable() == 2
    assert pool.evict_one()
    # leaf first: the root child (page 0 of the chain) must survive
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4, 5, 6, 7, 8)) == 4
    assert pool.stats["evictions"] == 1
    assert pool.evict_one() and not pool.evict_one()
    assert pool.free_pages == 4 and pool.cached_pages == 0


def test_pinned_pages_never_evicted():
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release([pages[1]])  # leaf ref 0; root of chain still held
    assert pool.evictable() == 1
    assert pool.evict_one() and not pool.evict_one()  # only the leaf goes
    assert pool.ref(pages[0]) == 1 and pool.cached_pages == 1
    pool.release([pages[0]])
    assert pool.drop_cache() == 1
    assert pool.free_pages == 4


def test_available_discounts_callers_own_pins():
    """The admission corner from PR 3 review, now a one-liner on the pool:
    a refcount-0 cached page the request itself is about to pin must not be
    counted as reclaimable supply for its own allocation."""
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    assert pool.available() == 4  # 2 free + 2 evictable
    assert pool.available(pinned=pages) == 2
    assert pool.available(pinned=[pages[0], pages[0]]) == 3  # dedup
    pool.share([pages[0]])  # someone else holds it -> not supply either way
    # 2 free + 1 evictable - 1 self-pinned (the still-ref-0 leaf)
    assert pool.available(pinned=pages) == 2


def test_index_disabled_degrades_to_plain_allocator():
    pool = PagePool(4, 4, index_enabled=False)
    pages = pool.alloc(2)
    assert pool.index_page(pool.root, (1, 2, 3, 4), pages[0]) is None
    node, hit, matched, cow = pool.match_prefix(_prompt(1, 2, 3, 4))
    assert (hit, matched, cow) == ([], 0, None)
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4)) == 0
    pool.release(pages)
    assert pool.free_pages == 4 and pool.cached_pages == 0


# ---------------------------------------------------------------------------
# Byte-denominated budgeting


def test_kv_byte_pricing_linear_and_int8_smaller():
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", smoke=True)
    for dt in ("float32", "bfloat16", "int8"):
        assert kv_page_bytes(cfg, 8, dt) == 8 * kv_bytes_per_token(cfg, dt)
    # the byte budget's whole premise: int8 pages cost >= 2x less
    assert 2 * kv_page_bytes(cfg, 8, "int8") <= kv_page_bytes(
        cfg, 8, "float32")
