"""PagePool unit tests — the memory-settings layer in isolation.

The point of the three-layer split: every page policy (refcounts, prefix
trie, COW matching, LRU eviction, admission supply) is testable here in
microseconds with NO model, NO jax arrays, NO engine — just integer page
ids.  The engine-level behavior these policies produce is covered by
tests/test_serve.py and tests/test_serve_api.py."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.serve.pool import PagePool, kv_bytes_per_token, kv_page_bytes


def _prompt(*toks):
    return np.asarray(toks, np.int32)


def _chain(pool, tokens, page_size=4):
    """Index ``tokens`` (a multiple of page_size) as a cached chain of
    freshly allocated pages; returns the pages (refcount 1, caller owns)."""
    assert len(tokens) % page_size == 0
    node = pool.root
    pages = pool.alloc(len(tokens) // page_size)
    for j, p in enumerate(pages):
        key = tuple(tokens[j * page_size:(j + 1) * page_size])
        node = pool.index_page(node, key, p)
        assert node is not None
    return pages


# ---------------------------------------------------------------------------
# Allocation / refcounts


def test_alloc_release_roundtrip():
    pool = PagePool(6, 4)
    pages = pool.alloc(4)
    assert len(pages) == len(set(pages)) == 4
    assert pool.free_pages == 2
    assert all(pool.ref(p) == 1 for p in pages)
    pool.share(pages[:2])
    assert [pool.ref(p) for p in pages] == [2, 2, 1, 1]
    pool.release(pages)
    assert [pool.ref(p) for p in pages] == [1, 1, 0, 0]
    assert pool.free_pages == 4  # unindexed ref-0 pages free immediately
    pool.release(pages[:2])
    assert pool.free_pages == 6
    assert pool.reclaimable_pages == pool.n_pages


def test_over_release_asserts():
    pool = PagePool(2, 4)
    [p] = pool.alloc(1)
    pool.release([p])
    with pytest.raises(AssertionError):
        pool.release([p])


def test_alloc_beyond_supply_raises():
    pool = PagePool(2, 4)
    pool.alloc(2)
    with pytest.raises(RuntimeError):
        pool.alloc(1)  # nothing free, nothing evictable


# ---------------------------------------------------------------------------
# Prefix trie: match, index ownership, COW candidates


def test_match_prefix_full_pages_and_cow():
    pool = PagePool(8, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)  # indexed: stays cached at refcount 0
    assert pool.cached_pages == 2 and pool.free_pages == 6

    node, hit, matched, cow = pool.match_prefix(
        _prompt(1, 2, 3, 4, 5, 6, 7, 8, 9))
    assert hit == pages and matched == 8 and cow is None
    # diverging mid-page: 2 full pages... no wait, diverges inside page 2
    node, hit, matched, cow = pool.match_prefix(_prompt(1, 2, 3, 4, 5, 6, 99))
    assert hit == [pages[0]] and matched == 4
    assert cow == (pages[1], 2)  # lcp(5,6 | 5,6,7,8) = 2 extra tokens
    # no shared tokens at all
    node, hit, matched, cow = pool.match_prefix(_prompt(9, 9, 9, 9, 9))
    assert hit == [] and matched == 0 and cow is None


def test_index_page_ownership_conflict():
    """A second, byte-identical page never displaces the index owner — the
    caller learns to stop indexing (None) and keeps its private copy."""
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4])
    [dup] = pool.alloc(1)
    assert pool.index_page(pool.root, (1, 2, 3, 4), dup) is None
    assert pool.cached_pages == 1  # still just the original
    pool.release(pages)
    pool.release([dup])
    assert pool.free_pages == 3 and pool.cached_pages == 1


def test_probe_prefix_len_matches_and_does_not_touch_lru():
    pool = PagePool(8, 4)
    a = _chain(pool, [1, 2, 3, 4])
    b = _chain(pool, [5, 6, 7, 8])
    pool.release(a)
    pool.release(b)
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4, 9)) == 4
    assert pool.probe_prefix_len(_prompt(9, 1, 2, 3)) == 0
    # a MUTATING match on `a` makes it most-recently-used...
    pool.match_prefix(_prompt(1, 2, 3, 4))
    # ...then probing `b` must NOT refresh it: b is still the LRU victim
    pool.probe_prefix_len(_prompt(5, 6, 7, 8))
    pool.alloc(7)  # forces one eviction
    assert pool.cached_pages == 1
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4)) == 4  # a survived
    assert pool.probe_prefix_len(_prompt(5, 6, 7, 8)) == 0  # b evicted


# ---------------------------------------------------------------------------
# Eviction: LRU over refcount-0, leaf-first


def test_evict_lru_leaf_first():
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])  # one 2-page chain
    pool.release(pages)
    assert pool.evictable() == 2
    assert pool.evict_one()
    # leaf first: the root child (page 0 of the chain) must survive
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4, 5, 6, 7, 8)) == 4
    assert pool.stats["evictions"] == 1
    assert pool.evict_one() and not pool.evict_one()
    assert pool.free_pages == 4 and pool.cached_pages == 0


def test_pinned_pages_never_evicted():
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release([pages[1]])  # leaf ref 0; root of chain still held
    assert pool.evictable() == 1
    assert pool.evict_one() and not pool.evict_one()  # only the leaf goes
    assert pool.ref(pages[0]) == 1 and pool.cached_pages == 1
    pool.release([pages[0]])
    assert pool.drop_cache() == 1
    assert pool.free_pages == 4


def test_available_discounts_callers_own_pins():
    """The admission corner from PR 3 review, now a one-liner on the pool:
    a refcount-0 cached page the request itself is about to pin must not be
    counted as reclaimable supply for its own allocation."""
    pool = PagePool(4, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    assert pool.available() == 4  # 2 free + 2 evictable
    assert pool.available(pinned=pages) == 2
    assert pool.available(pinned=[pages[0], pages[0]]) == 3  # dedup
    pool.share([pages[0]])  # someone else holds it -> not supply either way
    # 2 free + 1 evictable - 1 self-pinned (the still-ref-0 leaf)
    assert pool.available(pinned=pages) == 2


def test_index_disabled_degrades_to_plain_allocator():
    pool = PagePool(4, 4, index_enabled=False)
    pages = pool.alloc(2)
    assert pool.index_page(pool.root, (1, 2, 3, 4), pages[0]) is None
    node, hit, matched, cow = pool.match_prefix(_prompt(1, 2, 3, 4))
    assert (hit, matched, cow) == ([], 0, None)
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4)) == 0
    pool.release(pages)
    assert pool.free_pages == 4 and pool.cached_pages == 0


# ---------------------------------------------------------------------------
# Host tier: demotion keeps prefixes matchable, promotion brings them back


def test_demotion_keeps_prefix_matchable():
    pool = PagePool(2, 4, host_pages=4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    other = pool.alloc(2)  # pressure: both cached pages demote, not drop
    assert pool.stats["demotions"] == 2 and pool.stats["evictions"] == 0
    assert pool.host_cached_pages == 2 and pool.cached_pages == 0
    # the trie still matches the full prefix, as encoded host ids
    node, hit, matched, cow = pool.match_prefix(
        _prompt(1, 2, 3, 4, 5, 6, 7, 8))
    assert matched == 8 and all(pool.is_host(p) for p in hit)
    assert pool.probe_prefix_split(_prompt(1, 2, 3, 4, 5, 6, 7, 8)) == (0, 8)
    # chronological event log: leaf demoted first, each into a known slot
    ev = pool.drain_events()
    assert [e[0] for e in ev] == ["demote", "demote"]
    assert {e[1] for e in ev} == set(pages)
    assert pool.drain_events() == []  # drained
    pool.release(other)


def test_acquire_promotes_host_hits_back_to_device():
    pool = PagePool(2, 4, host_pages=4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    pool.release(pool.alloc(2))  # demote both out...
    pool.drain_events()
    node, hit, matched, cow = pool.match_prefix(
        _prompt(1, 2, 3, 4, 5, 6, 7, 8))
    got = pool.acquire(hit)  # ...and a later hit promotes them back
    assert len(got) == 2 and all(not pool.is_host(p) for p in got)
    assert all(pool.ref(p) == 1 for p in got)
    assert pool.stats["promotions"] == 2 and pool.host_cached_pages == 0
    assert [e[0] for e in pool.drain_events()] == ["promote", "promote"]
    # the promoted chain is a device-tier cache entry again
    pool.release(got)
    assert pool.probe_prefix_split(_prompt(1, 2, 3, 4, 5, 6, 7, 8)) == (8, 0)
    assert pool.reclaimable_pages == pool.n_pages


def test_device_region_stays_prefix_closed():
    """Demotion picks the LRU node with no DEVICE children — a chain
    demotes leaf-first, so every device page's ancestors are device pages
    and a matched chain's host hits are a contiguous tail."""
    pool = PagePool(3, 4, host_pages=4)
    pages = _chain(pool, list(range(1, 13)))  # 3-page chain
    pool.release(pages)
    [p] = pool.alloc(1)  # one demotion: must be the chain's LEAF
    node, hit, matched, _ = pool.match_prefix(_prompt(*range(1, 13)))
    assert matched == 12
    assert [pool.is_host(q) for q in hit] == [False, False, True]
    assert pool.probe_prefix_split(_prompt(*range(1, 13))) == (8, 4)
    pool.release([p])


def test_host_tier_full_evicts_lru_host_page():
    pool = PagePool(2, 4, host_pages=1)
    a = _chain(pool, [1, 2, 3, 4])
    pool.release(a)
    b = pool.alloc(1)  # a demotes into the single host slot
    b_node = pool.index_page(pool.root, (5, 6, 7, 8), b[0])
    assert b_node is not None
    pool.release(b)
    pool.alloc(2)  # b needs the slot -> a is host-evicted
    assert pool.stats["demotions"] == 2
    assert pool.stats["host_evictions"] == 1
    assert pool.host_cached_pages == 1
    ev = pool.drain_events()
    assert [e[0] for e in ev] == ["demote", "hevict", "demote"]
    assert pool.probe_prefix_len(_prompt(1, 2, 3, 4)) == 0  # a is gone
    assert pool.probe_prefix_len(_prompt(5, 6, 7, 8)) == 4  # b survives


def test_untiered_pool_has_no_tier_traffic():
    """host_pages=0 must behave exactly like the pre-tier pool: eviction
    drops, nothing demotes, the event log stays empty."""
    pool = PagePool(2, 4)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    pool.alloc(2)
    assert pool.stats["evictions"] == 2
    assert pool.stats["demotions"] == 0 and pool.stats["promotions"] == 0
    assert pool.events == [] and pool.host_cached_pages == 0
    assert pool.probe_prefix_split(_prompt(1, 2, 3, 4)) == (0, 0)


def test_available_ignores_encoded_host_ids():
    """Encoded host ids in ``pinned`` are not device supply — promoting
    them CONSUMES a device page, which admission prices as extra demand."""
    pool = PagePool(2, 4, host_pages=2)
    pages = _chain(pool, [1, 2, 3, 4])
    pool.release(pages)
    held = pool.alloc(2)  # demote the cached page
    pool.release([held[0]])
    _, hit, _, _ = pool.match_prefix(_prompt(1, 2, 3, 4))
    assert [pool.is_host(p) for p in hit] == [True]
    # 1 free device page; the host id must neither inflate nor (via the
    # ref-0 discount meant for cached DEVICE pins) deflate the count
    assert pool.available(hit) == 1
    assert pool.available(hit + hit) == 1  # encoded ids dedup too


def test_drop_cache_clears_both_tiers():
    pool = PagePool(2, 4, host_pages=2)
    pages = _chain(pool, [1, 2, 3, 4, 5, 6, 7, 8])
    pool.release(pages)
    pool.release(pool.alloc(2))  # both pages now host-resident
    pool.drain_events()
    assert pool.host_cached_pages == 2
    pool.drop_cache()
    assert pool.host_cached_pages == 0 and pool.cached_pages == 0
    assert pool.free_pages == 2 and pool.host_free_slots == 2
    assert [e[0] for e in pool.drain_events()] == ["hevict", "hevict"]


@settings(deadline=None, max_examples=60)
@given(ops=st.lists(st.tuples(st.integers(0, 3), st.integers(1, 3),
                              st.booleans()),
                    min_size=1, max_size=40),
       host_pages=st.integers(0, 3))
def test_tiered_interleavings_never_leak(ops, host_pages):
    """No-leak property across BOTH tiers: random admission/release traffic
    over a pool smaller than the prefix working set (every (family, length,
    release-first) triple drives match -> acquire -> alloc -> index ->
    release, the engine's exact call sequence) keeps every page accounted
    for in exactly one place, keeps host slots partitioned free/resident,
    and keeps the event log consistent with a simulated host store."""
    P = 4
    pool = PagePool(4, P, host_pages=host_pages)
    held, store = [], set()  # our chains; simulated engine host storage

    def drain():
        for ev in pool.drain_events():
            if ev[0] == "demote":
                assert ev[2] not in store  # never overwrites live bytes
                store.add(ev[2])
            else:  # promote / hevict both surrender the slot's bytes
                assert ev[1] in store
                store.discard(ev[1])

    for fam, npages, release_first in ops:
        if release_first and held:
            pool.release(held.pop(0))
        prompt = np.asarray([fam * 100 + i for i in range(npages * P)],
                            np.int32)
        node, pages, matched, _ = pool.match_prefix(prompt)
        need = npages - len(pages)
        n_host = sum(1 for p in pages if pool.is_host(p))
        while need + n_host > pool.available(pages) and held:
            pool.release(held.pop(0))
        if need + n_host > pool.available(pages):
            continue  # infeasible: engine would leave it queued
        pages = pool.acquire(pages)
        new = pool.alloc(need)
        for j, p in enumerate(new):
            key = tuple(int(t) for t in
                        prompt[matched + j * P:matched + (j + 1) * P])
            nxt = pool.index_page(node, key, p)
            if nxt is None:
                break
            node = nxt
        held.append(pages + new)
        drain()
        # every device page in exactly one place; host slots partitioned
        tracked = set(pool._page_node) | {
            p for p in range(pool.n_pages) if pool.ref(p) > 0}
        assert tracked.isdisjoint(pool._free)
        assert len(pool._free) + len(tracked) == pool.n_pages
        assert sorted(pool._host_free + list(pool._host_node)) == list(
            range(host_pages))
        assert store == set(pool._host_node)
    for chain in held:
        pool.release(chain)
    drain()
    assert (pool._ref == 0).all()
    assert pool.reclaimable_pages == pool.n_pages
    pool.drop_cache()
    drain()
    assert pool.free_pages == pool.n_pages and not store
    assert pool.host_free_slots == host_pages


# ---------------------------------------------------------------------------
# Byte-denominated budgeting


def test_kv_byte_pricing_linear_and_int8_smaller():
    from repro.configs import get_config

    cfg = get_config("qwen2-1.5b", smoke=True)
    for dt in ("float32", "bfloat16", "int8"):
        assert kv_page_bytes(cfg, 8, dt) == 8 * kv_bytes_per_token(cfg, dt)
    # the byte budget's whole premise: int8 pages cost >= 2x less
    assert 2 * kv_page_bytes(cfg, 8, "int8") <= kv_page_bytes(
        cfg, 8, "float32")
