"""Fault-injection chaos harness: FaultInjector determinism (a fault
schedule is a pure function of (seed, tick) — replayable, consultation- and
liveness-order independent), engine runs under injected alloc failures /
random cancels / host eviction storms / stalled ticks that stay leak-free
on both tiers with typed abort causes, and the property-based acceptance
gate: random submit / preempt / resume / cancel / deadline interleavings —
speculation off AND on — drain to zero leaked pages with every COMPLETED
request's transcript identical to an unpressured reference.  The happy-path
preemption tests live in tests/test_preemption.py."""
import jax
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.models import model as M
from repro.serve.chaos import FaultInjector
from repro.serve.engine import ServeEngine
from repro.serve.errors import Cancelled, DeadlineExceeded, ServeError

KEY = jax.random.PRNGKey(0)
CACHE = 64


@pytest.fixture(scope="module")
def qwen():
    # float32 keeps greedy argmax stable across batching layouts
    cfg = get_config("qwen2-1.5b", smoke=True).replace(dtype="float32")
    params = M.init_params(KEY, cfg)
    return cfg, params


def _prompts(cfg, lens, seed=0):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, L) for L in lens]


def _engine(params, cfg, **kw):
    kw.setdefault("batch_size", 2)
    kw.setdefault("cache_len", CACHE)
    kw.setdefault("page_size", 8)
    kw.setdefault("prefill_chunk", 16)
    kw.setdefault("token_budget", 32)
    return ServeEngine(params, cfg, **kw)


def _leak_free(eng):
    pool = eng.pool
    return bool((eng._ref == 0).all()
                and eng.reclaimable_pages == eng.n_pages
                and pool.parked_pages == 0
                and len(pool._host_free) + pool.host_cached_pages
                == pool.host_pages
                and set(eng._host_store) == set(pool._host_node))


# ---------------------------------------------------------------------------
# FaultInjector determinism


def test_fault_schedule_is_pure_function_of_seed_and_tick():
    kw = dict(p_alloc_fail=0.4, p_cancel=0.4, p_evict_storm=0.4,
              p_stall=0.4)
    a, b = FaultInjector(seed=11, **kw), FaultInjector(seed=11, **kw)
    sched_a = [a.faults(t, [3, 1, 2]) for t in range(40)]
    # consult b out of order, twice per tick: same schedule regardless
    sched_b = {t: b.faults(t, [2, 3, 1]) for t in reversed(range(40))}
    for t in range(40):
        assert sched_a[t] == b.faults(t, [1, 2, 3]) == sched_b[t]
    assert any(f["alloc_fail"] for f in sched_a)
    assert any(f["cancel"] is not None for f in sched_a)
    assert FaultInjector(seed=12, **kw).faults(0, [1]) != sched_a[0] or \
        FaultInjector(seed=12, **kw).faults(1, [1]) != sched_a[1]


def test_fault_draws_independent_of_liveness():
    # storm/stall outcomes must not shift with how many requests are live
    kw = dict(p_cancel=0.5, p_evict_storm=0.5, p_stall=0.5)
    a, b = FaultInjector(seed=3, **kw), FaultInjector(seed=3, **kw)
    for t in range(30):
        fa, fb = a.faults(t, [7, 8]), b.faults(t, [])
        assert fb["cancel"] is None  # nothing live, nothing to cancel
        assert (fa["evict_storm"], fa["stall"]) == (fb["evict_storm"],
                                                    fb["stall"])


def test_fault_window_and_validation():
    fi = FaultInjector(seed=0, p_stall=1.0, start_tick=10, stop_tick=12)
    hits = [t for t in range(20) if fi.faults(t, [])["stall"]]
    assert hits == [10, 11]
    assert fi.log == [(10, "stall", None), (11, "stall", None)]
    with pytest.raises(ValueError):
        FaultInjector(p_cancel=1.5)
    with pytest.raises(ValueError):
        FaultInjector(p_alloc_fail=-0.1)


# ---------------------------------------------------------------------------
# Chaos runs through the engine: leak-free, typed aborts, identical tokens


def test_chaos_run_leakfree_and_token_identical(qwen):
    cfg, params = qwen
    prompts = _prompts(cfg, [16, 16, 6, 6, 12, 8])
    clean = _engine(params, cfg, max_pages=16, host_pages=8,
                    scheduler="slo")
    want = [clean.submit(p, max_tokens=6).result() for p in prompts]

    eng = _engine(params, cfg, max_pages=8, host_pages=8, scheduler="slo",
                  fault_injector=FaultInjector(
                      seed=3, p_alloc_fail=0.3, p_cancel=0.1,
                      p_evict_storm=0.2, p_stall=0.2))
    handles = [eng.submit(p, max_tokens=6, priority=i % 2)
               for i, p in enumerate(prompts)]
    eng.run()
    assert all(h.done for h in handles)
    n_ok = 0
    for h, w in zip(handles, want):
        if h.request.error is not None:
            assert isinstance(h.request.error, (Cancelled,
                                                DeadlineExceeded))
            assert isinstance(h.request.error, ServeError)
            with pytest.raises(type(h.request.error)):
                h.result()
        elif len(h.request.out_tokens) == 6:
            assert list(h.request.out_tokens) == w  # survived == unchanged
            n_ok += 1
    assert n_ok >= 1  # the run must not degrade to all-cancelled
    st_ = eng.stats
    assert (st_["chaos_alloc_fails"] + st_["chaos_cancels"]
            + st_["chaos_evict_storms"] + st_["chaos_stalled_ticks"]) > 0
    assert st_["traces"] == 1
    assert _leak_free(eng)


def test_chaos_stall_advances_deadlines(qwen):
    cfg, params = qwen
    # every tick stalls: the clock runs, nothing is served, the deadline
    # still fires — liveness of the abort path does not depend on progress
    eng = _engine(params, cfg,
                  fault_injector=FaultInjector(seed=0, p_stall=1.0))
    (p,) = _prompts(cfg, [8])
    h = eng.submit(p, max_tokens=4, deadline_ticks=3)
    for _ in range(5):
        eng.tick()
    with pytest.raises(DeadlineExceeded):
        h.result(timeout_ticks=1)
    assert h.request.out_tokens == []
    assert eng.stats["chaos_stalled_ticks"] >= 3
    assert _leak_free(eng)


# ---------------------------------------------------------------------------
# Property: random pressure interleavings, spec off and on


def _drive_pressure_interleaving(eng, cfg, expect, prompts, ops):
    """Replay one op schedule against the shared engine; the hog/chat
    priority split plus the undersized pool makes preempt/resume fire
    inside ordinary interleavings rather than via a bespoke hook."""
    handles = []
    for op, j in ops:
        if op == "submit":
            k = j % len(prompts)
            hog = len(prompts[k]) > 8
            handles.append(eng.submit(
                prompts[k], max_tokens=8 if hog else 3,
                priority=0 if hog else 1,
                deadline_ticks=None if j % 3 else 16))
        elif op == "tick":
            eng.tick()
        elif handles:
            handles[j % len(handles)].cancel()
    eng.run()
    assert all(h.done for h in handles)
    for h in handles:
        r = h.request
        if (r.error is None and not r.cancelled
                and len(r.out_tokens) == r.max_tokens):
            assert list(r.out_tokens) == expect[r.prompt.tobytes()][
                :r.max_tokens]
    assert _leak_free(eng)


def _pressure_fixture(fn, params, cfg, spec_k):
    """One engine + reference transcripts shared across examples: later
    examples inherit earlier cache/tier state — more adversarial than a
    fresh pool, and much faster."""
    if not hasattr(fn, "_st"):
        prompts = _prompts(cfg, [16, 16, 6, 6])
        ref = _engine(params, cfg, max_pages=24)
        # keyed on the int32 form submit() normalizes prompts to
        expect = {np.asarray(p, np.int32).tobytes():
                  ref.submit(p, max_tokens=8).result()
                  for p in prompts}
        # undersized pool (two hog footprints) + host tier + slo classes:
        # chat submits preempt decoding hogs, hogs park and resume
        eng = _engine(params, cfg, max_pages=6, host_pages=8,
                      scheduler="slo", spec_k=spec_k,
                      fault_injector=FaultInjector(
                          seed=7, p_alloc_fail=0.1, p_cancel=0.05,
                          p_stall=0.05, p_evict_storm=0.05))
        fn._st = (eng, expect, prompts)
    return fn._st


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "tick",
                                               "cancel"]),
                              st.integers(0, 7)),
                    min_size=4, max_size=16))
def test_pressure_interleavings_never_leak(qwen, ops):
    cfg, params = qwen
    eng, expect, prompts = _pressure_fixture(
        test_pressure_interleavings_never_leak, params, cfg, spec_k=0)
    _drive_pressure_interleaving(eng, cfg, expect, prompts, ops)


@settings(max_examples=5, deadline=None)
@given(ops=st.lists(st.tuples(st.sampled_from(["submit", "tick", "tick",
                                               "cancel"]),
                              st.integers(0, 7)),
                    min_size=4, max_size=16))
def test_pressure_interleavings_never_leak_speculative(qwen, ops):
    """The same property with speculation on: preempting a slot mid-draft
    (and resuming it) must roll back cleanly — same transcripts, no leaked
    pages on either tier."""
    cfg, params = qwen
    eng, expect, prompts = _pressure_fixture(
        test_pressure_interleavings_never_leak_speculative, params, cfg,
        spec_k=4)
    _drive_pressure_interleaving(eng, cfg, expect, prompts, ops)
