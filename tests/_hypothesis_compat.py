"""Optional-``hypothesis`` shim for the test suite.

``hypothesis`` is a dev-only dependency and is absent from some runtime
images (the tier-1 gate must collect everywhere).  When it is installed we
re-export the real ``given`` / ``settings`` / ``strategies``; when it is
missing we fall back to a deterministic parametrized sampler: each
``@given(x=st.integers(a, b), ...)`` becomes a ``pytest.mark.parametrize``
over a fixed set of example tuples (bounds first, then seeded draws), so the
property tests still run — with fixed rather than searched examples.
"""
from __future__ import annotations

import inspect
import random

import pytest

try:  # pragma: no cover - exercised implicitly by which branch imports
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _N_EXAMPLES = 5  # per-test fallback example count (bounds + seeded draws)

    class _Strategy:
        """A value source with deterministic indexed draws."""

        def __init__(self, draw):
            self._draw = draw

        def example_at(self, i: int, rnd: random.Random):
            return self._draw(i, rnd)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            def draw(i, rnd):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rnd.randint(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def floats(min_value, max_value, **_kw):
            def draw(i, rnd):
                if i == 0:
                    return min_value
                if i == 1:
                    return max_value
                return rnd.uniform(min_value, max_value)

            return _Strategy(draw)

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)

            def draw(i, rnd):
                if i < len(elements):
                    return elements[i]
                return rnd.choice(elements)

            return _Strategy(draw)

        @staticmethod
        def booleans():
            return _strategies.sampled_from([False, True])

        @staticmethod
        def tuples(*strategies):
            def draw(i, rnd):
                return tuple(s.example_at(i, rnd) for s in strategies)

            return _Strategy(draw)

        @staticmethod
        def lists(elements, *, min_size=0, max_size=10):
            def draw(i, rnd):
                if i == 0:
                    n = min_size
                elif i == 1:
                    n = max_size
                else:
                    n = rnd.randint(min_size, max_size)
                # random per-element indices: bound-only draws would make
                # every list a constant repetition
                return [elements.example_at(rnd.randint(0, _N_EXAMPLES + 2),
                                            rnd) for _ in range(n)]

            return _Strategy(draw)

    st = _strategies()

    def settings(*_a, **_kw):  # noqa: D401 - mirror hypothesis.settings
        """No-op decorator factory (deadline/max_examples are meaningless
        for the fixed-example fallback)."""

        def deco(fn):
            return fn

        return deco

    def given(**strategy_kw):
        names = sorted(strategy_kw)

        def deco(fn):
            rnd = random.Random(f"hypothesis-compat:{fn.__name__}")
            cases = []
            for i in range(_N_EXAMPLES):
                cases.append(tuple(strategy_kw[n].example_at(i, rnd)
                                   for n in names))
            # dedupe (tiny domains can repeat the bound cases); key by repr —
            # drawn values may be unhashable (lists)
            seen, uniq = set(), []
            for c in cases:
                if repr(c) not in seen:
                    seen.add(repr(c))
                    uniq.append(c)

            def wrapper(*args, **kw):
                case = kw.pop("_hc_case")
                kw.update(dict(zip(names, case)))
                return fn(*args, **kw)

            # pytest reads the signature to bind fixtures/params: expose
            # ``_hc_case`` plus the original non-strategy params (fixtures)
            sig = inspect.signature(fn)
            passthrough = [p for n, p in sig.parameters.items()
                           if n not in names]
            wrapper.__signature__ = sig.replace(parameters=passthrough + [
                inspect.Parameter("_hc_case",
                                  inspect.Parameter.KEYWORD_ONLY)])
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return pytest.mark.parametrize("_hc_case", uniq)(wrapper)

        return deco
